"""End-to-end training driver: ~100M-param model, few hundred steps.

A scaled-down gemma-family config (~100M params) trained on the synthetic
Zipf+bigram stream with AdamW, cosine schedule, checkpointing every 100
steps. On this CPU container a step takes a few seconds — pass --steps 20
for a quick look; the default 200 steps show a clear loss curve.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.training import AdamWConfig, save_checkpoint, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("gemma-2b"),
        arch_id="gemma-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=1, head_dim=64, d_ff=2048, vocab_size=32000)
    print(f"{cfg.arch_id}: {cfg.param_count()/1e6:.0f}M params")

    params, opt, hist = train(
        cfg, steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        log_every=max(args.steps // 20, 1),
        opt_cfg=AdamWConfig(lr=6e-4, warmup_steps=20,
                            total_steps=args.steps))
    save_checkpoint(args.ckpt, params, extra={"steps": args.steps,
                                              "arch": cfg.arch_id})
    print(f"checkpoint -> {args.ckpt}; "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
