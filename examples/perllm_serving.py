"""The paper's scenario end-to-end: PerLLM scheduling over real engines.

Edge servers run a small model, the cloud runs a larger one (both reduced
for CPU). Service requests flow through the CS-UCB scheduler; chosen servers
execute real JAX prefill/decode via the continuous-batching engine, and the
cluster simulator accounts time/energy. Compares PerLLM against FineInfer,
and demonstrates the allocation-aware contract: the testbed carries a DVFS
frequency ladder, so each `Decision` names a (server, tier) pair and the
learned-tier policy undercuts the fixed-nominal one on energy.

    PYTHONPATH=src python examples/perllm_serving.py
"""
import copy

import jax

from repro.cluster import (
    BandwidthModel, DVFS_TIERS, Simulator, generate_workload, paper_testbed,
)
from repro.configs import get_config
from repro.core import ClusterView, drive_slot, make_policy
from repro.models import init_params
from repro.serving import ServingEngine


def main():
    # --- real execution engines (reduced models; CPU) -------------------
    key = jax.random.key(0)
    edge_cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=64,
                                              vocab_size=256)
    cloud_cfg = get_config("gemma3-12b").reduced(n_layers=2, d_model=128,
                                                 vocab_size=256)
    # every server carries the stock DVFS ladder: scheduling decisions are
    # (server, tier) pairs, not bare placements
    specs = paper_testbed("llama2-7b", n_edge=2, freq_tiers=DVFS_TIERS)
    engines = [ServingEngine(edge_cfg, init_params(key, edge_cfg),
                             max_batch=2, max_seq=64) for _ in range(2)]
    engines.append(ServingEngine(cloud_cfg, init_params(key, cloud_cfg),
                                 max_batch=4, max_seq=64))

    services = generate_workload(600, rate=8.0, seed=0)

    for name in ("perllm", "fineinfer"):
        sim = Simulator(specs, BandwidthModel(False, seed=1), seed=42)
        res = sim.run([copy.copy(s) for s in services],
                      make_policy(name, len(specs)))
        print(res.row())

    # --- the energy story: learned tier selection vs the nominal clock --
    for tiers, tag in ((False, "fixed-nominal"), (True, "learned-tiers")):
        sim = Simulator(specs, BandwidthModel(False, seed=1), slot=None,
                        seed=42)
        res = sim.run([copy.copy(s) for s in services],
                      make_policy("perllm", len(specs), admission=True,
                                  tiers=tiers))
        print(f"{tag:14s} energy={res.total_energy/1e3:6.1f} kJ "
              f"({res.energy_per_token:.2f} J/tok) "
              f"adm_succ={res.admitted_success_rate*100:5.1f}%")

    # --- the same cluster, event-driven: per-arrival views, feedback at
    # true completion time, plus a bursty workload with a mid-run cloud
    # bandwidth drop (Scenario hooks on the shared event loop) -----------
    bursty = generate_workload(600, rate=8.0, seed=0, scenario="burst")
    sim = Simulator(specs, BandwidthModel(False, seed=1), slot=None, seed=42)
    res = sim.run([copy.copy(s) for s in bursty],
                  make_policy("perllm", len(specs)), scenario="bwdrop")
    print("event-driven burst+bwdrop:", res.row())

    # --- drive a slice of real tokens through the chosen engines --------
    # Each Decision's Allocation says how the engine's host is paced: the
    # chosen DVFS tier is printed alongside the placement.
    policy = make_policy("perllm", len(specs))
    from repro.cluster.workload import classify
    view = ClusterView(t=0.0, specs=specs, bw_factor=[1.0] * len(specs),
                       uplink_free_at=[0.0] * len(specs),
                       lane_free=[[0.0] * s.max_concurrency for s in specs])
    slice_ = services[:24]
    for s in slice_:
        s.class_id = classify(s)
    decisions = drive_slot(policy, slice_, view, 0)
    tiers_chosen = [specs[d.server].tier_freq(d.alloc.freq_tier)
                    for d in decisions]
    print("allocations: " + " ".join(
        f"s{d.server}@f{f:.2f}"
        for d, f in zip(decisions[:8], tiers_chosen[:8],
                        strict=True)) + " ...")
    for svc, d in zip(slice_, decisions, strict=True):
        engines[d.server].set_freq_scale(
            specs[d.server].tier_freq(d.alloc.freq_tier))
        engines[d.server].submit([1 + svc.sid % 40, 2, 3, 4],
                                 max_new_tokens=4)
    done = sum(len(e.run_until_idle()) for e in engines)
    print(f"executed {done}/{len(slice_)} requests on real engines "
          f"(edge0={len(engines[0].completed)}, "
          f"edge1={len(engines[1].completed)}, "
          f"cloud={len(engines[2].completed)})")


if __name__ == "__main__":
    main()
