"""The paper's scenario end-to-end: PerLLM scheduling over real engines.

Edge servers run a small model, the cloud runs a larger one (both reduced
for CPU). Service requests flow through the CS-UCB scheduler; chosen servers
execute real JAX prefill/decode via the continuous-batching engine, and the
cluster simulator accounts time/energy. Compares PerLLM against FineInfer.

    PYTHONPATH=src python examples/perllm_serving.py
"""
import copy

import jax

from repro.cluster import (
    BandwidthModel, Simulator, generate_workload, paper_testbed,
)
from repro.configs import get_config
from repro.core import ClusterView, drive_slot, make_policy
from repro.models import init_params
from repro.serving import ServingEngine


def main():
    # --- real execution engines (reduced models; CPU) -------------------
    key = jax.random.key(0)
    edge_cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=64,
                                              vocab_size=256)
    cloud_cfg = get_config("gemma3-12b").reduced(n_layers=2, d_model=128,
                                                 vocab_size=256)
    specs = paper_testbed("llama2-7b", n_edge=2)
    engines = [ServingEngine(edge_cfg, init_params(key, edge_cfg),
                             max_batch=2, max_seq=64) for _ in range(2)]
    engines.append(ServingEngine(cloud_cfg, init_params(key, cloud_cfg),
                                 max_batch=4, max_seq=64))

    services = generate_workload(600, rate=8.0, seed=0)

    for name in ("perllm", "fineinfer"):
        sim = Simulator(specs, BandwidthModel(False, seed=1), seed=42)
        res = sim.run([copy.copy(s) for s in services],
                      make_policy(name, len(specs)))
        print(res.row())

    # --- the same cluster, event-driven: per-arrival views, feedback at
    # true completion time, plus a bursty workload with a mid-run cloud
    # bandwidth drop (Scenario hooks on the shared event loop) -----------
    bursty = generate_workload(600, rate=8.0, seed=0, scenario="burst")
    sim = Simulator(specs, BandwidthModel(False, seed=1), slot=None, seed=42)
    res = sim.run([copy.copy(s) for s in bursty],
                  make_policy("perllm", len(specs)), scenario="bwdrop")
    print("event-driven burst+bwdrop:", res.row())

    # --- drive a slice of real tokens through the chosen engines --------
    policy = make_policy("perllm", len(specs))
    from repro.cluster.workload import classify
    view = ClusterView(t=0.0, specs=specs, bw_factor=[1.0] * len(specs),
                       uplink_free_at=[0.0] * len(specs),
                       lane_free=[[0.0] * s.max_concurrency for s in specs])
    slice_ = services[:24]
    for s in slice_:
        s.class_id = classify(s)
    decisions = drive_slot(policy, slice_, view, 0)
    for svc, d in zip(slice_, decisions):
        engines[d.server].submit([1 + svc.sid % 40, 2, 3, 4],
                                 max_new_tokens=4)
    done = sum(len(e.run_until_idle()) for e in engines)
    print(f"executed {done}/{len(slice_)} requests on real engines "
          f"(edge0={len(engines[0].completed)}, "
          f"edge1={len(engines[1].completed)}, "
          f"cloud={len(engines[2].completed)})")


if __name__ == "__main__":
    main()
