"""Quickstart: train a tiny model, then serve it with continuous batching.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs import get_config
from repro.serving import ServingEngine
from repro.training import AdamWConfig, train


def main():
    # 1. pick an architecture from the zoo and shrink it for CPU
    cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=128,
                                         vocab_size=512)
    print(f"arch={cfg.arch_id} params={cfg.param_count()/1e6:.1f}M")

    # 2. train briefly on the synthetic bigram stream
    params, _, hist = train(
        cfg, steps=30, batch_size=4, seq_len=64, log_every=10,
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30))
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # 3. serve it
    engine = ServingEngine(cfg, params, max_batch=4, max_seq=128,
                           temperature=0.0)
    reqs = [engine.submit(list(range(10 + i, 18 + i)), max_new_tokens=8)
            for i in range(6)]
    engine.run_until_idle()
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt={r.prompt[:4]}... -> {r.generated}")


if __name__ == "__main__":
    main()
