"""Quickstart: train a tiny model, serve it with continuous batching, and
schedule requests onto a DVFS-tiered edge-cloud testbed.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs import get_config
from repro.serving import ServingEngine
from repro.training import AdamWConfig, train


def main():
    # 1. pick an architecture from the zoo and shrink it for CPU
    cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=128,
                                         vocab_size=512)
    print(f"arch={cfg.arch_id} params={cfg.param_count()/1e6:.1f}M")

    # 2. train briefly on the synthetic bigram stream
    params, _, hist = train(
        cfg, steps=30, batch_size=4, seq_len=64, log_every=10,
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30))
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # 3. serve it
    engine = ServingEngine(cfg, params, max_batch=4, max_seq=128,
                           temperature=0.0)
    reqs = [engine.submit(list(range(10 + i, 18 + i)), max_new_tokens=8)
            for i in range(6)]
    engine.run_until_idle()
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt={r.prompt[:4]}... -> {r.generated}")

    # 4. schedule a workload: every Decision names a server AND a resource
    #    Allocation — here PerLLM learns which DVFS tier each service class
    #    can afford (a slow tier that still meets the deadline is cheaper)
    import copy

    from repro.cluster import (
        DVFS_TIERS, Simulator, generate_workload, paper_testbed,
    )
    from repro.core import make_policy

    specs = paper_testbed("llama2-7b", freq_tiers=DVFS_TIERS)
    services = generate_workload(800, rate=8.0, seed=0)
    for tiers, tag in ((False, "fixed-nominal"), (True, "learned-tiers")):
        sim = Simulator(specs, slot=None, seed=42)
        res = sim.run([copy.copy(s) for s in services],
                      make_policy("perllm", len(specs), tiers=tiers))
        print(f"{tag:14s} {res.row()}")


if __name__ == "__main__":
    main()
