"""Architecture zoo tour: one forward + one decode step per assigned arch.

    PYTHONPATH=src python examples/arch_zoo.py [--arch mixtral-8x7b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import (
    cpu_context, decode_step, dummy_batch, init_cache, init_params,
    prefill,
)


def tour(arch: str):
    cfg = get_config(arch).reduced()
    ctx = cpu_context(remat=False)
    key = jax.random.key(0)
    params = init_params(key, cfg)
    batch = dummy_batch(key, cfg, 2, 32, "prefill")
    t0 = time.time()
    cache = init_cache(cfg, 2, 64)
    last, cache = prefill(params, batch, cache, cfg=cfg, ctx=ctx)
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    extras = {"audio_frames": batch["audio_frames"]} if cfg.enc_dec else None
    logits, cache = decode_step(params, tok, cache, jnp.int32(32), cfg=cfg,
                                ctx=ctx, batch_extras=extras)
    dt = time.time() - t0
    full = get_config(arch)
    print(f"{arch:20s} [{cfg.family:6s}] full={full.param_count()/1e9:6.2f}B "
          f"reduced={cfg.param_count()/1e6:6.1f}M  prefill+decode {dt:5.2f}s "
          f"logits={tuple(logits.shape)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ASSIGNED_ARCHS))
    args = ap.parse_args()
    for arch in ([args.arch] if args.arch else ASSIGNED_ARCHS):
        tour(arch)


if __name__ == "__main__":
    main()
