"""Scale sweep: wall-clock per arrival of the event core at N up to 10⁶.

The tentpole claim behind the array-backed `_EventSimRuntime` is that
simulator throughput — not scheduling quality — was the bottleneck for
"millions of users" experiments. This sweep measures exactly that
surface, at a fixed operating point, for two schedulers:

* ``probe``  — a minimal O(n_servers) argmin over ``uplink_free_at``.
  Near-zero policy cost, so its µs/arrival is the *runtime core's* cost:
  event heap, ledger bookkeeping, view construction, booking. This is
  the number the CI scale gate holds.
* ``perllm`` — the full CS-UCB scheduler, whose per-arrival scan puts an
  upper bound on a realistic policy's cost on top of the same core
  (swept to 10⁵ only; its cost is policy-dominated and linear in N).

Operating point: ``paper_testbed(n_edge=40)`` (41 servers), Poisson
rate 100 req/s, workload seed 42 — heavy enough that uplink queues and
lane backlogs are real, calm enough that the success rate stays
meaningful (no queue meltdown).

Reported per sweep point: ``us_per_arrival`` (sim.run wall / N),
``wl_us_per_arrival`` (workload generation), ``peak_rss_mb`` (ru_maxrss
high-water mark — includes everything allocated so far this process),
and the success rate (a cheap trajectory checksum: any core change that
alters scheduling shows up here before anyone reads a profile).

CI usage (the `scale-gate` job; nightly raises --max-n to 1e5)::

    python -m benchmarks.scale --max-n 10000 --json BENCH_scale.json
    python benchmarks/compare_baseline.py BENCH_scale.json \
        benchmarks/BENCH_scale.json

The committed baseline gates ``us_per_arrival`` with ``direction:
"lower"`` and a generous per-metric 25% tolerance (runner jitter), only
at the N every CI run reaches (10³, 10⁴) — nightly-only points are
reported, not gated.
"""
from __future__ import annotations

import argparse
import json
import resource
import sys
import time

from repro.cluster import Simulator, generate_workload, paper_testbed
from repro.core import Decision, make_policy

N_EDGE = 40
RATE = 100.0
WL_SEED = 42
PROBE_NS = (1_000, 10_000, 100_000, 1_000_000)
PERLLM_CAP = 100_000


class UplinkProbe:
    """Cheapest useful policy: route to the server whose uplink frees
    first. One O(n_servers) scalar scan per arrival, no learning — the
    measured µs/arrival is the runtime core, not the policy."""

    name = "uplink-probe"

    def assign(self, req, view):
        up = view.uplink_free_at
        best, best_v = 0, up[0]
        for j in range(1, len(up)):
            v = up[j]
            if v < best_v:
                best, best_v = j, v
        return Decision(server=best)

    def feedback(self, req, out):
        pass


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _make_policy(kind: str, n_servers: int):
    if kind == "probe":
        return UplinkProbe()
    return make_policy("perllm", n_servers)


def run_point(kind: str, n: int, specs) -> dict:
    t0 = time.perf_counter()
    services = generate_workload(n, rate=RATE, seed=WL_SEED)
    wl_s = time.perf_counter() - t0
    sim = Simulator(specs)
    policy = _make_policy(kind, len(specs))
    t0 = time.perf_counter()
    res = sim.run(services, policy)
    wall = time.perf_counter() - t0
    return {
        "wall_s": round(wall, 3),
        "metrics": {
            "us_per_arrival": wall / n * 1e6,
            "wl_us_per_arrival": wl_s / n * 1e6,
            "peak_rss_mb": _peak_rss_mb(),
            "success_rate": res.success_rate,
        },
    }


def trace_overhead_point(n: int, specs) -> dict:
    """Interleaved probe runs at the gated N, untraced vs traced
    (alternating pairs so clock drift hits both sides equally; min of 7
    each after a warmup): ``trace_overhead_ratio`` is the recorder's
    hot-path cost on top of the event core, gated in CI at +10% over
    the untraced ``us_per_arrival``."""
    from repro.obs import TraceRecorder

    def one(trace):
        services = generate_workload(n, rate=RATE, seed=WL_SEED)
        sim = Simulator(specs)
        policy = _make_policy("probe", len(specs))
        t0 = time.perf_counter()
        sim.run(services, policy, trace=trace)
        return time.perf_counter() - t0

    one(None)                                   # warmup
    base_walls, traced_walls, rows, dropped = [], [], 0, 0
    for _ in range(7):
        base_walls.append(one(None))
        rec = TraceRecorder()
        traced_walls.append(one(rec))
        rows, dropped = len(rec), rec.dropped
    base, traced = min(base_walls), min(traced_walls)
    return {
        "wall_s": round(traced, 3),
        "metrics": {
            "trace_overhead_ratio": traced / base,
            "us_per_arrival": base / n * 1e6,
            "traced_us_per_arrival": traced / n * 1e6,
            "trace_rows": rows,
            "trace_rows_dropped": dropped,
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Event-core scale sweep (us/arrival + peak RSS).")
    ap.add_argument("--max-n", type=int, default=1_000_000,
                    help="largest probe sweep point (default 1e6; the "
                         "perllm sweep is additionally capped at 1e5)")
    ap.add_argument("--policies", default="probe,perllm",
                    help="comma-separated subset of probe,perllm")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results as compare_baseline-schema JSON "
                         "(the CI scale-gate artifact)")
    ap.add_argument("--trace-overhead-n", type=int, default=10_000,
                    metavar="N",
                    help="N for the traced-vs-untraced probe overhead "
                         "point (0 disables; skipped when N > --max-n)")
    args = ap.parse_args(argv)
    kinds = [k for k in args.policies.split(",") if k]
    bad = [k for k in kinds if k not in ("probe", "perllm")]
    if bad:
        sys.exit(f"unknown policy kind(s) {bad}; choose from probe,perllm")

    specs = paper_testbed(n_edge=N_EDGE)
    out = {}
    print(f"# testbed: {len(specs)} servers (n_edge={N_EDGE}), "
          f"rate={RATE:g} req/s, workload seed {WL_SEED}")
    print(f"# {'experiment':24s} {'us/arr':>8s} {'wl us/arr':>9s} "
          f"{'wall s':>8s} {'rss MB':>7s} {'success':>8s}")
    for kind in kinds:
        cap = args.max_n if kind == "probe" else min(args.max_n, PERLLM_CAP)
        for n in PROBE_NS:
            if n > cap:
                break
            point = run_point(kind, n, specs)
            name = f"scale_{kind}_n{n}"
            out[name] = point
            m = point["metrics"]
            print(f"  {name:24s} {m['us_per_arrival']:8.1f} "
                  f"{m['wl_us_per_arrival']:9.2f} {point['wall_s']:8.2f} "
                  f"{m['peak_rss_mb']:7.0f} {m['success_rate']:8.4f}")
    n_tr = args.trace_overhead_n
    if "probe" in kinds and 0 < n_tr <= args.max_n:
        point = trace_overhead_point(n_tr, specs)
        name = f"scale_probe_traced_n{n_tr}"
        out[name] = point
        m = point["metrics"]
        print(f"  {name:24s} traced {m['traced_us_per_arrival']:.1f} "
              f"vs {m['us_per_arrival']:.1f} us/arr -> overhead ratio "
              f"{m['trace_overhead_ratio']:.3f} "
              f"({m['trace_rows']} rows, {m['trace_rows_dropped']} "
              f"dropped)")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
