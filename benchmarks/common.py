"""Shared benchmark infrastructure.

All Table-1/Fig-4/Fig-6 numbers come from the same simulation matrix
(4 edge-model deployments × {stable, fluctuating} × 4 schedulers), computed
once and cached; Fig 5 runs its own saturation sweep. `BENCH_N` scales the
workload (default 6000 services; the paper uses 10000 — set BENCH_N=10000
for the full run).

Every cell runs the event-driven simulator (the historical slotted mode
was retired; all baselines are event-driven numbers).

Scenario plumbing (also settable via `python -m benchmarks.run
--scenario`):

* `BENCH_SCENARIO` — a registered scenario name (`burst`, `diurnal`,
  `bwdrop`, `overload`, `cloud-outage`, ...) shaping the matrix's arrival
  process and injecting its bandwidth events into every simulation cell.
* `BENCH_ADMISSION` — any non-empty value other than `0` gives PerLLM
  admission control (`Decision.admit`): infeasible requests are shed with
  an SLO-violation cost instead of queueing; results report the
  admitted-request SLO rate alongside overall success.
* `BENCH_TOPOLOGY` — `degenerate` (default, the legacy one-private-link
  per server) or `edge-cloud` (per-link graph: private edge access links,
  cloud reached over user-cloud + the shared edge-cloud backhaul, each
  link on an independent fluctuation substream).
* `BENCH_TIERS` — any non-empty value other than `0` gives every server
  the stock DVFS ladder (`repro.cluster.server.DVFS_TIERS`): PerLLM's
  arm space expands to (class, server, tier) and its Decisions carry
  non-nominal Allocations; the baselines stay allocation-blind. Off by
  default — the untier'd testbed is bit-exact with the pre-allocation
  cost model.
"""
from __future__ import annotations

import copy
import functools
import os
import time
from typing import Dict, Tuple

from repro.cluster import (
    BandwidthModel, DVFS_TIERS, SimResult, Simulator, generate_workload,
    make_topology, paper_testbed,
)
from repro.core import make_policy

EDGE_MODELS = ("yi-6b", "llama2-7b", "llama3-8b", "yi-9b")
METHODS = ("PerLLM", "FineInfer", "AGOD", "RewardlessGuidance")
BENCH_N = int(os.environ.get("BENCH_N", "6000"))
SCENARIO = os.environ.get("BENCH_SCENARIO") or None
ADMISSION = os.environ.get("BENCH_ADMISSION", "") not in ("", "0")
TOPOLOGY = os.environ.get("BENCH_TOPOLOGY", "degenerate")
TIERS = os.environ.get("BENCH_TIERS", "") not in ("", "0")
if os.environ.get("BENCH_RUNTIME", "event") != "event":
    raise SystemExit(
        f"BENCH_RUNTIME={os.environ['BENCH_RUNTIME']!r}: the slotted "
        "runtime was retired — every benchmark runs event-driven now; "
        "unset BENCH_RUNTIME")
SIM_SEED = 42
BW_SEED = 1


def make_scheduler(name: str, n_servers: int, tiers: bool = True):
    """All benchmark schedulers come from the policy registry. With
    BENCH_ADMISSION set, PerLLM runs with admission control (the paper
    baselines have no shedding mechanism and always admit); `tiers=False`
    pins PerLLM to the nominal DVFS tier (the fixed-frequency comparator
    — only meaningful when BENCH_TIERS puts a ladder on the testbed)."""
    kwargs = {}
    if name.lower() == "perllm":
        if ADMISSION:
            kwargs["admission"] = True
        if not tiers:
            kwargs["tiers"] = False
    return make_policy(name, n_servers, **kwargs)


def bench_testbed(edge_model: str):
    """The simulation matrix's testbed under the current BENCH_* knobs."""
    return paper_testbed(edge_model,
                         freq_tiers=DVFS_TIERS if TIERS else (1.0,))


@functools.lru_cache(maxsize=None)
def run_cell(edge_model: str, fluctuating: bool, method: str,
             n: int = BENCH_N,
             scenario: str = None,
             tiers: bool = True) -> Tuple[SimResult, float]:
    """One (deployment × bandwidth × scheduler) simulation. Returns
    (result, wall_seconds). `scenario=None` resolves the module-level
    SCENARIO at call time (benchmarks.run may rebind it after import;
    ADMISSION/TOPOLOGY/TIERS are module-level reads for the same
    reason). `tiers=False` runs PerLLM pinned to the nominal tier."""
    if scenario is None:
        scenario = SCENARIO
    specs = bench_testbed(edge_model)
    services = generate_workload(n, seed=0, scenario=scenario)
    topology = None
    if TOPOLOGY != "degenerate":
        topology = make_topology(TOPOLOGY, specs, fluctuating=fluctuating,
                                 seed=BW_SEED)
    sim = Simulator(specs, BandwidthModel(fluctuating=fluctuating,
                                          seed=BW_SEED), seed=SIM_SEED,
                    topology=topology)
    sched = make_scheduler(method, len(specs), tiers=tiers)
    t0 = time.time()
    res = sim.run([copy.copy(s) for s in services], sched,
                  scenario=scenario)
    return res, time.time() - t0


def matrix(fluctuating: bool) -> Dict[str, Dict[str, SimResult]]:
    out = {}
    for em in EDGE_MODELS:
        out[em] = {m: run_cell(em, fluctuating, m)[0] for m in METHODS}
    return out


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
