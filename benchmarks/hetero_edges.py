"""Heterogeneous edge servers — the paper's stated limitation, addressed.

PerLLM §6: "the same equipment is used for multiple edge servers, and the
heterogeneous edges are not yet considered." The CS-UCB formulation needs
no change: heterogeneity is just more per-(class, server) structure for the
bandit to learn. We deploy five *different* edge tiers (mixed models and
speeds) and show PerLLM holds its success rate while the static edge-cloud
baseline degrades.
"""
from __future__ import annotations

import copy
import dataclasses
import time

from benchmarks.common import csv_row, make_scheduler
from repro.cluster import BandwidthModel, Simulator, generate_workload, paper_testbed

EDGE_MODELS = ("yi-6b", "llama2-7b", "llama3-8b", "yi-9b", "yi-6b")
SPEED = (1.0, 0.8, 1.2, 0.6, 1.5)          # heterogeneous capability


def hetero_testbed():
    specs = paper_testbed("llama2-7b")
    out = []
    for i, s in enumerate(specs[:-1]):
        out.append(dataclasses.replace(
            s, arch_id=EDGE_MODELS[i], flops=s.flops * SPEED[i],
            mem_bw=s.mem_bw * SPEED[i],
            max_concurrency=max(2, int(s.max_concurrency * SPEED[i]))))
    out.append(specs[-1])
    return out


def run(n: int = 3000) -> str:
    t0 = time.time()
    specs = hetero_testbed()
    services = generate_workload(n, seed=0)
    lines = ["# Heterogeneous edges (5 distinct tiers + cloud)",
             f"{'method':22s} {'succ':>7s} {'kJ':>8s} {'per-server served'}"]
    res = {}
    for m in ("PerLLM", "RewardlessGuidance", "AGOD"):
        sim = Simulator(specs, BandwidthModel(False, seed=1), seed=42)
        res[m] = sim.run([copy.copy(s) for s in services],
                         make_scheduler(m, len(specs)))
        r = res[m]
        lines.append(f"{m:22s} {r.success_rate*100:6.1f}% "
                     f"{r.total_energy/1e3:8.1f} {r.per_server_served}")
    print("\n".join(lines))
    per = res["PerLLM"]
    return csv_row("hetero_edges", (time.time() - t0) * 1e6,
                   f"hetero_succ={per.success_rate*100:.1f}%")
