"""KV-pressure: memory-bounded serving with the paged KV-cache subsystem.

Long-context services (the `kv-pressure` scenario: 4× prompts, token-cheap
payloads, 1.5× arrival rate) on a testbed whose `ServerSpec`s model a
paged block pool — KV memory, not bandwidth, is the binding resource.
Compares always-admit PerLLM against PerLLM with admission + KV-aware
preemption: admission sheds requests the pool can't hold (C5 slack), and
preemption's KV-resume path means a same-server requeue skips re-prefill
(`kv_prefill_tokens_saved`).

A second section runs the `shared-prefix` scenario (Zipf-reused system
prompts) on the same pressured testbed twice — once with the pool
identities stripped (`no-share`) and once intact (`share`) — so the
prefix-sharing subsystem's relief is request-for-request comparable:
resident prefixes shrink admissions' unique KV footprint and skip their
prefill, and preempted requests may ship their pages cross-server
(`Decision.migrate_kv`) instead of abandoning them.

Derived metrics (gated by the CI regression gate, see
`benchmarks/compare_baseline.py`): `kv_adm_success` — admitted-request
SLO rate with the KV-aware policy; `kv_evictions` — preemptions that
touched KV pages (mechanism liveness); `kv_prefill_saved` — prompt tokens
of prefill skipped via page resume; `prefix_hits` / `prefix_saved` —
shared-prefix admissions served off resident pages and the prefill tokens
they skipped; `prefix_adm_success` vs `noshare_adm_success` — the
admitted-SLO win sharing buys on the identical workload; `kv_migrated` —
cross-server page transfers (named to dodge the gate's ``*ratio*``
exclusion, which "migrations" trips; orphaned pages are reported, not
gated: fewer is better).
"""
from __future__ import annotations

import copy
import time

from benchmarks.common import BENCH_N, csv_row
from repro.cluster import Simulator, generate_workload, paper_testbed
from repro.core import make_policy

# Edge pools sized so a handful of long-context requests exhaust memory
# while lanes idle (8 lanes/edge; ~13 blocks per shaped request at 64
# tokens/block); the cloud gets 4× the edge pool.
EDGE_KV_BLOCKS = 64
KV_BLOCK_TOKENS = 64


def run(edge_model: str = "llama2-7b") -> str:
    t0 = time.time()
    specs = paper_testbed(edge_model, kv_blocks=EDGE_KV_BLOCKS,
                          kv_block_tokens=KV_BLOCK_TOKENS)
    services = generate_workload(BENCH_N, seed=0, scenario="kv-pressure")
    lines = [f"# KV pressure ({edge_model}): "
             f"{EDGE_KV_BLOCKS} edge blocks × {KV_BLOCK_TOKENS} tok, "
             f"n={BENCH_N}"]
    results = {}
    for label, kwargs in (
            ("always-admit", {}),
            ("kv-preempt", dict(preempt=True)),
            ("admit+preempt", dict(admission=True, preempt=True))):
        sim = Simulator(specs, slot=None, seed=42)
        res = sim.run([copy.copy(s) for s in services],
                      make_policy("perllm", len(specs), **kwargs))
        results[label] = res
        lines.append(
            f"{label:14s} succ={res.success_rate * 100:5.1f}% "
            f"adm_succ={res.admitted_success_rate * 100:5.1f}% "
            f"rej={res.n_rejected} pre={res.n_preempted} "
            f"kv_evict={res.n_kv_evictions} "
            f"kv_saved={res.kv_prefill_tokens_saved} tok")
    print("\n".join(lines))
    # --- shared-prefix: sharing + migration on the pressured pool -----
    lines = [f"# shared-prefix ({edge_model}): same pools, n={BENCH_N}"]
    shared_cells = {}
    for label, strip in (("no-share", True), ("share", False)):
        services = generate_workload(BENCH_N, seed=0,
                                     scenario="shared-prefix")
        if strip:
            for r in services:
                r.prefix_id, r.prefix_tokens = -1, 0
        sim = Simulator(specs, slot=None, seed=42)
        res = sim.run(services, make_policy("perllm", len(specs),
                                            admission=True, preempt=True))
        shared_cells[label] = res
        lines.append(
            f"{label:14s} succ={res.success_rate * 100:5.1f}% "
            f"adm_succ={res.admitted_success_rate * 100:5.1f}% "
            f"rej={res.n_rejected} hits={res.n_prefix_hits} "
            f"saved={res.kv_prefill_tokens_saved} tok "
            f"mig={res.n_kv_migrations} orph={res.n_kv_orphaned}")
    print("\n".join(lines))
    # the preempt-only cell exercises KV-preserving eviction + affinity
    # resume; the admission cell shows SLO protection off C5 slack; the
    # share/no-share pair isolates what prefix residency buys
    pre = results["kv-preempt"]
    aware = results["admit+preempt"]
    share = shared_cells["share"]
    noshare = shared_cells["no-share"]
    derived = (f"kv_adm_success={aware.admitted_success_rate * 100:.1f}%;"
               f"kv_preempt_success={pre.success_rate * 100:.1f}%;"
               f"kv_evictions={pre.n_kv_evictions};"
               f"kv_prefill_saved={pre.kv_prefill_tokens_saved};"
               f"kv_rejected={aware.n_rejected};"
               f"prefix_hits={share.n_prefix_hits};"
               f"prefix_saved={share.kv_prefill_tokens_saved};"
               f"prefix_adm_success={share.admitted_success_rate * 100:.1f}%;"
               f"noshare_adm_success="
               f"{noshare.admitted_success_rate * 100:.1f}%;"
               f"kv_migrated={share.n_kv_migrations};"
               f"kv_orphaned={share.n_kv_orphaned}")
    return csv_row("kv_pressure", (time.time() - t0) * 1e6, derived)
