"""Benchmark regression gate: compare a run's JSON against the baseline.

Usage (what CI runs after the smoke benchmarks)::

    python -m benchmarks.run table1_success_rate fig5_throughput \
        --json BENCH_smoke.json
    python benchmarks/compare_baseline.py BENCH_smoke.json \
        benchmarks/baseline.json

Gated metrics are the quality-style ones (names containing ``success``,
``thpt``/``throughput`` or ``goodput`` — higher is better; ``*ratio*``
names are excluded, since a PerLLM/baseline ratio shrinks when the
*baseline* improves) plus the paged-KV subsystem's liveness metrics
(``kv_evictions``, ``*saved*`` — the deterministic smoke run must keep
exercising KV-preserving preemption and banking resume savings); the job
fails if any falls more than ``--tolerance`` (default 5%) below the
committed baseline. Wall-clock (`us_per_call`) is reported but never gated: CI
runners are too noisy for latency gates. Regenerate the baseline with the
exact smoke-scale command above after an intentional behavior change.
"""
from __future__ import annotations

import argparse
import json
import sys

GATED_TAGS = ("success", "thpt", "throughput", "goodput", "kv_evictions",
              "saved")


def gated(metric_name: str) -> bool:
    name = metric_name.lower()
    # PerLLM-vs-baseline ratios are NOT gated: improving a baseline's
    # absolute goodput shrinks the ratio without any regression
    if "ratio" in name:
        return False
    return any(tag in name for tag in GATED_TAGS)


def compare(current: dict, baseline: dict, tolerance: float) -> list:
    """Failure messages for every gated metric below baseline×(1−tol)."""
    failures = []
    checked = 0
    for exp, info in sorted(baseline.items()):
        cur = current.get(exp)
        if cur is None:
            failures.append(f"{exp}: missing from current run")
            continue
        for key, base_val in sorted(info.get("metrics", {}).items()):
            if not gated(key):
                continue
            cur_val = cur.get("metrics", {}).get(key)
            if cur_val is None:
                failures.append(f"{exp}.{key}: metric missing "
                                f"(baseline {base_val:g})")
                continue
            checked += 1
            floor = base_val * (1.0 - tolerance)
            status = "ok" if cur_val >= floor else "REGRESSION"
            print(f"{status:10s} {exp}.{key}: {cur_val:g} "
                  f"(baseline {base_val:g}, floor {floor:g})")
            if cur_val < floor:
                failures.append(
                    f"{exp}.{key}: {cur_val:g} < floor {floor:g} "
                    f"({(1 - cur_val / base_val) * 100:.1f}% below "
                    f"baseline {base_val:g})")
    if checked == 0:
        failures.append("no gated metrics were compared — baseline or "
                        "current JSON is empty/malformed")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail if gated benchmark metrics regress vs baseline.")
    ap.add_argument("current", help="JSON written by benchmarks.run --json")
    ap.add_argument("baseline", help="committed benchmarks/baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="allowed fractional drop below baseline "
                         "(default 0.05)")
    args = ap.parse_args(argv)
    with open(args.current) as fh:
        current = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    failures = compare(current, baseline, args.tolerance)
    if failures:
        print("\nbenchmark regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbenchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
