"""Benchmark regression gate: compare a run's JSON against the baseline.

Usage (what CI runs after the smoke benchmarks)::

    python -m benchmarks.run table1_success_rate fig5_throughput \
        --json BENCH_smoke.json
    python benchmarks/compare_baseline.py BENCH_smoke.json \
        benchmarks/baseline.json

Several current files may be given (they are merged — the CI energy smoke
writes its own JSON next to the default smoke's)::

    python benchmarks/compare_baseline.py BENCH_smoke.json \
        BENCH_energy.json benchmarks/baseline.json

Gated metrics are the quality-style ones (names containing ``success``,
``thpt``/``throughput`` or ``goodput`` — higher is better; ``*ratio*``
names are excluded, since a PerLLM/baseline ratio shrinks when the
*baseline* improves), the paged-KV subsystem's liveness metrics
(``kv_evictions``, ``*saved*``, ``*prefix*``, ``*migrat*`` — the
deterministic smoke run must keep exercising KV-preserving preemption,
banking resume savings, and taking shared-prefix hits; migration counts
are gated so the cross-server path can't silently vanish), and the
allocation subsystem's efficiency metrics: ``admitted_success_rate``
(higher is better) and ``energy_per_token`` — the one *lower-is-better*
gate, failing when energy per served token rises more than ``--tolerance``
above the committed baseline. Wall-clock (`us_per_call`) is reported but
never gated: CI runners are too noisy for latency gates. Regenerate the
baseline with the exact smoke-scale commands above after an intentional
behavior change.
"""
from __future__ import annotations

import argparse
import json
import sys

GATED_TAGS = ("success", "thpt", "throughput", "goodput", "kv_evictions",
              "saved", "admitted_success", "energy_per_token", "prefix",
              "migrat")

# gated metrics where *smaller* is the good direction
LOWER_IS_BETTER_TAGS = ("energy_per_token",)


def gated(metric_name: str) -> bool:
    name = metric_name.lower()
    # PerLLM-vs-baseline ratios are NOT gated: improving a baseline's
    # absolute goodput shrinks the ratio without any regression
    if "ratio" in name:
        return False
    return any(tag in name for tag in GATED_TAGS)


def lower_is_better(metric_name: str) -> bool:
    name = metric_name.lower()
    return any(tag in name for tag in LOWER_IS_BETTER_TAGS)


def compare(current: dict, baseline: dict, tolerance: float) -> list:
    """Failure messages for every gated metric outside baseline±tol (below
    the floor for higher-is-better metrics, above the ceiling for
    lower-is-better ones)."""
    failures = []
    checked = 0
    for exp, info in sorted(baseline.items()):
        cur = current.get(exp)
        if cur is None:
            failures.append(f"{exp}: missing from current run")
            continue
        for key, base_val in sorted(info.get("metrics", {}).items()):
            if not gated(key):
                continue
            cur_val = cur.get("metrics", {}).get(key)
            if cur_val is None:
                failures.append(f"{exp}.{key}: metric missing "
                                f"(baseline {base_val:g})")
                continue
            checked += 1
            if lower_is_better(key):
                ceiling = base_val * (1.0 + tolerance)
                bad = cur_val > ceiling
                status = "ok" if not bad else "REGRESSION"
                print(f"{status:10s} {exp}.{key}: {cur_val:g} "
                      f"(baseline {base_val:g}, ceiling {ceiling:g})")
                if bad:
                    failures.append(
                        f"{exp}.{key}: {cur_val:g} > ceiling {ceiling:g} "
                        f"({(cur_val / base_val - 1) * 100:.1f}% above "
                        f"baseline {base_val:g})")
            else:
                floor = base_val * (1.0 - tolerance)
                bad = cur_val < floor
                status = "ok" if not bad else "REGRESSION"
                print(f"{status:10s} {exp}.{key}: {cur_val:g} "
                      f"(baseline {base_val:g}, floor {floor:g})")
                if bad:
                    failures.append(
                        f"{exp}.{key}: {cur_val:g} < floor {floor:g} "
                        f"({(1 - cur_val / base_val) * 100:.1f}% below "
                        f"baseline {base_val:g})")
    if checked == 0:
        failures.append("no gated metrics were compared — baseline or "
                        "current JSON is empty/malformed")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail if gated benchmark metrics regress vs baseline.")
    ap.add_argument("current", nargs="+",
                    help="JSON file(s) written by benchmarks.run --json "
                         "(merged when several are given)")
    ap.add_argument("baseline", help="committed benchmarks/baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="allowed fractional drift from baseline "
                         "(default 0.05)")
    args = ap.parse_args(argv)
    current: dict = {}
    for path in args.current:
        with open(path) as fh:
            current.update(json.load(fh))
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    failures = compare(current, baseline, args.tolerance)
    if failures:
        print("\nbenchmark regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbenchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
