"""Benchmark regression gate: compare a run's JSON against the baseline.

Usage (what CI runs after the smoke benchmarks)::

    python -m benchmarks.run table1_success_rate fig5_throughput \
        --json BENCH_smoke.json
    python benchmarks/compare_baseline.py BENCH_smoke.json \
        benchmarks/baseline.json

Several current files may be given (they are merged — the CI energy smoke
writes its own JSON next to the default smoke's)::

    python benchmarks/compare_baseline.py BENCH_smoke.json \
        BENCH_energy.json benchmarks/baseline.json

Gating is **explicit, per metric**: every entry in ``baseline.json``'s
``metrics`` maps the metric name to an object::

    {"value": 92.5, "gate": true}
    {"value": 0.31, "gate": true, "direction": "lower"}
    {"value": 1730.4, "gate": true, "direction": "lower",
     "tolerance": 0.25}

``gate: true`` metrics fail the build when the current value drifts more
than ``--tolerance`` below the baseline (or above it, for ``direction:
"lower"`` metrics like ``energy_per_token``). ``gate: false`` metrics
are recorded for context but never compared — e.g. PerLLM-vs-baseline
*ratios*, which shrink when the baseline improves without any
regression. Name-pattern heuristics are gone: a metric's gate status is
whatever its baseline entry says, no matter what it is called.

A per-metric ``tolerance`` overrides the global ``--tolerance`` for that
entry — timing metrics (``us_per_call``, ``us_per_arrival``) are gated
with a generous 25% so CI-runner jitter doesn't flake the build, while
correctness ratios stay on the tight default.

Regenerating the baseline after an intentional behavior change::

    python benchmarks/compare_baseline.py BENCH_smoke.json \
        BENCH_energy.json benchmarks/baseline.json \
        --emit-baseline benchmarks/baseline.json

which merges the run values into the baseline schema, preserving each
existing metric's ``gate``/``direction`` flags; metrics new to the
baseline default to ``gate: false`` (with a notice) so gating a new
metric is always a deliberate edit.
"""
from __future__ import annotations

import argparse
import json
import sys


def _entry(exp: str, key: str, raw) -> dict:
    """Validate one baseline metric entry (the explicit-gate schema)."""
    if not isinstance(raw, dict) or "value" not in raw or "gate" not in raw:
        raise SystemExit(
            f"baseline entry {exp}.{key} = {raw!r} is not in the explicit "
            f"gate schema: expected {{\"value\": <num>, \"gate\": "
            f"true/false}} (optionally \"direction\": \"lower\"); "
            f"regenerate with --emit-baseline")
    return raw


def compare(current: dict, baseline: dict, tolerance: float) -> list:
    """Failure messages for every gated metric outside baseline±tol
    (below the floor for higher-is-better metrics, above the ceiling for
    ``direction: "lower"`` ones). An entry's own ``tolerance`` key
    overrides the global one."""
    failures = []
    checked = 0
    for exp, info in sorted(baseline.items()):
        cur = current.get(exp)
        if cur is None:
            # an experiment with no gated metrics is reference context
            # (e.g. nightly-only sweep points) — its absence from a
            # smaller run is expected, not a regression
            if any(isinstance(raw, dict) and raw.get("gate")
                   for raw in info.get("metrics", {}).values()):
                failures.append(f"{exp}: missing from current run")
            continue
        for key, raw in sorted(info.get("metrics", {}).items()):
            entry = _entry(exp, key, raw)
            if not entry["gate"]:
                continue
            base_val = entry["value"]
            cur_val = cur.get("metrics", {}).get(key)
            if cur_val is None:
                failures.append(f"{exp}.{key}: metric missing "
                                f"(baseline {base_val:g})")
                continue
            checked += 1
            tol = float(entry.get("tolerance", tolerance))
            if entry.get("direction") == "lower":
                ceiling = base_val * (1.0 + tol)
                bad = cur_val > ceiling
                status = "ok" if not bad else "REGRESSION"
                print(f"{status:10s} {exp}.{key}: {cur_val:g} "
                      f"(baseline {base_val:g}, ceiling {ceiling:g})")
                if bad:
                    failures.append(
                        f"{exp}.{key}: {cur_val:g} > ceiling {ceiling:g} "
                        f"({(cur_val / base_val - 1) * 100:.1f}% above "
                        f"baseline {base_val:g})")
            else:
                floor = base_val * (1.0 - tol)
                bad = cur_val < floor
                status = "ok" if not bad else "REGRESSION"
                print(f"{status:10s} {exp}.{key}: {cur_val:g} "
                      f"(baseline {base_val:g}, floor {floor:g})")
                if bad:
                    failures.append(
                        f"{exp}.{key}: {cur_val:g} < floor {floor:g} "
                        f"({(1 - cur_val / base_val) * 100:.1f}% below "
                        f"baseline {base_val:g})")
    if checked == 0:
        failures.append("no gated metrics were compared — baseline or "
                        "current JSON is empty/malformed")
    return failures


def emit_baseline(current: dict, baseline: dict) -> dict:
    """Merge a run's values into the baseline schema, preserving each
    existing metric's gate/direction flags. Metrics (or experiments) the
    baseline has never seen default to ``gate: false`` and are listed so
    the author can promote them deliberately."""
    out: dict = {}
    new_metrics = []
    for exp, cur in sorted(current.items()):
        old = baseline.get(exp, {})
        old_metrics = old.get("metrics", {})
        metrics = {}
        for key, cur_val in sorted(cur.get("metrics", {}).items()):
            prev = old_metrics.get(key)
            entry = {"value": cur_val, "gate": False}
            if isinstance(prev, dict) and "gate" in prev:
                entry["gate"] = prev["gate"]
                if prev.get("direction") == "lower":
                    entry["direction"] = "lower"
                if "tolerance" in prev:
                    entry["tolerance"] = prev["tolerance"]
            else:
                new_metrics.append(f"{exp}.{key}")
            metrics[key] = entry
        out[exp] = {k: v for k, v in cur.items() if k != "metrics"}
        out[exp]["metrics"] = metrics
    for name in new_metrics:
        print(f"note: {name} is new — emitted with gate: false; edit the "
              f"baseline to gate it", file=sys.stderr)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail if gated benchmark metrics regress vs baseline.")
    ap.add_argument("current", nargs="+",
                    help="JSON file(s) written by benchmarks.run --json "
                         "(merged when several are given)")
    ap.add_argument("baseline", help="committed benchmarks/baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="allowed fractional drift from baseline "
                         "(default 0.05)")
    ap.add_argument("--emit-baseline", metavar="OUT", default=None,
                    help="instead of gating, write OUT in the baseline "
                         "schema: current values, existing gate flags "
                         "preserved, new metrics gate: false")
    args = ap.parse_args(argv)
    current: dict = {}
    for path in args.current:
        with open(path) as fh:
            current.update(json.load(fh))
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    if args.emit_baseline:
        merged = emit_baseline(current, baseline)
        with open(args.emit_baseline, "w") as fh:
            json.dump(merged, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.emit_baseline}")
        return 0
    failures = compare(current, baseline, args.tolerance)
    if failures:
        print("\nbenchmark regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbenchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
