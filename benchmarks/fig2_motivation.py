"""Figure 2 (motivation): single-server processing time & energy vs load.

Reproduces the observation that drove PerLLM: as concurrent services grow,
the cloud's processing time and energy surge (uplink congestion) while the
edge degrades gracefully (compute-bound, local link).
"""
from __future__ import annotations

import copy
import time

from benchmarks.common import csv_row
from repro.cluster import BandwidthModel, Simulator, generate_workload, paper_testbed
from repro.core import Decision, SchedulingPolicy


class _FixedTier(SchedulingPolicy):
    """All traffic to one tier: the cloud, or round-robin over the edges."""

    def __init__(self, servers, name):
        self.servers = list(servers)
        self.name = name
        self._i = 0

    def assign(self, req, view):
        j = self.servers[self._i % len(self.servers)]
        self._i += 1
        return Decision(server=j)


def run() -> str:
    t0 = time.time()
    specs = paper_testbed("llama2-7b")
    cloud = [len(specs) - 1]
    edges = list(range(len(specs) - 1))
    lines = ["# Fig 2: per-service time (s) and energy (J) vs concurrency",
             f"{'n_concurrent':>12s} {'cloud_t':>8s} {'edge_t':>8s} "
             f"{'cloud_J':>9s} {'edge_J':>9s}"]
    crossover = None
    for n in (10, 40, 80, 160, 320):
        # n services arriving within one second = n-way concurrency
        services = generate_workload(n, rate=float(n), seed=3)
        row = {}
        for servers, name in ((cloud, "cloud"), (edges, "edge")):
            sim = Simulator(specs, BandwidthModel(False, seed=1), seed=7)
            res = sim.run([copy.copy(s) for s in services],
                          _FixedTier(servers, name))
            row[name] = (res.avg_processing_time,
                         (res.e_tx + res.e_infer) / n)
        lines.append(f"{n:12d} {row['cloud'][0]:8.2f} {row['edge'][0]:8.2f} "
                     f"{row['cloud'][1]:9.1f} {row['edge'][1]:9.1f}")
        if crossover is None and row["cloud"][0] > row["edge"][0]:
            crossover = n
    print("\n".join(lines))
    return csv_row("fig2_motivation", (time.time() - t0) * 1e6,
                   f"cloud_slower_beyond_n={crossover}")
