"""Figure 4: average processing time per service under each method."""
from __future__ import annotations

import time

from benchmarks.common import EDGE_MODELS, METHODS, csv_row, matrix


def run() -> str:
    t0 = time.time()
    lines = []
    for fluct in (False, True):
        tag = "fluctuating" if fluct else "stable"
        m = matrix(fluct)
        lines.append(f"# Fig 4: avg processing time, s ({tag})")
        lines.append(f"{'model':12s} "
                     + " ".join(f"{x:>20s}" for x in METHODS))
        for em in EDGE_MODELS:
            lines.append(f"{em:12s} " + " ".join(
                f"{m[em][x].avg_processing_time:20.2f}" for x in METHODS))
    m = matrix(False)
    speedup = min(m[em]["FineInfer"].avg_processing_time
                  / m[em]["PerLLM"].avg_processing_time
                  for em in EDGE_MODELS)
    print("\n".join(lines))
    return csv_row("fig4_processing_time", (time.time() - t0) * 1e6,
                   f"min_time_speedup_vs_fineinfer={speedup:.2f}x")
