"""Table 1: average success rates for meeting processing-time requirements.

Paper: PerLLM ≥ 97–99%; baselines 58–77%.
"""
from __future__ import annotations

import time

from benchmarks import common
from benchmarks.common import EDGE_MODELS, METHODS, csv_row, matrix


def run() -> str:
    t0 = time.time()
    lines = []
    for fluct in (False, True):
        tag = "fluctuating" if fluct else "stable"
        m = matrix(fluct)
        lines.append(f"# Table 1 ({tag} bandwidth)")
        header = f"{'model':12s} " + " ".join(f"{x:>20s}" for x in METHODS)
        lines.append(header)
        for em in EDGE_MODELS:
            row = f"{em:12s} " + " ".join(
                f"{m[em][x].success_rate*100:19.1f}%" for x in METHODS)
            lines.append(row)
        if common.ADMISSION:
            # under admission control the SLO story splits: overall success
            # still counts every shed request as a miss; admitted success
            # is the rate among requests the system accepted
            for em in EDGE_MODELS:
                r = m[em]["PerLLM"]
                lines.append(
                    f"{em:12s} PerLLM admitted-SLO "
                    f"{r.admitted_success_rate*100:5.1f}% "
                    f"(rejected {r.n_rejected}/{r.n_services})")
    per_min = min(matrix(False)[em]["PerLLM"].success_rate
                  for em in EDGE_MODELS)
    wall = (time.time() - t0) * 1e6
    derived = f"perllm_min_success={per_min*100:.1f}%"
    if common.ADMISSION:
        adm_min = min(matrix(False)[em]["PerLLM"].admitted_success_rate
                      for em in EDGE_MODELS)
        derived += f";perllm_min_admitted_success={adm_min*100:.1f}%"
    print("\n".join(lines))
    return csv_row("table1_success_rate", wall, derived)
