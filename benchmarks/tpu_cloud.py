"""Hardware adaptation: v5e-pod-slice cloud instead of the A100 cloud.

The TPU-native reinterpretation (DESIGN.md §3): the "cloud server" cost
model comes from this repo's own roofline constants (197 TF/s bf16,
819 GB/s HBM per chip, 4-chip slice serving gemma3-27b). Shows the PerLLM
scheduler is calibration-agnostic: it re-learns the new cost surface and
keeps its claims.
"""
from __future__ import annotations

import copy
import time

from benchmarks.common import csv_row, make_scheduler
from repro.cluster import BandwidthModel, Simulator, generate_workload, tpu_testbed

METHODS = ("PerLLM", "FineInfer", "RewardlessGuidance")


def run(n: int = 3000) -> str:
    t0 = time.time()
    specs = tpu_testbed(edge_arch="gemma-2b", cloud_arch="gemma3-27b",
                        cloud_chips=4)
    services = generate_workload(n, seed=0)
    lines = ["# TPU v5e cloud variant (edge=gemma-2b int8, cloud=gemma3-27b"
             " on a 4-chip slice)",
             f"{'method':22s} {'succ':>7s} {'kJ':>8s} {'tok/s':>9s}"]
    res = {}
    for m in METHODS:
        sim = Simulator(specs, BandwidthModel(False, seed=1), seed=42)
        res[m] = sim.run([copy.copy(s) for s in services],
                         make_scheduler(m, len(specs)))
        r = res[m]
        lines.append(f"{m:22s} {r.success_rate*100:6.1f}% "
                     f"{r.total_energy/1e3:8.1f} "
                     f"{r.throughput_tokens_per_s:9.1f}")
    print("\n".join(lines))
    per = res["PerLLM"]
    return csv_row("tpu_cloud", (time.time() - t0) * 1e6,
                   f"tpu_variant_succ={per.success_rate*100:.1f}%")
