"""Benchmark driver — one experiment per paper table/figure.

Prints each experiment's human-readable table, then a final CSV block:
``name,us_per_call,derived``.

  BENCH_N=10000 PYTHONPATH=src python -m benchmarks.run        # paper scale
  PYTHONPATH=src python -m benchmarks.run                      # default 6000
  BENCH_N=200 python -m benchmarks.run table1_success_rate     # smoke subset

Scenario shaping (the event-driven runtime's `Scenario` hooks):

  python -m benchmarks.run table1_success_rate --scenario burst
  python -m benchmarks.run fig4_processing_time --scenario bwdrop

`--scenario` picks a registered arrival/bandwidth scenario (burst, diurnal,
bwdrop, overload, cloud-outage, trace, poisson) for the shared simulation
matrix; `--admission` gives PerLLM admission control; `--topology
edge-cloud` swaps the per-server bandwidth model for the explicit link
graph. Equivalent env vars: BENCH_SCENARIO / BENCH_ADMISSION /
BENCH_TOPOLOGY. (Every cell is event-driven; the slotted runtime and its
`--runtime` flag were retired.)

`--json PATH` additionally writes the run's derived metrics as JSON —
the artifact the CI regression gate feeds to
`benchmarks/compare_baseline.py`.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import traceback


def _parse_derived(derived: str) -> dict:
    """`k=v;k2=v2` pairs -> numeric metrics (%/x suffixes stripped)."""
    metrics = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        key, val = part.split("=", 1)
        with contextlib.suppress(ValueError):
            metrics[key.strip()] = float(val.strip().rstrip("%x"))
    return metrics


def write_json(rows, path: str) -> None:
    """Dump each experiment's wall time + parsed derived metrics.
    `us_per_call` rides inside `metrics` too, so the baseline gate can
    hold the line on simulator wall-clock like any other metric."""
    out = {}
    for r in rows:
        name, us, derived = r.split(",", 2)
        metrics = _parse_derived(derived)
        metrics["us_per_call"] = float(us)
        out[name] = {"us_per_call": float(us), "derived": derived,
                     "metrics": metrics}
    with open(path, "w") as fh:
        json.dump(out, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {path}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="Run the paper-reproduction benchmark suite.")
    ap.add_argument("experiments", nargs="*",
                    help="subset of experiments to run (default: all)")
    ap.add_argument("--scenario", default=None, metavar="NAME",
                    help="arrival/bandwidth scenario for the simulation "
                         "matrix: burst, diurnal, bwdrop, overload, "
                         "cloud-outage, trace, poisson "
                         "(default: stationary poisson)")
    ap.add_argument("--admission", action="store_true",
                    help="run PerLLM with admission control: infeasible "
                         "requests are shed (SLO-violation cost) instead "
                         "of queueing forever")
    ap.add_argument("--topology", default=None,
                    choices=("degenerate", "edge-cloud"),
                    help="network model for the simulation matrix: the "
                         "legacy per-server links (default) or the "
                         "explicit edge-cloud link graph")
    ap.add_argument("--tiers", action="store_true",
                    help="give every server the stock DVFS frequency "
                         "ladder: PerLLM schedules (server, tier) pairs "
                         "and fig6 reports the learned-tier energy cut "
                         "vs the fixed-nominal comparator")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write derived metrics as JSON (the CI "
                         "regression-gate artifact)")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:])
    # benchmarks.common reads these at import time, so set them before the
    # experiment imports below
    if args.scenario:
        from repro.core import available_scenarios, make_scenario
        try:
            make_scenario(args.scenario)
        except KeyError:
            sys.exit(f"unknown scenario {args.scenario!r}; choose from "
                     + ", ".join(available_scenarios()))
        except TypeError:
            sys.exit(f"scenario {args.scenario!r} needs constructor "
                     "arguments (e.g. trace times) — use it "
                     "programmatically via repro.core.make_scenario")
        os.environ["BENCH_SCENARIO"] = args.scenario
    if args.admission:
        os.environ["BENCH_ADMISSION"] = "1"
    if args.topology:
        os.environ["BENCH_TOPOLOGY"] = args.topology
    if args.tiers:
        os.environ["BENCH_TIERS"] = "1"
    rebind = (args.scenario or args.admission
              or args.topology or args.tiers)
    if rebind and "benchmarks.common" in sys.modules:
        # already imported (programmatic/repeat use): env vars were read at
        # import time, so rebind and drop the stale cell cache
        common = sys.modules["benchmarks.common"]
        if args.scenario:
            common.SCENARIO = args.scenario
        if args.admission:
            common.ADMISSION = True
        if args.topology:
            common.TOPOLOGY = args.topology
        if args.tiers:
            common.TIERS = True
        common.run_cell.cache_clear()

    from benchmarks import (
        ablation_csucb, fig2_motivation, fig4_processing_time,
        fig5_throughput, fig6_energy, hetero_edges, kv_pressure,
        regret_bound, roofline, table1_success_rate, tpu_cloud,
    )
    experiments = [
        ("fig2_motivation", fig2_motivation.run),
        ("table1_success_rate", table1_success_rate.run),
        ("fig4_processing_time", fig4_processing_time.run),
        ("fig5_throughput", fig5_throughput.run),
        ("fig6_energy", fig6_energy.run),
        ("regret_bound", regret_bound.run),
        ("ablation_csucb", ablation_csucb.run),
        ("kv_pressure", kv_pressure.run),
        ("tpu_cloud", tpu_cloud.run),
        ("hetero_edges", hetero_edges.run),
        ("roofline", roofline.run),
    ]
    selected = args.experiments
    if selected:
        known = {name for name, _ in experiments}
        unknown = [s for s in selected if s not in known]
        if unknown:
            sys.exit(f"unknown experiment(s) {unknown}; "
                     f"choose from {sorted(known)}")
        experiments = [(n, f) for n, f in experiments if n in selected]
    rows = []
    for name, fn in experiments:
        print(f"\n===== {name} =====")
        try:
            rows.append(fn())
        except Exception:  # noqa: BLE001 — keep the suite running
            traceback.print_exc()
            rows.append(f"{name},0.0,ERROR")
    print("\n# name,us_per_call,derived")
    for r in rows:
        print(r)
    json_path = args.json or os.environ.get("BENCH_JSON")
    if json_path:
        write_json(rows, json_path)
    if any(r.endswith("ERROR") for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
