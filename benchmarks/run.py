"""Benchmark driver — one experiment per paper table/figure.

Prints each experiment's human-readable table, then a final CSV block:
``name,us_per_call,derived``.

  BENCH_N=10000 PYTHONPATH=src python -m benchmarks.run        # paper scale
  PYTHONPATH=src python -m benchmarks.run                      # default 6000
  BENCH_N=200 python -m benchmarks.run table1_success_rate     # smoke subset
"""
from __future__ import annotations

import sys
import traceback


def main(argv=None) -> None:
    from benchmarks import (
        ablation_csucb, fig2_motivation, fig4_processing_time,
        fig5_throughput, fig6_energy, hetero_edges, regret_bound, roofline,
        table1_success_rate, tpu_cloud,
    )
    experiments = [
        ("fig2_motivation", fig2_motivation.run),
        ("table1_success_rate", table1_success_rate.run),
        ("fig4_processing_time", fig4_processing_time.run),
        ("fig5_throughput", fig5_throughput.run),
        ("fig6_energy", fig6_energy.run),
        ("regret_bound", regret_bound.run),
        ("ablation_csucb", ablation_csucb.run),
        ("tpu_cloud", tpu_cloud.run),
        ("hetero_edges", hetero_edges.run),
        ("roofline", roofline.run),
    ]
    selected = list(argv if argv is not None else sys.argv[1:])
    if selected:
        known = {name for name, _ in experiments}
        unknown = [s for s in selected if s not in known]
        if unknown:
            sys.exit(f"unknown experiment(s) {unknown}; "
                     f"choose from {sorted(known)}")
        experiments = [(n, f) for n, f in experiments if n in selected]
    rows = []
    for name, fn in experiments:
        print(f"\n===== {name} =====")
        try:
            rows.append(fn())
        except Exception:  # noqa: BLE001 — keep the suite running
            traceback.print_exc()
            rows.append(f"{name},0.0,ERROR")
    print("\n# name,us_per_call,derived")
    for r in rows:
        print(r)
    if any(r.endswith("ERROR") for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
