"""Ablation: CS-UCB components (λ constraint shaping, δ exploration, θ penalty).

Validates the paper's design: removing the constraint-satisfaction term
(λ=0), the exploration bonus (δ=0) or the violation penalty (θ=0) each
degrades deadline success and/or energy.
"""
from __future__ import annotations

import copy
import time

from benchmarks.common import csv_row
from repro.cluster import BandwidthModel, Simulator, generate_workload, paper_testbed
from repro.core import Decision, PerLLMScheduler
from repro.core.bandit import CSUCBParams
from repro.core.constraints import evaluate_constraints


class _NoFilter(PerLLMScheduler):
    """Pure UCB without the constraint-satisfaction mechanism (Eq. 3)."""

    def assign(self, req, view):
        import numpy as np
        feasible = np.ones(self.n_servers, bool)        # filter disabled
        j = self.bandit.select(req.class_id, feasible)
        slacks = evaluate_constraints(req, j, view)
        self._pending_slacks[req.sid] = slacks
        self._nominal_pred[req.sid] = \
            self.predicted_time(req, j, view) / self.SAFETY
        self._last_nominal_infer[req.sid] = view.predict_infer(req, j)
        return Decision(server=j,
                        infer_scale=float(self.infer_ratio[req.class_id, j]),
                        slacks=slacks)


VARIANTS = [
    ("full CS-UCB", CSUCBParams()),
    ("λ=0 (no constraint shaping)", CSUCBParams(lam=0.0)),
    ("δ=0 (no exploration)", CSUCBParams(delta=0.0)),
    ("θ=0 (no violation penalty)", CSUCBParams(theta=0.0)),
    ("λ=4 (over-shaped)", CSUCBParams(lam=4.0)),
    ("no C1-C3 feasibility filter", None),   # _NoFilter
]


def run(n: int = 3000) -> str:
    t0 = time.time()
    specs = paper_testbed("llama2-7b")
    services = generate_workload(n, seed=0)
    lines = ["# CS-UCB ablation (success / energy / avg time)",
             f"{'variant':32s} {'succ':>7s} {'kJ':>8s} {'avg_s':>7s}"]
    base = None
    for name, params in VARIANTS:
        sim = Simulator(specs, BandwidthModel(False, seed=1), seed=42)
        sched = (_NoFilter(len(specs)) if name.startswith("no C1")
                 else PerLLMScheduler(len(specs), params=params))
        res = sim.run([copy.copy(s) for s in services], sched)
        lines.append(f"{name:32s} {res.success_rate*100:6.1f}% "
                     f"{res.total_energy/1e3:8.1f} "
                     f"{res.avg_processing_time:7.2f}")
        if base is None:
            base = res
    print("\n".join(lines))
    return csv_row("ablation_csucb", (time.time() - t0) * 1e6,
                   f"full_succ={base.success_rate*100:.1f}%")
