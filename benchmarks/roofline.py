"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape) on the single-pod 16×16 mesh:
  compute term    = FLOPs / (chips × 197 TF/s bf16)
  memory term     = bytes / (chips × 819 GB/s HBM)
  collective term = collective bytes / (chips × 50 GB/s ICI link)

FLOPs/bytes come from the loop-aware jaxpr cost model (global, ÷chips);
collective bytes come from the compiled per-device HLO. MODEL_FLOPS is
6·N_active·D for training and 2·N_active·D for prefill/decode — the
MODEL/HLO ratio flags dispatch/remat waste. The memory term uses *unfused*
bytes, an upper bound (XLA fusion reduces real HBM traffic).
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import csv_row
from repro.configs import INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12        # bf16 per v5e chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

SUGGEST = {
    "compute": ("drop non-useful FLOPs (capacity-based MoE dispatch, less "
                "remat, fused attention kernel)"),
    "memory": ("improve fusion/layout: Pallas flash kernels remove the "
               "unfused attention traffic; bigger microbatch raises "
               "arithmetic intensity"),
    "collective": ("re-shard to cut gathers: wider data axis, expert "
                   "parallelism for MoE, overlap collectives with compute"),
}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch    # decode: one token per sequence


def load_reports(path: str = "dryrun_single.jsonl"):
    if not os.path.exists(path):
        return []
    rows = {}
    with open(path) as fh:
        for line in fh:
            r = json.loads(line)
            if r.get("error") or r.get("skipped"):
                continue
            rows[(r["arch"], r["shape"])] = r   # keep latest per pair
    return list(rows.values())


def terms(r: dict) -> dict:
    chips = r["n_devices"]
    compute = r["global_flops"] / chips / PEAK_FLOPS
    memory = r["global_bytes_unfused"] / chips / HBM_BW
    collective = r["collective_bytes"]["total"] / LINK_BW  # already per-chip
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    mf = model_flops(r["arch"], r["shape"])
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / r["global_flops"] if r["global_flops"] else 0.0,
        "suggestion": SUGGEST[dominant],
    }


def run(path: str = "dryrun_single.jsonl") -> str:
    t0 = time.time()
    reports = load_reports(path)
    if not reports:
        print(f"# roofline: no dry-run artifacts at {path} — run "
              "`python -m repro.launch.dryrun --all --json {path}` first")
        return csv_row("roofline", 0.0, "missing_dryrun_artifacts")
    lines = ["# Roofline terms per (arch × shape), single-pod 16×16",
             f"{'arch':18s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
             f" {'coll_s':>10s} {'bound':>10s} {'useful':>7s}"]
    worst = None
    for r in sorted(reports, key=lambda x: (x["arch"], x["shape"])):
        t = terms(r)
        lines.append(
            f"{r['arch']:18s} {r['shape']:12s} {t['compute_s']:10.4f} "
            f"{t['memory_s']:10.4f} {t['collective_s']:10.4f} "
            f"{t['dominant']:>10s} {t['useful_ratio']:7.2f}")
        if worst is None or t["useful_ratio"] < worst[1]:
            worst = (f"{r['arch']}/{r['shape']}", t["useful_ratio"])
    lines.append("# suggestion per dominant term: "
                 + "; ".join(f"{k}: {v}" for k, v in SUGGEST.items()))
    print("\n".join(lines))
    return csv_row("roofline", (time.time() - t0) * 1e6,
                   f"worst_useful_ratio={worst[0]}:{worst[1]:.3f}")
