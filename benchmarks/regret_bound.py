"""§3.3: CS-UCB cumulative regret vs the Eq. 7 bound (log-over-time)."""
from __future__ import annotations

import copy
import time

import numpy as np

from benchmarks.common import csv_row
from repro.cluster import BandwidthModel, Simulator, generate_workload, paper_testbed
from repro.core import PerLLMScheduler


def run() -> str:
    t0 = time.time()
    specs = paper_testbed("llama2-7b")
    services = generate_workload(4000, seed=0)
    sched = PerLLMScheduler(len(specs))
    sim = Simulator(specs, BandwidthModel(False, seed=1), seed=42)
    sim.run([copy.copy(s) for s in services], sched)
    trace = np.array(sched.regret_trace)
    lines = ["# CS-UCB cumulative (approximate) regret over decisions",
             f"{'t':>6s} {'regret':>10s} {'regret/t':>10s}"]
    for frac in (0.1, 0.25, 0.5, 0.75, 1.0):
        t = max(int(len(trace) * frac) - 1, 0)
        lines.append(f"{t+1:6d} {trace[t]:10.1f} {trace[t]/(t+1):10.4f}")
    # sublinearity: per-step regret decreasing over the run
    early = trace[len(trace) // 4] / (len(trace) // 4)
    late = (trace[-1] - trace[len(trace) // 2]) / (len(trace) // 2)
    bound = sched.bandit.regret_bound()
    lines.append(f"# per-step regret early={early:.4f} late={late:.4f} "
                 f"(Eq.7 bound term={bound:.1f})")
    print("\n".join(lines))
    return csv_row("regret_bound", (time.time() - t0) * 1e6,
                   f"per_step_regret_early={early:.4f};late={late:.4f}")
