"""Figure 5: system throughput (tokens/s) under saturation.

The paper blasts 10,000 concurrent services; throughput is the sustained
token rate of *successfully served* requests (goodput). We sweep arrival
rate and report each method's best sustained goodput — the paper's headline
ratios are PerLLM = 2.2× FineInfer, 2.1× AGOD, 1.6× RewardlessGuidance.
"""
from __future__ import annotations

import copy
import os
import time

from benchmarks.common import csv_row, make_scheduler
from repro.cluster import BandwidthModel, Simulator, generate_workload, paper_testbed

METHODS = ("PerLLM", "FineInfer", "AGOD", "RewardlessGuidance")
RATES = (10.0, 16.0, 22.0, 28.0)
N = int(os.environ.get("BENCH_N_SAT", "4000"))


def goodput(res) -> float:
    # tokens of deadline-meeting services per second of makespan
    return res.throughput_tokens_per_s * res.success_rate


def run(edge_model: str = "llama2-7b") -> str:
    t0 = time.time()
    best = {}
    lines = [f"# Fig 5: goodput tokens/s vs arrival rate ({edge_model})",
             f"{'rate':>6s} " + " ".join(f"{m:>20s}" for m in METHODS)]
    for rate in RATES:
        services = generate_workload(N, rate=rate, seed=0)
        row = [f"{rate:6.0f}"]
        for m in METHODS:
            specs = paper_testbed(edge_model)
            sim = Simulator(specs, BandwidthModel(False, seed=1), seed=42)
            res = sim.run([copy.copy(s) for s in services],
                          make_scheduler(m, len(specs)))
            g = goodput(res)
            best[m] = max(best.get(m, 0.0), g)
            row.append(f"{g:20.1f}")
        lines.append(" ".join(row))
    ratios = {m: best["PerLLM"] / best[m] for m in METHODS if m != "PerLLM"}
    lines.append("# saturation goodput ratios vs PerLLM: "
                 + ", ".join(f"{m}={r:.2f}x" for m, r in ratios.items()))
    print("\n".join(lines))
    derived = (f"thpt_ratio_fineinfer={ratios['FineInfer']:.2f}x;"
               f"agod={ratios['AGOD']:.2f}x;"
               f"rg={ratios['RewardlessGuidance']:.2f}x;"
               f"perllm_goodput={best['PerLLM']:.1f}")
    return csv_row("fig5_throughput", (time.time() - t0) * 1e6, derived)
