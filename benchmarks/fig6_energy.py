"""Figure 6: energy cost (transmission / inference / idle) per method.

With `--tiers` (BENCH_TIERS) the testbed carries the stock DVFS ladder and
a second section reports the paper's allocation story: PerLLM's learned
(class, server, tier) policy against the fixed-nominal-tier PerLLM —
total-energy cut, energy per served token, and the admitted-SLO rate the
cut is achieved at. These are the gated metrics of the CI energy smoke
(`benchmarks/compare_baseline.py`).
"""
from __future__ import annotations

import time

import benchmarks.common as common
from benchmarks.common import EDGE_MODELS, METHODS, csv_row, matrix, run_cell


def tier_section(lines) -> str:
    """Learned-tier vs fixed-nominal PerLLM on the active scenario."""
    edge = "llama2-7b"
    nominal, _ = run_cell(edge, False, "PerLLM", tiers=False)
    tiered, _ = run_cell(edge, False, "PerLLM", tiers=True)
    cut = 1.0 - tiered.total_energy / nominal.total_energy
    lines.append("# Fig 6b: DVFS tier selection (PerLLM learned vs "
                 "fixed-nominal)")
    lines.append(f"{'policy':16s} {'energy kJ':>10s} {'J/token':>8s} "
                 f"{'adm_succ':>9s} {'rejected':>9s}")
    for tag, r in (("fixed-nominal", nominal), ("learned-tiers", tiered)):
        lines.append(f"{tag:16s} {r.total_energy/1e3:10.1f} "
                     f"{r.energy_per_token:8.2f} "
                     f"{r.admitted_success_rate*100:8.1f}% "
                     f"{r.n_rejected:9d}")
    lines.append(f"# learned tiers cut total energy {cut*100:.1f}% "
                 f"(inference {100*(1-tiered.e_infer/nominal.e_infer):.1f}%)")
    return (f"tier_energy_cut={cut*100:.1f}%;"
            f"energy_per_token={tiered.energy_per_token:.3f};"
            f"admitted_success_rate={tiered.admitted_success_rate*100:.1f}%")


def run() -> str:
    t0 = time.time()
    lines = []
    for fluct in (False, True):
        tag = "fluctuating" if fluct else "stable"
        m = matrix(fluct)
        lines.append(f"# Fig 6: total energy, kJ (tx/infer/idle) ({tag})")
        lines.append(f"{'model':12s} "
                     + " ".join(f"{x:>26s}" for x in METHODS))
        for em in EDGE_MODELS:
            cells = []
            for x in METHODS:
                r = m[em][x]
                cells.append(f"{r.total_energy/1e3:8.0f}"
                             f"({r.e_tx/1e3:.0f}/{r.e_infer/1e3:.0f}"
                             f"/{r.e_idle/1e3:.0f})")
            lines.append(f"{em:12s} " + " ".join(f"{c:>26s}" for c in cells))
    m = matrix(False)
    red_fine = min(1 - m[em]["PerLLM"].total_energy
                   / m[em]["FineInfer"].total_energy for em in EDGE_MODELS)
    red_avg = min(
        1 - m[em]["PerLLM"].total_energy
        / (sum(m[em][x].total_energy for x in METHODS if x != "PerLLM") / 3)
        for em in EDGE_MODELS)
    derived = (f"energy_cut_vs_fineinfer={red_fine*100:.0f}%;"
               f"vs_baseline_avg={red_avg*100:.0f}%")
    if common.TIERS:       # read at call time: benchmarks.run may rebind
        derived += ";" + tier_section(lines)
    print("\n".join(lines))
    return csv_row("fig6_energy", (time.time() - t0) * 1e6, derived)
