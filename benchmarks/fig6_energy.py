"""Figure 6: energy cost (transmission / inference / idle) per method."""
from __future__ import annotations

import time

from benchmarks.common import EDGE_MODELS, METHODS, csv_row, matrix


def run() -> str:
    t0 = time.time()
    lines = []
    for fluct in (False, True):
        tag = "fluctuating" if fluct else "stable"
        m = matrix(fluct)
        lines.append(f"# Fig 6: total energy, kJ (tx/infer/idle) ({tag})")
        lines.append(f"{'model':12s} "
                     + " ".join(f"{x:>26s}" for x in METHODS))
        for em in EDGE_MODELS:
            cells = []
            for x in METHODS:
                r = m[em][x]
                cells.append(f"{r.total_energy/1e3:8.0f}"
                             f"({r.e_tx/1e3:.0f}/{r.e_infer/1e3:.0f}"
                             f"/{r.e_idle/1e3:.0f})")
            lines.append(f"{em:12s} " + " ".join(f"{c:>26s}" for c in cells))
    m = matrix(False)
    red_fine = min(1 - m[em]["PerLLM"].total_energy
                   / m[em]["FineInfer"].total_energy for em in EDGE_MODELS)
    red_avg = min(
        1 - m[em]["PerLLM"].total_energy
        / (sum(m[em][x].total_energy for x in METHODS if x != "PerLLM") / 3)
        for em in EDGE_MODELS)
    print("\n".join(lines))
    derived = (f"energy_cut_vs_fineinfer={red_fine*100:.0f}%;"
               f"vs_baseline_avg={red_avg*100:.0f}%")
    return csv_row("fig6_energy", (time.time() - t0) * 1e6, derived)
