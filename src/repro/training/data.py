"""Synthetic-but-structured data pipeline.

Deterministic PRNG token streams with Zipfian unigram statistics and induced
bigram structure, packed into fixed-length training batches. Gives training
runs a learnable signal (loss drops well below uniform entropy) without any
external datasets — this container is offline.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticLM:
    """Zipf unigrams + deterministic bigram successor structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = ranks ** (-cfg.zipf_a)
        self.unigram /= self.unigram.sum()
        # each token has a preferred successor; followed with prob 0.5
        self.successor = rng.permutation(v)
        self._rng = np.random.default_rng(cfg.seed + 1)

    def _sample_seq(self, n: int) -> np.ndarray:
        out = np.empty(n, np.int64)
        out[0] = self._rng.choice(self.cfg.vocab_size, p=self.unigram)
        follow = self._rng.uniform(size=n) < 0.5
        fresh = self._rng.choice(self.cfg.vocab_size, p=self.unigram, size=n)
        for i in range(1, n):
            out[i] = self.successor[out[i - 1]] if follow[i] else fresh[i]
        return out

    def batches(self) -> Iterator[dict]:
        c = self.cfg
        while True:
            toks = np.stack([self._sample_seq(c.seq_len + 1)
                             for _ in range(c.batch_size)])
            yield {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32)}
