"""AdamW + gradient clipping + LR schedules, in pure JAX.

Optimizer state mirrors the params pytree (m, v in f32 regardless of param
dtype — standard mixed-precision practice), so `param_shardings` applies to
it leaf-for-leaf.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def cosine_lr(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v, strict=True)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
