"""Training loop: jit'd train_step factory + a simple driver."""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.parallel import ParallelContext, param_shardings
from repro.training.optimizer import (
    AdamWConfig, OptState, adamw_update, init_opt_state,
)


def make_train_step(cfg: ModelConfig, ctx: ParallelContext,
                    opt_cfg: AdamWConfig, microbatches: int = 1,
                    acc_dtype=None):
    """Returns train_step(params, opt_state, batch) -> (p', s', metrics).

    `microbatches > 1` enables gradient accumulation: the global batch is
    scanned in chunks, so activation transients shrink ~linearly while the
    optimizer math runs once (§Perf memory lever for the large train
    shapes). `acc_dtype=jnp.bfloat16` halves the accumulator/conversion
    footprint at ~2 bits of accumulation precision (measured lever, not the
    default).
    """
    import jax.numpy as _jnp
    acc_dtype = acc_dtype or _jnp.float32

    grad_fn = jax.value_and_grad(M.loss_fn, has_aux=True)

    # ZeRO-2-style accumulation: constrain the f32 grad accumulator to the
    # (data × model)-sharded optimizer-moment layout, so each microbatch's
    # grads are reduce-scattered and the carry holds only a shard
    if ctx.mesh is not None and microbatches > 1:
        from repro.models.parallel import opt_state_shardings
        _gshard = opt_state_shardings(M.params_shapes(cfg), ctx)

        def _constrain_grads(g):
            return jax.tree.map(jax.lax.with_sharding_constraint, g, _gshard)
    else:
        def _constrain_grads(g):
            return g

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (total, metrics), grads = grad_fn(params, batch, cfg=cfg,
                                              ctx=ctx)
        else:
            b = batch["tokens"].shape[0]
            assert b % microbatches == 0, (b, microbatches)
            mb = b // microbatches

            def split(x):
                return x.reshape((microbatches, mb) + x.shape[1:]) \
                    if x.shape[0] == b else \
                    jnp.broadcast_to(x, (microbatches,) + x.shape)

            chunks = {k: split(v) for k, v in batch.items()
                      if k != "positions"}
            if "positions" in batch:  # (3, B, S) -> (k, 3, mb, S)
                p3 = batch["positions"]
                chunks["positions"] = jnp.moveaxis(
                    p3.reshape(3, microbatches, mb, -1), 1, 0)

            def body(carry, chunk):
                grads_acc, loss_acc, aux_acc = carry
                (total, metrics), grads = grad_fn(params, chunk, cfg=cfg,
                                                  ctx=ctx)
                grads_acc = _constrain_grads(jax.tree.map(
                    lambda a, g: a + g.astype(acc_dtype) / microbatches,
                    grads_acc, grads))
                return (grads_acc, loss_acc + metrics["loss"] / microbatches,
                        aux_acc + metrics["moe_aux_loss"] / microbatches), \
                    None

            zeros = _constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params))
            (grads, loss, aux), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), chunks)
            total = loss
            metrics = {"loss": loss, "moe_aux_loss": aux}
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["total_loss"] = total
        return params, opt_state, metrics

    return train_step


def jit_train_step(cfg: ModelConfig, ctx: ParallelContext,
                   opt_cfg: AdamWConfig):
    step = make_train_step(cfg, ctx, opt_cfg)
    if ctx.mesh is None:
        return jax.jit(step, donate_argnums=(0, 1))
    pshapes = M.params_shapes(cfg)
    pshard = param_shardings(pshapes, ctx)
    oshard = OptState(
        step=ctx.sharding(),
        m=pshard, v=jax.tree.map(lambda s: s, pshard))
    bshard = {"tokens": ctx.sharding(ctx.batch_spec, None),
              "labels": ctx.sharding(ctx.batch_spec, None)}
    return jax.jit(step, in_shardings=(pshard, oshard, bshard),
                   out_shardings=(pshard, oshard, None),
                   donate_argnums=(0, 1))


def train(cfg: ModelConfig, ctx: Optional[ParallelContext] = None,
          steps: int = 50, batch_size: int = 8, seq_len: int = 128,
          opt_cfg: Optional[AdamWConfig] = None, seed: int = 0,
          log_every: int = 10, data_iter=None):
    """End-to-end small-scale training driver (CPU-friendly)."""
    from repro.models.parallel import cpu_context
    from repro.training.data import DataConfig, SyntheticLM

    ctx = ctx or cpu_context()
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps)
    params = M.init_params(jax.random.key(seed), cfg)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, ctx, opt_cfg),
                      donate_argnums=(0, 1))
    if data_iter is None:
        data = SyntheticLM(DataConfig(cfg.vocab_size, seq_len, batch_size,
                                      seed=seed))
        data_iter = data.batches()

    history = []
    t0 = time.time()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data_iter).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["elapsed_s"] = time.time() - t0
            history.append(m)
            print(f"step {i:4d} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e}")
    return params, opt_state, history
