"""Checkpointing: flattened-pytree .npz + JSON treedef manifest."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _paths(params) -> list:
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]


def save_checkpoint(path: str, params: Any, extra: dict = None) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(params)
    names = _paths(params)
    arrays = {}
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtypes.append(str(arr.dtype))
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
            arr = arr.view(np.uint16)  # bf16: store raw bits
        arrays[f"arr_{i}"] = arr
    np.savez(os.path.join(path, "weights.npz"), **arrays)
    manifest = {"names": names, "n_leaves": len(leaves), "dtypes": dtypes,
                "extra": extra or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str, like: Any) -> Tuple[Any, dict]:
    """Restore into the structure of `like` (shape/dtype-checked)."""
    data = np.load(os.path.join(path, "weights.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == manifest["n_leaves"], "structure mismatch"
    new_leaves = []
    for i, leaf in enumerate(leaves):
        arr = data[f"arr_{i}"]
        want = manifest.get("dtypes", [None] * len(leaves))[i]
        if want and "bfloat16" in want and arr.dtype == np.uint16:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        assert arr.shape == tuple(leaf.shape), (arr.shape, leaf.shape)
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return treedef.unflatten(new_leaves), manifest["extra"]
