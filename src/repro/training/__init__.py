from repro.training.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state
from repro.training.train_loop import jit_train_step, make_train_step, train
from repro.training.data import DataConfig, SyntheticLM
from repro.training.checkpoint import load_checkpoint, save_checkpoint

__all__ = [
    "AdamWConfig", "DataConfig", "OptState", "SyntheticLM", "adamw_update",
    "init_opt_state", "jit_train_step", "load_checkpoint", "make_train_step",
    "save_checkpoint", "train",
]
