"""Mamba-2 block with the SSD (state-space duality) chunked algorithm.

TPU adaptation notes (vs. the CUDA kernels in the paper):
  * The chunked SSD decomposition (diagonal block + inter-chunk state
    recurrence) is already MXU-friendly — each term is an einsum over
    (chunk × chunk) or (chunk × state) tiles; we keep chunk_size=256 so the
    contraction dims are 128-multiples.
  * The inter-chunk recurrence is a `lax.scan` carrying the (B, H, P, N)
    state — sequential in S/chunk (16 steps at 4k), negligible vs. the
    matmuls.
  * Decode is the dual recurrent form: O(1) state update per token, which is
    what makes `long_500k` trivially sub-quadratic for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm
from repro.models.parallel import ParallelContext


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    return s, di, nh


def init_ssm(key, cfg: ModelConfig):
    s, di, nh = _dims(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    conv_dim = di + 2 * s.d_state
    return {
        "wz": dense_init(ks[0], (d, di), dtype=dt),
        "wx": dense_init(ks[1], (d, di), dtype=dt),
        "wB": dense_init(ks[2], (d, s.d_state), dtype=dt),
        "wC": dense_init(ks[3], (d, s.d_state), dtype=dt),
        "w_dt": dense_init(ks[4], (d, nh), dtype=dt),
        "dt_bias": jnp.zeros((nh,), jnp.float32)
        + jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            ks[5], (nh,), minval=jnp.log(0.001), maxval=jnp.log(0.1))))),
        "A_log": jnp.log(jax.random.uniform(ks[6], (nh,), minval=1.0,
                                            maxval=16.0)),
        "D": jnp.ones((nh,), jnp.float32),
        "conv": dense_init(ks[7], (s.d_conv, conv_dim), scale=0.2, dtype=dt),
        "norm": init_rmsnorm(di),
        "out_proj": dense_init(jax.random.fold_in(key, 99), (di, d), dtype=dt),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=None):
    s, di, nh = _dims(cfg)
    dt = dtype or jnp.dtype(cfg.dtype)
    conv_dim = di + 2 * s.d_state
    return {
        "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dt),
    }


def _causal_conv(u, kernel, conv_state=None):
    """Depthwise causal conv along S. u: (B, S, C); kernel: (K, C)."""
    k = kernel.shape[0]
    pad = (jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
           if conv_state is None else conv_state.astype(u.dtype))
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * kernel[i] for i in range(k))
    new_state = up[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, use_pallas: bool = False):
    """SSD forward, chunk-parallel (Mamba-2 Alg. 1 dual form).

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B, S, N) — single group broadcast across heads.
    Returns y: (B, S, H, P) and the final state (B, H, P, N).
    With `use_pallas`, the quadratic diagonal-block term runs in the
    `repro.kernels.ssd_diag` TPU kernel and only the (linear) inter-chunk
    recurrence stays in the scan.
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    s_orig = s
    if s % chunk:
        # zero-pad the tail: dt=0 there, so decay=1 and state is untouched
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    f32 = jnp.float32

    # one scan over chunks does everything: the per-chunk working set is
    # O(B·Q·Q·H) — never materialize (B, nc, Q, Q, H) at once
    xr = jnp.moveaxis(x.reshape(b, nc, chunk, h, p), 1, 0).astype(f32)
    dtr = jnp.moveaxis(dt.reshape(b, nc, chunk, h), 1, 0).astype(f32)
    Br = jnp.moveaxis(Bm.reshape(b, nc, chunk, n), 1, 0).astype(f32)
    Cr = jnp.moveaxis(Cm.reshape(b, nc, chunk, n), 1, 0).astype(f32)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])

    def body(h_prev, xs):
        xc, dtc, Bc, Cc = xs            # (B,Q,H,P) (B,Q,H) (B,Q,N) (B,Q,N)
        a = dtc * A                      # (B,Q,H), negative
        cum = jnp.cumsum(a, axis=1)
        dtx = dtc[..., None] * xc        # (B,Q,H,P)

        if use_pallas:
            y_diag = 0.0                 # kernel computes it outside
        else:
            scores = jnp.einsum("bqn,bkn->bqk", Cc, Bc)
            decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,K,H)
            lmat = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
            w = scores[..., None] * lmat                     # (B,Q,K,H)
            y_diag = jnp.einsum("bqkh,bkhp->bqhp", w, dtx)

        # contribution of the carried inter-chunk state
        y_off = jnp.einsum("bqn,bhpn->bqhp", Cc, h_prev) \
            * jnp.exp(cum)[..., None]

        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)      # (B,Q,H)
        state_c = jnp.einsum("bqn,bqh,bqhp->bhpn", Bc, decay_to_end, dtx)
        h_new = h_prev * jnp.exp(cum[:, -1])[:, :, None, None] + state_c
        return h_new, y_diag + y_off

    init = jnp.zeros((b, h, p, n), f32)
    # recompute the chunk internals in backward (the (B,Q,Q,H) decay matrix
    # would otherwise be saved for every chunk) — same policy as the CUDA
    # mamba kernels
    final_state, ys = jax.lax.scan(jax.checkpoint(body), init,
                                   (xr, dtr, Br, Cr))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    if use_pallas:
        from repro.kernels.ops import _auto_interpret
        from repro.kernels.ssd_diag import ssd_diag
        y = y + ssd_diag(x.astype(f32), dt.astype(f32), A, Bm.astype(f32),
                         Cm.astype(f32), chunk=chunk,
                         interpret=_auto_interpret(None))
    y = y[:, :s_orig]
    return y.astype(x.dtype), final_state


def ssm_layer(p, x, *, cfg: ModelConfig, ctx: ParallelContext, mode: str,
              cache=None):
    """Full Mamba-2 mixing layer. Returns (out, new_cache)."""
    s_cfg, di, nh = _dims(cfg)
    b, s, d = x.shape
    hd = s_cfg.head_dim

    z = x @ p["wz"]
    xin = x @ p["wx"]
    Bm = x @ p["wB"]
    Cm = x @ p["wC"]
    dt_raw = x @ p["w_dt"]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    new_cache = None
    if mode == "decode":
        conv_out, conv_state = _causal_conv(conv_in, p["conv"],
                                            cache["conv"])
        xin, Bm, Cm = jnp.split(conv_out, [di, di + s_cfg.d_state], axis=-1)
        xh = xin.reshape(b, nh, hd)                       # s == 1
        dt1 = dt[:, 0]                                    # (B, H)
        da = jnp.exp(dt1 * A)                             # (B, H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt1, xh.astype(jnp.float32),
                         Bm[:, 0].astype(jnp.float32))
        h_new = cache["state"] * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h_new)
        y = y + p["D"][:, None] * xh.astype(jnp.float32)
        y = y.reshape(b, 1, di).astype(x.dtype)
        new_cache = {"state": h_new, "conv": conv_state}
    else:
        conv_out, conv_state = _causal_conv(conv_in, p["conv"])
        xin, Bm, Cm = jnp.split(conv_out, [di, di + s_cfg.d_state], axis=-1)
        xh = xin.reshape(b, s, nh, hd)
        y, final_state = ssd_chunked(xh, dt, A, Bm, Cm,
                                     min(s_cfg.chunk_size, s),
                                     use_pallas=ctx.use_pallas)
        y = y + p["D"][None, None, :, None] * xh.astype(y.dtype)
        y = y.reshape(b, s, di)
        if mode == "prefill":
            new_cache = {"state": final_state,
                         "conv": conv_in[:, -(s_cfg.d_conv - 1):]}

    y = rmsnorm(p["norm"], y * jax.nn.silu(z)).astype(x.dtype)
    return y @ p["out_proj"], new_cache
