"""Mixture-of-Experts layer: shared + routed experts, top-k gating.

Covers Mixtral (8 experts, top-2, softmax-renormalized gates) and
DeepSeekMoE (fine-grained: 2 shared + 64 routed, top-6).

The routed computation uses dense one-hot dispatch/combine einsums — every
token multiplies against every expert's weights with a (top-k-normalized)
combine weight that is zero for unrouted experts. On TPU this is the
deterministic, all-to-all-free baseline (compute cost = E/k × active FLOPs);
`expert_parallel=True` in the layout hillclimb shards the expert dim instead
(see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_mlp, mlp
from repro.models.parallel import ParallelContext


def init_moe(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.moe_d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    k_router, k_up, k_gate, k_down, k_shared = jax.random.split(key, 5)
    e = cfg.n_experts
    params = {
        "router": dense_init(k_router, (d, e), scale=0.02, dtype=jnp.float32),
        "we_gate": dense_init(k_gate, (e, d, h), dtype=dt),
        "we_up": dense_init(k_up, (e, d, h), dtype=dt),
        "we_down": dense_init(k_down, (e, h, d), dtype=dt),
    }
    if cfg.n_shared_experts:
        params["shared"] = init_mlp(k_shared, d, cfg.n_shared_experts * h, dt)
    return params


def router_probs(p, x, cfg: ModelConfig):
    """(tokens, E) routing probabilities and top-k indices."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)  # renormalize gates
    return probs, topv, topi


def moe_layer(p, x, *, cfg: ModelConfig, ctx: ParallelContext):
    """x: (B, S, D) -> (out, aux) with load-balance auxiliary loss terms."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    probs, topv, topi = router_probs(p, xt, cfg)

    # combine weights: (tokens, E), zero outside top-k
    comb = jnp.zeros_like(probs)
    comb = jax.vmap(lambda c, i, v: c.at[i].set(v))(comb, topi, topv)
    comb = comb.astype(x.dtype)

    act = jax.nn.gelu if cfg.activation == "geglu" else jax.nn.silu
    gate = jnp.einsum("td,edf->tef", xt, p["we_gate"])
    up = jnp.einsum("td,edf->tef", xt, p["we_up"])
    hidden = act(gate) * up
    expert_out = jnp.einsum("tef,efd->ted", hidden, p["we_down"])
    out = jnp.einsum("ted,te->td", expert_out, comb)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xt, cfg.activation, ctx)

    # Switch-style load-balance loss: E * sum_e f_e * P_e
    e = cfg.n_experts
    dispatch = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=1)
    frac_tokens = jnp.mean(dispatch, axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux_loss = e * jnp.sum(frac_tokens * frac_probs)

    return out.reshape(b, s, d), {"moe_aux_loss": aux_loss}


def moe_layer_capacity(p, x, *, cfg: ModelConfig, ctx: ParallelContext,
                       capacity_factor: float = 1.25):
    """Capacity-based sorted dispatch (§Perf hillclimb, beyond-paper).

    Tokens are sorted by expert id and packed into an (E, C, D) buffer with
    C = ceil(top_k·T/E · capacity_factor); each expert multiplies only its
    buffer. FLOPs drop from E× to top_k·capacity_factor× the per-expert
    cost (≈8.5× less for DeepSeekMoE-64e-top6); overflow tokens beyond an
    expert's capacity are dropped from that expert (standard Switch/GShard
    semantics — their other top-k routes still serve them).
    """
    b, s, d = x.shape
    e = cfg.n_experts
    k = cfg.top_k
    # per-SEQUENCE dispatch: the sort/pack stays inside the data shard (a
    # global token sort would cross devices — measured 4.3× collective blowup)
    cap = int(np.ceil(k * s / e * capacity_factor))
    probs, topv, topi = router_probs(p, x.reshape(b * s, d), cfg)
    topv = topv.reshape(b, s, k)
    topi = topi.reshape(b, s, k)

    def dispatch_row(xr, ir, wr):
        """xr: (S, D); ir/wr: (S, k) -> buffer (E, C, D) + combine info."""
        flat_e = ir.reshape(s * k)
        flat_tok = jnp.repeat(jnp.arange(s), k)
        flat_w = wr.reshape(s * k)
        order = jnp.argsort(flat_e)
        se, st, sw = flat_e[order], flat_tok[order], flat_w[order]
        start = jnp.searchsorted(se, jnp.arange(e), side="left")
        rank = jnp.arange(s * k) - start[se]
        keep = rank < cap
        slot = jnp.where(keep, se * cap + rank, e * cap)  # overflow row
        buf = jnp.zeros((e * cap + 1, d), xr.dtype).at[slot].set(xr[st])
        return buf[:-1].reshape(e, cap, d), (st, sw, keep, slot)

    buf, (st, sw, keep, slot) = jax.vmap(dispatch_row)(x, topi, topv)

    act = jax.nn.gelu if cfg.activation == "geglu" else jax.nn.silu
    gate = jnp.einsum("becd,edf->becf", buf, p["we_gate"])
    up = jnp.einsum("becd,edf->becf", buf, p["we_up"])
    hidden = act(gate) * up
    out_buf = jnp.einsum("becf,efd->becd", hidden, p["we_down"])

    def combine_row(ob, st, sw, keep, slot):
        flat = ob.reshape(e * cap, d)
        contrib = flat[jnp.where(keep, slot, 0)] \
            * (sw * keep)[:, None].astype(flat.dtype)
        return jnp.zeros((s, d), flat.dtype).at[st].add(contrib)

    out = jax.vmap(combine_row)(out_buf, st, sw, keep, slot)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x, cfg.activation, ctx)

    dispatch = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=2)
    aux_loss = e * jnp.sum(jnp.mean(dispatch.reshape(b * s, e), 0)
                           * jnp.mean(probs, 0))
    return out, {"moe_aux_loss": aux_loss}


def moe_layer_ep_a2a(p, x, *, cfg: ModelConfig, ctx: ParallelContext,
                     capacity_factor: float = 1.25):
    """Expert-parallel MoE with explicit all-to-all (shard_map).

    The textbook TPU MoE flow (§Perf hillclimb):
      1. route + pack locally (per shard) into an (E, C_loc, D) buffer,
      2. all-to-all over the `model` axis: each device keeps its E/m experts
         and receives every shard's rows for them → (E/m, m·C_loc, D),
      3. local FFN with expert-sharded weights (no psum at all),
      4. inverse all-to-all + local weighted combine.
    Collective cost per layer = 2 all-to-alls of ~top_k·cf·tokens·D bytes —
    instead of the gather/AR storms GSPMD emits for the jnp scatter forms.
    """
    from jax.sharding import PartitionSpec as P
    m = ctx.axis_size(ctx.model_axis)
    e = cfg.n_experts
    if ctx.mesh is None or m == 1 or e % m:
        # ep_a2a needs n_experts % model_axis == 0. The capacity-gather
        # fallback measured WORSE than dense under GSPMD (mixtral train:
        # collective 1.0 → 10.8 s — EXPERIMENTS.md §Perf), so fall back to
        # the dense-dispatch baseline instead.
        if ctx.mesh is not None and m > 1:
            return moe_layer(p, x, cfg=cfg, ctx=ctx)
        return moe_layer_capacity(p, x, cfg=cfg, ctx=ctx,
                                  capacity_factor=capacity_factor)
    b, s, d = x.shape
    k = cfg.top_k
    # local token count: batch over data axes, seq over model (seq-parallel)
    bdiv = ctx.batch_size_divisor if b % ctx.batch_size_divisor == 0 else 1
    s_loc = s // m if s % m == 0 else s
    t_loc = (b // bdiv) * s_loc
    cap = int(np.ceil(k * t_loc / e * capacity_factor))
    act = jax.nn.gelu if cfg.activation == "geglu" else jax.nn.silu

    def body(xl, router, wg, wu, wd):
        bl, sl, _ = xl.shape
        tl = bl * sl
        xt = xl.reshape(tl, d)
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, k)
        topv = (topv / jnp.sum(topv, -1, keepdims=True)).astype(xl.dtype)

        flat_e = topi.reshape(tl * k)
        flat_tok = jnp.repeat(jnp.arange(tl), k)
        flat_w = topv.reshape(tl * k)
        order = jnp.argsort(flat_e)
        se, st, sw = flat_e[order], flat_tok[order], flat_w[order]
        start = jnp.searchsorted(se, jnp.arange(e), side="left")
        rank = jnp.arange(tl * k) - start[se]
        keep = rank < cap
        slot = jnp.where(keep, se * cap + rank, e * cap)
        buf = jnp.zeros((e * cap + 1, d), xl.dtype).at[slot].set(xt[st])
        buf = buf[:-1].reshape(e, cap, d)

        # exchange: (E, C, D) -> (E/m, m·C, D) rows for MY experts
        buf = jax.lax.all_to_all(buf, ctx.model_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
        gate = jnp.einsum("ecd,edf->ecf", buf, wg)
        up = jnp.einsum("ecd,edf->ecf", buf, wu)
        out_buf = jnp.einsum("ecf,efd->ecd", act(gate) * up, wd)
        # inverse exchange: rows return to their source shard
        out_buf = jax.lax.all_to_all(out_buf, ctx.model_axis, split_axis=1,
                                     concat_axis=0, tiled=True)

        flat = out_buf.reshape(e * cap, d)
        contrib = flat[jnp.where(keep, slot, 0)] \
            * (sw * keep).astype(flat.dtype)[:, None]
        out = jnp.zeros((tl, d), flat.dtype).at[st].add(contrib)

        disp = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=1)
        aux = e * jnp.sum(jnp.mean(disp, 0) * jnp.mean(probs, 0))
        aux = jax.lax.pmean(aux, ctx.mesh.axis_names)
        return out.reshape(bl, sl, d), aux

    bspec = ctx.batch_spec if b % ctx.batch_size_divisor == 0 else None
    sspec = ctx.model_axis if s % m == 0 else None
    x_spec = P(bspec, sspec, None)
    from jax.experimental.shard_map import shard_map
    out, aux = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(x_spec, P(), P(ctx.model_axis, None, None),
                  P(ctx.model_axis, None, None),
                  P(ctx.model_axis, None, None)),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x, cfg.activation, ctx)
    return out, {"moe_aux_loss": aux}


def moe_layer_expert_parallel(p, x, *, cfg: ModelConfig, ctx: ParallelContext):
    """Expert-parallel variant: experts sharded over the `model` axis.

    The dispatch one-hot contraction becomes an all-to-all-like pattern under
    GSPMD (tokens × expert-sharded weights). Used by the §Perf hillclimb; the
    math is identical to ``moe_layer``.
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    probs, topv, topi = router_probs(p, xt, cfg)
    comb = jnp.zeros_like(probs)
    comb = jax.vmap(lambda c, i, v: c.at[i].set(v))(comb, topi, topv)
    comb = comb.astype(x.dtype)

    act = jax.nn.gelu if cfg.activation == "geglu" else jax.nn.silu
    ma = ctx.model_axis
    e = cfg.n_experts

    def ep(w):  # shard expert dim when divisible
        if ctx.mesh is None or e % ctx.axis_size(ma):
            return w
        return ctx.constrain(w, ma, *([None] * (w.ndim - 1)))

    gate = jnp.einsum("td,edf->tef", xt, ep(p["we_gate"]))
    up = jnp.einsum("td,edf->tef", xt, ep(p["we_up"]))
    hidden = act(gate) * up
    if ctx.mesh is not None and e % ctx.axis_size(ma) == 0:
        hidden = ctx.constrain(hidden, None, ma, None)
    expert_out = jnp.einsum("tef,efd->ted", hidden, ep(p["we_down"]))
    out = jnp.einsum("ted,te->td", expert_out, comb)
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xt, cfg.activation, ctx)

    dispatch = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=1)
    aux_loss = e * jnp.sum(jnp.mean(dispatch, 0) * jnp.mean(probs, 0))
    return out.reshape(b, s, d), {"moe_aux_loss": aux_loss}
