"""Public model API: init / forward / loss / prefill / decode, per config.

All entry points are pure functions of ``(cfg, ctx)`` closed over at jit
time; `input_specs` yields ShapeDtypeStruct stand-ins for the dry-run so no
arrays are ever materialized for the full-size configs.
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.parallel import ParallelContext


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_stack, k_enc, k_out = jax.random.split(key, 4)
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "stack": T.init_stack(k_stack, cfg),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(
            k_out, (cfg.vocab_size, cfg.d_model), jnp.float32)
            / math.sqrt(cfg.d_model)).astype(dt)
    if cfg.enc_dec:
        params["encoder"] = T.init_encoder(k_enc, cfg)
    if cfg.vision_tokens:
        params["vision_proj"] = (jax.random.normal(
            jax.random.fold_in(key, 7), (cfg.d_model, cfg.d_model),
            jnp.float32) * 0.02).astype(dt)
    return params


def params_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family in ("dense", "hybrid", "vlm") and cfg.tie_embeddings:
        # gemma-family convention: scale token embeddings by sqrt(d)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def lm_head(params, x, cfg: ModelConfig):
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg: ModelConfig, ctx: ParallelContext,
                  mode: str = "train"):
    """Token (+ modality-stub) embedding. Returns (x, positions, enc_out)."""
    enc_out = None
    if cfg.enc_dec and mode != "decode":
        # decode never re-encodes: cross K/V were cached at prefill
        enc_out = T.run_encoder(params["encoder"], batch["audio_frames"],
                                cfg=cfg, ctx=ctx)
    x = embed_tokens(params, batch["tokens"], cfg)
    if cfg.vision_tokens and "vision_embeds" in batch:
        v = batch["vision_embeds"] @ params["vision_proj"]
        x = jnp.concatenate([v.astype(x.dtype), x[:, v.shape[1]:]], axis=1)
    positions = batch.get("positions")
    return ctx.shard_activation(x), positions, enc_out


def forward(params, batch, *, cfg: ModelConfig, ctx: ParallelContext,
            mode: str = "train", cache=None, pos=None):
    x, positions, enc_out = _embed_inputs(params, batch, cfg, ctx, mode)
    x, new_cache, aux = T.run_stack(
        params["stack"], x, cfg=cfg, ctx=ctx, mode=mode, cache=cache,
        pos=pos, positions=positions, enc_out=enc_out)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params, x, cfg)
    return logits, new_cache, aux


_CE_CHUNK = 512


def _ce_from_hidden(params, x, labels, cfg: ModelConfig):
    """Chunked softmax-CE straight from final hidden states.

    Scans over sequence chunks with a checkpointed body so the full
    (B, S, V) logits tensor is never alive — decisive for 256k vocabs.
    Returns (sum_nll, count).
    """
    b, s, _ = x.shape
    chunk = min(_CE_CHUNK, s)
    if s % chunk:
        chunk = s  # odd lengths: single chunk

    def body(carry, xs):
        xc, lc = xs                         # (B, c, D), (B, c)
        logits = lm_head(params, xc, cfg)   # (B, c, V) f32, transient
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        nll = jnp.sum((logz - gold) * mask)
        return (carry[0] + nll, carry[1] + jnp.sum(mask)), None

    nc = s // chunk
    xs = (jnp.moveaxis(x.reshape(b, nc, chunk, -1), 1, 0),
          jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0))
    (nll, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.float32)), xs)
    return nll, cnt


def loss_fn(params, batch, *, cfg: ModelConfig, ctx: ParallelContext,
            aux_weight: float = 0.01):
    x, positions, enc_out = _embed_inputs(params, batch, cfg, ctx)
    x, _, aux = T.run_stack(params["stack"], x, cfg=cfg, ctx=ctx,
                            mode="train", positions=positions,
                            enc_out=enc_out)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    nll, cnt = _ce_from_hidden(params, x, batch["labels"], cfg)
    loss = nll / jnp.maximum(cnt, 1.0)
    total = loss + aux_weight * aux["moe_aux_loss"]
    return total, {"loss": loss, "moe_aux_loss": aux["moe_aux_loss"]}


# ---------------------------------------------------------------------------
# Serving entry points
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    return T.init_stack_cache(cfg, batch, max_seq, dtype=dtype)


def prefill(params, batch, cache, *, cfg: ModelConfig, ctx: ParallelContext):
    """Run the prompt through the stack, fill the cache.

    Returns (last_token_logits (B, V), cache')."""
    logits, new_cache, _ = forward(params, batch, cfg=cfg, ctx=ctx,
                                   mode="prefill", cache=cache)
    return logits[:, -1], new_cache


def decode_step(params, tokens, cache, pos, *, cfg: ModelConfig,
                ctx: ParallelContext, batch_extras=None):
    """One decode step. tokens: (B, 1); pos: scalar int32 current position.

    Returns (logits (B, V), cache')."""
    batch = {"tokens": tokens}
    if batch_extras:
        batch.update(batch_extras)
    if cfg.mrope:
        b = tokens.shape[0]
        batch.setdefault(
            "positions",
            jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(1, -1, 1),
                             (3, b, 1)))
    logits, new_cache, _ = forward(params, batch, cfg=cfg, ctx=ctx,
                                   mode="decode", cache=cache, pos=pos)
    return logits[:, -1], new_cache


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: InputShape, dtype="bfloat16"):
    """ShapeDtypeStructs for every model input of (cfg, shape).

    train:   {tokens, labels [, positions/vision_embeds/audio_frames]}
    prefill: {tokens [, extras]}
    decode:  {tokens (B,1)} — the KV cache itself comes from `cache_specs`.
    """
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    out = {}
    if kind == "train":
        out["tokens"] = _sds((b, s), jnp.int32)
        out["labels"] = _sds((b, s), jnp.int32)
    else:  # prefill feeds the whole prompt; decode one token at a time
        out["tokens"] = _sds((b, s) if kind == "prefill" else (b, 1),
                             jnp.int32)

    seq_here = 1 if kind == "decode" else s
    if cfg.mrope:
        out["positions"] = _sds((3, b, seq_here), jnp.int32)
    if cfg.vision_tokens and kind != "decode":
        out["vision_embeds"] = _sds((b, cfg.vision_tokens, cfg.d_model),
                                    dtype)
    if cfg.enc_dec:
        enc_len = s if kind == "train" else cfg.encoder_seq_len
        if kind != "decode":
            enc_len = min(s, 32768) if kind == "prefill" else enc_len
            out["audio_frames"] = _sds((b, enc_len, cfg.d_model), dtype)
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int, dtype="bfloat16"):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_seq, dtype=jnp.dtype(dtype)))


# ---------------------------------------------------------------------------
# Convenience: tiny random batch for smoke tests
# ---------------------------------------------------------------------------


def dummy_batch(key, cfg: ModelConfig, batch: int, seq: int,
                kind: str = "train"):
    ks = jax.random.split(key, 4)
    tokens = jax.random.randint(ks[0], (batch, seq if kind != "decode" else 1),
                                0, cfg.vocab_size)
    out = {"tokens": tokens}
    if kind == "train":
        out["labels"] = jax.random.randint(ks[1], (batch, seq), 0,
                                           cfg.vocab_size)
    seq_here = 1 if kind == "decode" else seq
    if cfg.mrope:
        out["positions"] = jnp.broadcast_to(
            jnp.arange(seq_here, dtype=jnp.int32), (3, batch, seq_here))
    if cfg.vision_tokens and kind != "decode":
        out["vision_embeds"] = jax.random.normal(
            ks[2], (batch, min(cfg.vision_tokens, seq // 2), cfg.d_model),
            jnp.bfloat16)
    if cfg.enc_dec and kind != "decode":
        out["audio_frames"] = jax.random.normal(
            ks[3], (batch, min(cfg.encoder_seq_len, seq), cfg.d_model),
            jnp.bfloat16)
    return out
