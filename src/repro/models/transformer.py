"""Block-program transformer assembly.

Every architecture is described by a *block program*: an optional ``head``
(unscanned leading layers), a ``superblock`` (the repeating unit — scanned
with stacked params so compile time is O(distinct layer kinds), not
O(layers)), and an optional ``tail``. Examples:

  llama / gemma-2b      head=[] sb=[attn]                n_sb = n_layers
  mixtral               sb=[attn(win, moe)]              n_sb = 32
  deepseek-moe          head=[attn(dense mlp)] sb=[attn(moe)] n_sb = 27
  gemma3                sb=[attn(win)×5, attn(full)]     n_sb = 8
  recurrentgemma        sb=[rec, rec, attn(win)] ×8 + tail=[rec, rec]
  mamba2                sb=[ssm]                         n_sb = 64
  whisper decoder       sb=[attn(full, cross)]           n_sb = 6
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models.parallel import ParallelContext


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                 # attn | mla | rec | ssm
    window: int = 0           # 0 -> full attention
    moe: bool = False
    cross: bool = False


@dataclasses.dataclass(frozen=True)
class BlockProgram:
    head: Tuple[LayerSpec, ...]
    superblock: Tuple[LayerSpec, ...]
    n_superblocks: int
    tail: Tuple[LayerSpec, ...]

    @property
    def n_layers(self) -> int:
        return (len(self.head) + len(self.superblock) * self.n_superblocks
                + len(self.tail))


def block_program(cfg: ModelConfig) -> BlockProgram:
    if cfg.family == "ssm":
        return BlockProgram((), (LayerSpec("ssm"),), cfg.n_layers, ())
    if cfg.rglru is not None:
        pat = tuple(
            LayerSpec("rec") if b == "rec"
            else LayerSpec("attn", window=cfg.sliding_window)
            for b in cfg.rglru.block_pattern)
        n_sb = cfg.n_layers // len(pat)
        tail_n = cfg.n_layers - n_sb * len(pat)
        return BlockProgram((), pat, n_sb, pat[:tail_n])
    kind = "mla" if cfg.mla is not None else "attn"
    if cfg.local_global_pattern != (0, 0):
        nl, ng = cfg.local_global_pattern
        per = nl + ng
        sb = tuple([LayerSpec(kind, window=cfg.sliding_window)] * nl
                   + [LayerSpec(kind)] * ng)
        n_sb = cfg.n_layers // per
        tail = sb[: cfg.n_layers - n_sb * per]   # e.g. gemma3-27b: 62 = 10·6+2
        return BlockProgram((), sb, n_sb, tail)
    moe = cfg.n_experts > 0
    spec = LayerSpec(kind, window=cfg.sliding_window, moe=moe,
                     cross=cfg.enc_dec)
    if moe and cfg.n_shared_experts:
        # DeepSeekMoE: first layer keeps a dense FFN
        head = (LayerSpec(kind, window=cfg.sliding_window, moe=False),)
        return BlockProgram(head, (spec,), cfg.n_layers - 1, ())
    return BlockProgram((), (spec,), cfg.n_layers, ())


# ---------------------------------------------------------------------------
# Per-layer params / cache
# ---------------------------------------------------------------------------


def init_layer(key, spec: LayerSpec, cfg: ModelConfig):
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    kmix, kmlp, kcross = jax.random.split(key, 3)
    p = {"norm1": L.init_rmsnorm(d)}
    if spec.kind == "attn":
        p["mix"] = L.init_attention(kmix, cfg)
    elif spec.kind == "mla":
        p["mix"] = L.init_mla(kmix, cfg)
    elif spec.kind == "rec":
        p["mix"] = RG.init_rglru(kmix, cfg)
    elif spec.kind == "ssm":
        p["mix"] = SSM.init_ssm(kmix, cfg)
        return p  # mamba blocks have no separate MLP sublayer
    else:
        raise ValueError(spec.kind)
    if spec.cross:
        p["norm_cross"] = L.init_rmsnorm(d)
        p["cross"] = L.init_attention(kcross, cfg, cross=True)
    p["norm2"] = L.init_rmsnorm(d)
    if spec.moe:
        p["moe"] = MOE.init_moe(kmlp, cfg)
    else:
        p["mlp"] = L.init_mlp(kmlp, d, cfg.d_ff, dt)
    return p


def init_layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                     max_seq: int, dtype=None):
    if spec.kind == "attn":
        c = L.init_kv_cache(cfg, batch, max_seq, window=spec.window,
                            dtype=dtype)
        if spec.cross:
            ad = L.attn_dims(cfg)
            shape = (batch, cfg.encoder_seq_len, ad.n_kv_heads, ad.head_dim)
            c["cross_k"] = jnp.zeros(shape, dtype or jnp.dtype(cfg.dtype))
            c["cross_v"] = jnp.zeros(shape, dtype or jnp.dtype(cfg.dtype))
        return c
    if spec.kind == "mla":
        return L.init_mla_cache(cfg, batch, max_seq, dtype=dtype)
    if spec.kind == "rec":
        return RG.init_rglru_cache(cfg, batch, dtype=dtype)
    if spec.kind == "ssm":
        return SSM.init_ssm_cache(cfg, batch, dtype=dtype)
    raise ValueError(spec.kind)


def apply_layer(spec: LayerSpec, p, x, *, cfg: ModelConfig,
                ctx: ParallelContext, mode: str, cache=None, pos=None,
                positions=None, enc_out=None):
    """Pre-norm residual block. Returns (x, new_cache, aux)."""
    aux = {}
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.kind == "attn":
        mix, new_cache = L.attention_layer(
            p["mix"], h, cfg=cfg, ctx=ctx, mode=mode, cache=cache, pos=pos,
            window=spec.window, positions=positions)
    elif spec.kind == "mla":
        mix, new_cache = L.mla_layer(p["mix"], h, cfg=cfg, ctx=ctx,
                                     mode=mode, cache=cache, pos=pos,
                                     positions=positions)
    elif spec.kind == "rec":
        mix, new_cache = RG.rglru_layer(p["mix"], h, cfg=cfg, ctx=ctx,
                                        mode=mode, cache=cache)
    elif spec.kind == "ssm":
        mix, new_cache = SSM.ssm_layer(p["mix"], h, cfg=cfg, ctx=ctx,
                                       mode=mode, cache=cache)
        return x + mix, new_cache, aux
    else:
        raise ValueError(spec.kind)
    x = x + mix
    x = ctx.shard_activation(x)

    use_cross = spec.cross and (
        enc_out is not None
        or (mode == "decode" and cache is not None and "cross_k" in cache))
    if use_cross:
        h = L.rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        enc_cache = None
        if cache is not None and mode == "decode":
            enc_cache = {"k": cache["cross_k"], "v": cache["cross_v"]}
        mix, cross_kv = L.attention_layer(
            p["cross"], h, cfg=cfg, ctx=ctx, mode=mode, cache=None,
            enc_out=enc_out, enc_cache=enc_cache, causal=False)
        x = x + mix
        if new_cache is not None:
            new_cache = dict(new_cache)
            new_cache["cross_k"] = cross_kv["k"]
            new_cache["cross_v"] = cross_kv["v"]

    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if spec.moe:
        moe_fn = {
            "capacity": MOE.moe_layer_capacity,
            "ep_a2a": MOE.moe_layer_ep_a2a,
        }.get(ctx.moe_dispatch,
              MOE.moe_layer_expert_parallel if ctx.moe_expert_parallel
              else MOE.moe_layer)
        out, moe_aux = moe_fn(p["moe"], h, cfg=cfg, ctx=ctx)
        aux.update(moe_aux)
    else:
        out = L.mlp(p["mlp"], h, cfg.activation, ctx)
    x = x + out
    x = ctx.shard_activation(x)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-stack init / cache
# ---------------------------------------------------------------------------


def init_stack(key, cfg: ModelConfig):
    prog = block_program(cfg)
    ks = jax.random.split(key, 3)
    head = tuple(init_layer(jax.random.fold_in(ks[0], i), spec, cfg)
                 for i, spec in enumerate(prog.head))
    sb = tuple(
        jax.vmap(lambda k: init_layer(k, spec, cfg))(
            jax.random.split(jax.random.fold_in(ks[1], i),
                             prog.n_superblocks))
        for i, spec in enumerate(prog.superblock))
    tail = tuple(init_layer(jax.random.fold_in(ks[2], i), spec, cfg)
                 for i, spec in enumerate(prog.tail))
    return {"head": head, "sb": sb, "tail": tail}


def init_stack_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    prog = block_program(cfg)

    def one(spec):
        return init_layer_cache(spec, cfg, batch, max_seq, dtype)

    def stacked(spec):
        c = one(spec)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (prog.n_superblocks,) + a.shape)
            if prog.n_superblocks else a, c)

    return {
        "head": tuple(one(s) for s in prog.head),
        "sb": tuple(stacked(s) for s in prog.superblock),
        "tail": tuple(one(s) for s in prog.tail),
    }


def run_stack(params, x, *, cfg: ModelConfig, ctx: ParallelContext,
              mode: str, cache=None, pos=None, positions=None, enc_out=None):
    """Apply head + scanned superblocks + tail. Returns (x, cache', aux)."""
    prog = block_program(cfg)
    aux_sum = jnp.zeros((), jnp.float32)
    new_head = []
    for i, spec in enumerate(prog.head):
        c = cache["head"][i] if cache is not None else None
        x, nc, aux = apply_layer(spec, params["head"][i], x, cfg=cfg,
                                 ctx=ctx, mode=mode, cache=c, pos=pos,
                                 positions=positions, enc_out=enc_out)
        new_head.append(nc)
        aux_sum += aux.get("moe_aux_loss", 0.0)

    # nested remat: checkpoint each layer inside the scanned superblock so
    # backward recomputes one layer at a time (not the whole superblock)
    layer_remat = ctx.remat and mode == "train"

    def one_layer(i, spec, p_i, x, c_i):
        def f(p_i, x):
            return apply_layer(spec, p_i, x, cfg=cfg, ctx=ctx, mode=mode,
                               cache=c_i, pos=pos, positions=positions,
                               enc_out=enc_out)
        if layer_remat:
            f = jax.checkpoint(f, static_argnums=())
        return f(p_i, x)

    def sb_body(carry, xs):
        x, aux_sum = carry
        p_list = xs[0]
        c_list = xs[1] if cache is not None else [None] * len(prog.superblock)
        new_cs = []
        for i, spec in enumerate(prog.superblock):
            x, nc, aux = one_layer(i, spec, p_list[i], x, c_list[i])
            new_cs.append(nc)
            aux_sum += aux.get("moe_aux_loss", 0.0)
        return (x, aux_sum), tuple(new_cs)

    if prog.n_superblocks:
        xs = (params["sb"], cache["sb"] if cache is not None else None)
        if cache is None:
            xs = (params["sb"], None)
        (x, aux_sum), new_sb = jax.lax.scan(sb_body, (x, aux_sum), xs)
    else:
        new_sb = ()

    new_tail = []
    for i, spec in enumerate(prog.tail):
        c = cache["tail"][i] if cache is not None else None
        x, nc, aux = apply_layer(spec, params["tail"][i], x, cfg=cfg,
                                 ctx=ctx, mode=mode, cache=c, pos=pos,
                                 positions=positions, enc_out=enc_out)
        new_tail.append(nc)
        aux_sum += aux.get("moe_aux_loss", 0.0)

    new_cache = None
    if cache is not None and mode in ("prefill", "decode"):
        new_cache = {"head": tuple(new_head), "sb": new_sb,
                     "tail": tuple(new_tail)}
    return x, new_cache, {"moe_aux_loss": aux_sum}


# ---------------------------------------------------------------------------
# Whisper encoder (bidirectional stack over stub frame embeddings)
# ---------------------------------------------------------------------------


def init_encoder(key, cfg: ModelConfig):
    spec = LayerSpec("attn")
    stacked = jax.vmap(lambda k: init_layer(k, spec, cfg))(
        jax.random.split(key, cfg.n_encoder_layers))
    return {"layers": stacked, "norm": L.init_rmsnorm(cfg.d_model)}


def sinusoidal_positions(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[None]


def run_encoder(params, frames, *, cfg: ModelConfig, ctx: ParallelContext):
    """frames: (B, S_enc, D) stub conv-frontend embeddings."""
    x = frames + sinusoidal_positions(frames.shape[1],
                                      cfg.d_model).astype(frames.dtype)

    def body(x, p):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        mix, _ = L.attention_layer(p["mix"], h, cfg=cfg, ctx=ctx,
                                   mode="encode", causal=False)
        x = x + mix
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h, cfg.activation, ctx)
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rmsnorm(params["norm"], x, cfg.norm_eps)
