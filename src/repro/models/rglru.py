"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The temporal-mixing block is: two parallel projections of the input —
a GeLU gate branch and a recurrence branch (causal conv then the RG-LRU
gated linear recurrence) — multiplied and projected back.

    r_t = sigmoid(w_a ⊙ u_t + b_a)            (recurrence gate)
    i_t = sigmoid(w_x ⊙ u_t + b_x)            (input gate)
    log a_t = -c · softplus(Λ) ⊙ r_t          (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ u_t)

Gates here are diagonal (per-channel) — Griffin uses block-diagonal heads;
the diagonal form is the same compute pattern with head_count = d_rnn and is
noted as an approximation in DESIGN.md. Training/prefill evaluates the
recurrence with `associative_scan` (log-depth, TPU-friendly — the GPU paper
uses a custom linear-scan kernel; on TPU the associative form keeps the VPU
busy without a bespoke kernel). Decode is the O(1) step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.parallel import ParallelContext

_C = 8.0


def _d_rnn(cfg: ModelConfig) -> int:
    return cfg.rglru.d_rnn or cfg.d_model


def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    dr = _d_rnn(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    return {
        "w_gate_in": dense_init(ks[0], (d, dr), dtype=dt),
        "w_rec_in": dense_init(ks[1], (d, dr), dtype=dt),
        "conv": dense_init(ks[2], (cfg.rglru.d_conv, dr), scale=0.2, dtype=dt),
        "w_a": jnp.zeros((dr,), jnp.float32),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_x": jnp.zeros((dr,), jnp.float32),
        "b_x": jnp.zeros((dr,), jnp.float32),
        # Λ init so that a ∈ [0.9, 0.999] at r = 1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jax.random.uniform(ks[3], (dr,), minval=0.9,
                                        maxval=0.999)) / _C)),
        "out_proj": dense_init(ks[4], (dr, d), dtype=dt),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=None):
    dr = _d_rnn(cfg)
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru.d_conv - 1, dr), dt),
    }


def _gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(p["w_a"] * uf + p["b_a"])
    i = jax.nn.sigmoid(p["w_x"] * uf + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) \
        * (i * uf)
    return a, gated_in


def _conv_causal(u, kernel, state=None):
    k = kernel.shape[0]
    pad = (jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
           if state is None else state.astype(u.dtype))
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * kernel[i] for i in range(k))
    return out, (up[:, -(k - 1):] if k > 1 else None)


def rglru_layer(p, x, *, cfg: ModelConfig, ctx: ParallelContext, mode: str,
                cache=None):
    """Full Griffin recurrent mixing layer. Returns (out, new_cache)."""
    b, s, _ = x.shape
    gate = jax.nn.gelu(x @ p["w_gate_in"], approximate=True)
    u = x @ p["w_rec_in"]

    if mode == "decode":
        u, conv_state = _conv_causal(u, p["conv"], cache["conv"])
        a, gi = _gates(p, u[:, 0])
        h = a * cache["h"] + gi
        y = h[:, None, :]
        new_cache = {"h": h, "conv": conv_state}
    else:
        u, conv_state = _conv_causal(u, p["conv"])
        a, gi = _gates(p, u)                      # (B, S, dr) each

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        a_s, h = jax.lax.associative_scan(combine, (a, gi), axis=1)
        y = h
        new_cache = None
        if mode == "prefill":
            new_cache = {"h": h[:, -1].astype(jnp.float32),
                         "conv": conv_state}

    y = (gate.astype(jnp.float32) * y).astype(x.dtype)
    return y @ p["out_proj"], new_cache
