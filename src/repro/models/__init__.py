from repro.models.parallel import ParallelContext, cpu_context
from repro.models.model import (
    cache_specs, decode_step, dummy_batch, forward, init_cache, init_params,
    input_specs, loss_fn, params_shapes, prefill,
)

__all__ = [
    "ParallelContext", "cpu_context", "cache_specs", "decode_step",
    "dummy_batch", "forward", "init_cache", "init_params", "input_specs",
    "loss_fn", "params_shapes", "prefill",
]
