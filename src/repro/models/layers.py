"""Core transformer layers: norms, RoPE/M-RoPE, gated MLPs, attention.

Everything is a pure function of (params-dict, inputs); parameter trees are
created by the matching ``init_*`` functions. Attention supports GQA/MQA,
sliding windows, rolling KV caches (keys stored pre-rotated so slot order is
irrelevant), MLA latent caches and encoder/cross attention.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.parallel import ParallelContext


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.bfloat16):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"])).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, d/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# M-RoPE (Qwen2-VL): head_dim/2 frequency slots are split into temporal /
# height / width sections, each rotated by its own position stream.
MROPE_SECTIONS = (2, 1, 1)   # relative split of the d/2 freq slots (t, h, w)


def apply_mrope(x, positions3, theta: float):
    """x: (B, S, H, D); positions3: (3, B, S) int32 (t, h, w)."""
    d = x.shape[-1]
    half = d // 2
    freqs = rope_freqs(d, theta)                       # (half,)
    total = sum(MROPE_SECTIONS)
    bounds = []
    acc = 0
    for s in MROPE_SECTIONS:
        acc += int(round(half * s / total))
        bounds.append(acc)
    bounds[-1] = half
    slot = jnp.arange(half)
    sec = (slot >= bounds[0]).astype(jnp.int32) + (slot >= bounds[1]).astype(jnp.int32)
    # pos per slot: pick t/h/w stream per frequency slot
    pos = jnp.take(positions3, sec, axis=0)            # (half, B, S) -> gather on axis 0
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)  # (B, S, half)
    ang = pos * freqs                                   # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, hidden: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, hidden), dtype=dtype),
        "w_up": dense_init(k2, (d, hidden), dtype=dtype),
        "w_down": dense_init(k3, (hidden, d), dtype=dtype),
    }


def mlp(p, x, activation: str, ctx: ParallelContext):
    gate = x @ p["w_gate"]
    up = x @ p["w_up"]
    act = jax.nn.gelu(gate, approximate=True) if activation == "geglu" \
        else jax.nn.silu(gate)
    h = act * up
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Attention (GQA / MQA, full / sliding-window, self / cross)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int


def attn_dims(cfg: ModelConfig) -> AttnDims:
    return AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d = cfg.d_model
    ad = attn_dims(cfg)
    dt = _dtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, ad.n_heads * ad.head_dim), dtype=dt),
        "wk": dense_init(k2, (d, ad.n_kv_heads * ad.head_dim), dtype=dt),
        "wv": dense_init(k3, (d, ad.n_kv_heads * ad.head_dim), dtype=dt),
        "wo": dense_init(k4, (ad.n_heads * ad.head_dim, d),
                         scale=1.0 / math.sqrt(ad.n_heads * ad.head_dim), dtype=dt),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _block_mask(qi, ki, block_q, block_k, q_offset, causal, window):
    qpos = qi * block_q + jnp.arange(block_q)[:, None] + q_offset
    kpos = ki * block_k + jnp.arange(block_k)[None, :]
    msk = jnp.ones((block_q, block_k), bool)
    if causal:
        msk = msk & (kpos <= qpos)
    if window > 0:
        msk = msk & (kpos > qpos - window)
    return msk


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_jnp(q, k, v, causal: bool = True, window: int = 0,
              scale: float = 1.0, q_offset: int = 0, block_q: int = 512,
              block_k: int = 512):
    """Memory-efficient (flash-style) attention in pure jnp.

    Double lax.scan over (q blocks × k blocks) with running-softmax state —
    peak memory is O(block_q · block_k) per (batch, head) instead of O(S²).
    The custom VJP implements the FlashAttention-2 backward: probabilities
    are recomputed from the saved per-row logsumexp instead of saving scan
    carries, so training memory stays O(S·D). The Pallas kernel replaces
    this path on real TPUs. q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D).
    """
    out, _ = _flash_fwd_impl(q, k, v, causal, window, scale, q_offset,
                             block_q, block_k)
    return out


def _flash_fwd_impl(q, k, v, causal, window, scale, q_offset, block_q,
                    block_k):
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k

    qr = jnp.moveaxis(q.reshape(b, nq, block_q, hkv, g, d), 1, 0)
    kr = jnp.moveaxis(k.reshape(b, nk, block_k, hkv, d), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, block_k, hkv, d), 1, 0)

    def q_step(_, qx):
        qi, qb = qx                     # (), (B, bq, Hkv, G, D)
        qb32 = qb.astype(jnp.float32)

        def k_step(carry, kx):
            m, l, acc = carry
            ki, kb, vb = kx
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb32,
                           kb.astype(jnp.float32)) * scale
            msk = _block_mask(qi, ki, block_q, block_k, q_offset, causal,
                              window)
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, hkv, g, block_q), -1e30, jnp.float32),
                jnp.zeros((b, hkv, g, block_q), jnp.float32),
                jnp.zeros((b, hkv, g, block_q, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            k_step, init, (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out, lse)         # (B, Hkv, G, bq, D), (B, Hkv, G, bq)

    _, (blocks, lses) = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    # blocks: (nq, B, Hkv, G, bq, D) -> (B, Sq, Hq, D)
    out = jnp.moveaxis(blocks, 0, 3)            # (B, Hkv, G, nq, bq, D)
    out = out.reshape(b, hkv, g, sq, d)         # (B, Hkv, G, Sq, D)
    out = jnp.moveaxis(out, 3, 1)               # (B, Sq, Hkv, G, D)
    out = out.reshape(b, sq, hq, d).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, hkv, g, sq)  # (B,Hkv,G,Sq)
    return out, lse


def _flash_fwd_rule(q, k, v, causal, window, scale, q_offset, block_q,
                    block_k):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, scale, q_offset,
                               block_q, block_k)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, scale, q_offset, block_q, block_k,
                    res, do):
    """FlashAttention-2 backward: p is recomputed from (q, k, lse)."""
    q, k, v, out, lse = res
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    nq, nk = sq // bq, sk // bk
    f32 = jnp.float32

    do32 = do.astype(f32)
    delta = jnp.einsum("bshd,bshd->bhs", do32,
                       out.astype(f32))                     # (B, Hq, Sq)
    delta = delta.reshape(b, hkv, g, sq)

    qr = jnp.moveaxis(
        q.reshape(b, nq, bq, hkv, g, d), 1, 0).astype(f32)
    dor = jnp.moveaxis(
        do32.reshape(b, nq, bq, hkv, g, d), 1, 0)
    lser = jnp.moveaxis(
        lse.reshape(b, hkv, g, nq, bq), 3, 0)
    deltar = jnp.moveaxis(
        delta.reshape(b, hkv, g, nq, bq), 3, 0)
    kr = jnp.moveaxis(k.reshape(b, nk, bk, hkv, d), 1, 0).astype(f32)
    vr = jnp.moveaxis(v.reshape(b, nk, bk, hkv, d), 1, 0).astype(f32)

    def q_step(carry, xs):
        dk_all, dv_all = carry          # (nk, B, bk, Hkv, D) f32 each
        qi, qb, dob, lseb, deltab = xs

        def k_step(c2, kxs):
            dqb = c2
            ki, kb, vb, dkb, dvb = kxs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb) * scale
            msk = _block_mask(qi, ki, bq, bk, q_offset, causal, window)
            s = jnp.where(msk[None, None, None], s, -1e30)
            p = jnp.exp(s - lseb[..., None])                # (B,Hkv,G,q,k)
            dv_new = dvb + jnp.einsum("bhgqk,bqhgd->bkhd", p, dob)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dob, vb)
            ds = p * (dp - deltab[..., None]) * scale
            dq_new = dqb + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb)
            dk_new = dkb + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qb)
            return dq_new, (dk_new, dv_new)

        dq0 = jnp.zeros((b, bq, hkv, g, d), f32)
        dqb, (dk_all, dv_all) = jax.lax.scan(
            k_step, dq0, (jnp.arange(nk), kr, vr, dk_all, dv_all))
        return (dk_all, dv_all), dqb

    dk0 = jnp.zeros((nk, b, bk, hkv, d), f32)
    dv0 = jnp.zeros((nk, b, bk, hkv, d), f32)
    (dk_all, dv_all), dq_blocks = jax.lax.scan(
        q_step, (dk0, dv0), (jnp.arange(nq), qr, dor, lser, deltar))

    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(b, sq, hq, d).astype(q.dtype)
    dk = jnp.moveaxis(dk_all, 0, 1).reshape(b, sk, hkv, d).astype(k.dtype)
    dv = jnp.moveaxis(dv_all, 0, 1).reshape(b, sk, hkv, d).astype(v.dtype)
    return dq, dk, dv


flash_jnp.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_jnp_call(q, k, v, *, causal: bool = True, window: int = 0,
                   scale: float = 1.0, q_offset: int = 0,
                   block_q: int = 512, block_k: int = 512):
    """Keyword-friendly wrapper (custom_vjp wants positional args)."""
    bq = min(block_q, q.shape[1])
    bk = min(block_k, k.shape[1])
    return flash_jnp(q, k, v, causal, window, scale, q_offset, bq, bk)


# threshold above which the jnp path switches to the chunked flash form
_CHUNK_THRESHOLD = 2048


def attn_op(q, k, v, *, causal: bool, window: int, scale: float,
            q_offset=0, ctx: ParallelContext):
    """Attention dispatch: Pallas kernel / chunked-jnp / plain sdpa."""
    sq, sk = q.shape[1], k.shape[1]
    if ctx.use_pallas:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    q_offset=q_offset, scale=scale)
    if max(sq, sk) > _CHUNK_THRESHOLD:
        return flash_jnp_call(q, k, v, causal=causal, window=window,
                              scale=scale, q_offset=q_offset)
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (kj <= qi)
    if window > 0:
        mask = mask & (kj > qi - window)
    return sdpa(q, k, v, mask[None, None, None], scale, ctx)


def sdpa(q, k, v, mask, scale: float, ctx: ParallelContext):
    """Reference scaled-dot-product attention with GQA head grouping.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D); mask: broadcastable to
    (B, Hkv, G, Sq, Sk) or (B, 1, 1, Sq, Sk). Swapped for the Pallas flash
    kernel on TPU via ``repro.kernels.ops.flash_attention`` when
    ``ctx.use_pallas``.
    """
    if ctx.use_pallas:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, mask=mask, scale=scale)
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def causal_mask(sq: int, sk: int, q_offset, window: int = 0):
    """(1, 1, 1, sq, sk) boolean mask; q global pos = q_offset + i."""
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window > 0:
        m = m & (ki > qi - window)
    return m[None, None, None]


def update_cache_seq(cache_arr, new, pos):
    """Write `new` (B, s, ...) into `cache_arr` (B, S, ...) at seq offset
    `pos` — scalar (aligned batch) or (B,) vector (continuous batching)."""
    if getattr(pos, "ndim", 0) == 0 or not hasattr(pos, "ndim"):
        return jax.lax.dynamic_update_slice_in_dim(cache_arr, new, pos, 1)
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(c, n, p, 0)
    )(cache_arr, new, pos)


def kv_cache_cp(n_kv_heads: int, cache_len: int, ctx: ParallelContext,
                batch: int = 0) -> bool:
    """Whether the decode KV cache is context-parallel (seq over `model`).

    Used when KV heads don't divide the model axis (MQA/GQA with few heads):
    sharding hd instead makes GSPMD all-gather the whole cache per step
    (measured 2.1 GB/step on gemma3-12b decode_32k). The CP path does a
    local partial attention per shard + cross-shard logsumexp combine.

    Only for batch-shardable decode: the batch=1 long-context shape shards
    the cache sequence over `data` instead (launch/shardings.py), and
    resharding it to `model` here would all-gather the cache every step.
    """
    if ctx.mesh is None or ctx.model_axis is None:
        return False
    if batch and (batch == 1 or batch % ctx.batch_size_divisor != 0):
        return False
    m = ctx.axis_size(ctx.model_axis)
    return m > 1 and n_kv_heads % m != 0 and cache_len % m == 0


def _decode_cp(q, cache, new_k, new_v, pos, window, scale,
               cfg: ModelConfig, ctx: ParallelContext):
    """Context-parallel single-token decode (flash-decoding across chips).

    Caches are sharded (B, S/m, Hkv, hd) along `model`; each shard updates
    its slot (if owned), computes partial (m, l, acc) and the shards combine
    with a numerically-stable logsumexp reduction (pmax + psum).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b = q.shape[0]
    ad_hq, hd = q.shape[2], q.shape[3]
    hkv = new_k.shape[2]
    g = ad_hq // hkv
    cache_len = cache["k"].shape[1]
    m_axis = ctx.model_axis

    def body(q, kc, vc, nk, nv, pos):
        b = q.shape[0]          # local batch inside the shard
        idx = jax.lax.axis_index(m_axis)
        s_loc = kc.shape[1]
        offset = idx * s_loc
        slot_g = pos % window if window and window <= cache_len else pos
        local = slot_g - offset
        in_range = (local >= 0) & (local < s_loc)
        lc = jnp.clip(local, 0, s_loc - 1)
        # masked one-row update: only the owning shard writes
        cur_k = jax.lax.dynamic_slice_in_dim(kc, lc, 1, 1)
        cur_v = jax.lax.dynamic_slice_in_dim(vc, lc, 1, 1)
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, jnp.where(in_range, nk, cur_k), lc, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, jnp.where(in_range, nv, cur_v), lc, 1)

        # local partial attention
        qg = q.reshape(b, 1, hkv, g, hd).astype(jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                       kc.astype(jnp.float32)) * scale
        slots = offset + jnp.arange(s_loc)
        valid = (slots < jnp.minimum(pos + 1, window)
                 if window and window <= cache_len else slots <= pos)
        s = jnp.where(valid[None, None, None, None, :], s, -1e30)
        m_loc = jnp.max(s, axis=-1)                        # (B,Hkv,G,1)
        p = jnp.exp(s - m_loc[..., None])
        l_loc = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))

        # cross-shard logsumexp combine
        m_g = jax.lax.pmax(m_loc, m_axis)
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, m_axis)
        acc_g = jax.lax.psum(acc * corr[..., None], m_axis)
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        out = out.reshape(b, 1, hkv * g, hd).astype(q.dtype)
        return out, kc, vc

    bspec = ctx.batch_spec if b % ctx.batch_size_divisor == 0 else None
    qspec = P(bspec, None, None, None)
    cspec = P(bspec, m_axis, None, None)
    out, kc, vc = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(qspec, cspec, cspec, qspec, qspec, P()),
        out_specs=(qspec, cspec, cspec),
        check_rep=False,
    )(q, cache["k"], cache["v"], new_k, new_v, pos)
    return out, {"k": kc, "v": vc}


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, window: int = 0,
                  dtype=None):
    ad = attn_dims(cfg)
    s = min(window, max_seq) if window else max_seq
    dt = dtype or _dtype(cfg)
    shape = (batch, s, ad.n_kv_heads, ad.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def attention_layer(p, x, *, cfg: ModelConfig, ctx: ParallelContext,
                    mode: str, cache=None, pos=None, window: int = 0,
                    positions=None, enc_out=None, enc_cache=None,
                    causal: bool = True):
    """One attention op (no residual/norm).

    mode: "train" | "prefill" | "decode" | "encode".
    Returns (out, new_cache). Keys are rotated *before* caching, so rolling
    window slots need no position bookkeeping.
    """
    ad = attn_dims(cfg)
    b, s, _ = x.shape
    scale = 1.0 / math.sqrt(ad.head_dim)

    q = _split_heads(x @ p["wq"], ad.n_heads, ad.head_dim)
    if enc_out is not None or enc_cache is not None:
        # cross attention: kv from encoder output (cached at prefill)
        if enc_cache is not None:
            k, v = enc_cache["k"], enc_cache["v"]
        else:
            k = _split_heads(enc_out @ p["wk"], ad.n_kv_heads, ad.head_dim)
            v = _split_heads(enc_out @ p["wv"], ad.n_kv_heads, ad.head_dim)
        out = attn_op(q, k, v, causal=False, window=0, scale=scale, ctx=ctx)
        out = out.reshape(b, s, ad.n_heads * ad.head_dim) @ p["wo"]
        return out, {"k": k, "v": v}

    k = _split_heads(x @ p["wk"], ad.n_kv_heads, ad.head_dim)
    v = _split_heads(x @ p["wv"], ad.n_kv_heads, ad.head_dim)

    if positions is None:
        positions = (
            jnp.broadcast_to(
                jnp.asarray(pos, jnp.int32).reshape(-1, 1), (b, s))
            if mode == "decode"
            else jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0))

    if cfg.mrope and positions.ndim == 3:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    elif causal:  # encoders use their own (or no) positional scheme
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode in ("train", "encode") or cache is None and mode == "prefill":
        out = attn_op(q, k, v, causal=causal, window=window, scale=scale,
                      ctx=ctx)
    elif mode == "prefill":
        out = attn_op(q, k, v, causal=True, window=window, scale=scale,
                      ctx=ctx)
        cache_len = cache["k"].shape[1]
        if cache_len >= s:
            newk = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1)
            newv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)
        else:  # rolling window smaller than the prompt: keep the last slots
            assert s % cache_len == 0, "window must divide prefill length"
            newk = jax.lax.slice_in_dim(k, s - cache_len, s, axis=1)
            newv = jax.lax.slice_in_dim(v, s - cache_len, s, axis=1)
        new_cache = {"k": newk, "v": newv}
    elif mode == "decode":
        cache_len = cache["k"].shape[1]
        if (kv_cache_cp(ad.n_kv_heads, cache_len, ctx, batch=b)
                and getattr(pos, "ndim", 0) == 0):
            out, new_cache = _decode_cp(q, cache, k, v, pos, window, scale,
                                        cfg, ctx)
            out = out.reshape(b, s, ad.n_heads * ad.head_dim) @ p["wo"]
            return out, new_cache
        slot = pos % window if window and window <= cache_len else pos
        newk = update_cache_seq(cache["k"], k, slot)
        newv = update_cache_seq(cache["v"], v, slot)
        new_cache = {"k": newk, "v": newv}
        if ctx.use_pallas and getattr(pos, "ndim", 0) == 0:
            from repro.kernels import ops as kops
            vl = (jnp.minimum(pos + 1, window)
                  if window and window <= cache_len else pos + 1)
            out = kops.decode_attention(q, newk, newv, vl, scale=scale)
        else:
            ki = jnp.arange(cache_len)[None, :]
            posv = jnp.asarray(pos).reshape(-1, 1)       # scalar or (B, 1)
            valid = (ki < jnp.minimum(posv + 1, window)
                     if window and window <= cache_len else ki <= posv)
            mask = valid[:, None, None, None, :]  # (B,Hkv,G,Sq,Sk) bcast
            out = sdpa(q, newk, newv, mask, scale, ctx)
    else:
        raise ValueError(mode)

    out = out.reshape(b, s, ad.n_heads * ad.head_dim) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig):
    m = cfg.mla
    d = cfg.d_model
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype=dt),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, cfg.n_heads * qk_hd), dtype=dt),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            dtype=dt),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank,
                                    cfg.n_heads * (m.qk_nope_head_dim
                                                   + m.v_head_dim)), dtype=dt),
        "wo": dense_init(ks[4], (cfg.n_heads * m.v_head_dim, d), dtype=dt),
        "q_norm": init_rmsnorm(m.q_lora_rank),
        "kv_norm": init_rmsnorm(m.kv_lora_rank),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    m = cfg.mla
    dt = dtype or _dtype(cfg)
    return {"ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dt),
            "krope": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dt)}


def mla_layer(p, x, *, cfg: ModelConfig, ctx: ParallelContext, mode: str,
              cache=None, pos=None, positions=None):
    """MLA with the compressed-latent KV cache (decode caches c_kv + k_rope).

    The latent cache is the paper-faithful memory saving: per token we store
    ``kv_lora_rank + qk_rope_head_dim`` floats instead of ``2·H·hd``.
    """
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = 1.0 / math.sqrt(qk_hd)

    if positions is None:
        positions = (
            jnp.broadcast_to(
                jnp.asarray(pos, jnp.int32).reshape(-1, 1), (b, s))
            if mode == "decode"
            else jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0))

    q = rmsnorm(p["q_norm"], x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(b, s, h, qk_hd)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["wkv_a"]
    ckv, krope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    ckv = rmsnorm(p["kv_norm"], ckv)
    krope = apply_rope(krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    new_cache = None
    if mode == "decode":
        ckv = update_cache_seq(cache["ckv"], ckv, pos)
        krope = update_cache_seq(cache["krope"], krope, pos)
        new_cache = {"ckv": ckv, "krope": krope}
        t = ckv.shape[1]
        posv = jnp.asarray(pos).reshape(-1, 1)
        valid = (jnp.arange(t)[None, :] <= posv)[:, None, None, :]  # b h q t
    elif mode == "prefill" and cache is not None:
        full_ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, 0, 1)
        full_krope = jax.lax.dynamic_update_slice_in_dim(cache["krope"],
                                                         krope, 0, 1)
        new_cache = {"ckv": full_ckv, "krope": full_krope}
        t = s
        valid = (jnp.arange(t)[None, :] <= jnp.arange(s)[:, None])[None, None]
    else:
        t = s
        valid = (jnp.arange(t)[None, :] <= jnp.arange(s)[:, None])[None, None]

    if mode == "decode":
        # weight-absorbed MLA decode (DeepSeek-V2 serving trick, §Perf):
        # attend in the r-dim latent space — the cache is never expanded to
        # per-head keys/values. Per step this reads the (S, r) latent once
        # instead of materializing (S, H, dn+dv).
        wkv = p["wkv_b"].reshape(m.kv_lora_rank, h,
                                 m.qk_nope_head_dim + m.v_head_dim)
        wk_b = wkv[:, :, :m.qk_nope_head_dim]
        wv_b = wkv[:, :, m.qk_nope_head_dim:]
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                           wk_b.astype(jnp.float32))
        logits = (jnp.einsum("bqhr,bkr->bhqk", q_lat,
                             ckv.astype(jnp.float32))
                  + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                               krope.astype(jnp.float32))) * scale
        logits = jnp.where(valid, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out_lat = jnp.einsum("bhqk,bkr->bqhr", probs,
                             ckv.astype(jnp.float32))
        out = jnp.einsum("bqhr,rhd->bqhd", out_lat,
                         wv_b.astype(jnp.float32)).astype(x.dtype)
    else:
        kv_up = ckv[:, :t] @ p["wkv_b"]
        kv_up = kv_up.reshape(b, t, h, m.qk_nope_head_dim + m.v_head_dim)
        k_nope, v = jnp.split(kv_up, [m.qk_nope_head_dim], axis=-1)
        # long-sequence path: fold the shared rope key into per-head keys so
        # MLA becomes standard attention with head_dim = nope + rope, then
        # go through the chunked/flash dispatch (O(S) memory)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :t, None, :],
                                      (b, t, h, m.qk_rope_head_dim))],
            axis=-1)
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                         (0, qk_hd - m.v_head_dim)))
        out = attn_op(q_full, k_full, vp, causal=True, window=0,
                      scale=scale, ctx=ctx)[..., :m.v_head_dim]
    out = out.reshape(b, s, h * m.v_head_dim) @ p["wo"]
    return out, new_cache
