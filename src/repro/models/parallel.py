"""Parallelism context: mesh-aware sharding helpers shared by all models.

All model code is written against a ``ParallelContext``. With ``mesh=None``
(CPU smoke tests) every helper is a no-op; under the production mesh the same
code paths emit explicit ``with_sharding_constraint``s, so the single model
definition serves 1-device tests and the 512-chip dry-run alike.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)   # ("pod","data") on multi-pod
    model_axis: Optional[str] = "model"
    cp_axis: Optional[str] = None   # context-parallel axis for long-KV decode
    use_pallas: bool = False        # pallas kernels need a real TPU backend
    remat: bool = True              # activation checkpointing in train_step
    moe_expert_parallel: bool = False  # §Perf layout lever (EXPERIMENTS.md)
    moe_dispatch: str = "dense"        # dense | capacity (§Perf lever)

    # ------------------------------------------------------------------
    def axis_size(self, name: Optional[str]) -> int:
        if self.mesh is None or name is None:
            return 1
        return self.mesh.shape[name]

    @property
    def batch_size_divisor(self) -> int:
        out = 1
        for a in self.batch_axes:
            out *= self.axis_size(a)
        return out

    def sharding(self, *spec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*spec))

    def constrain(self, x, *spec):
        """with_sharding_constraint; no-op when there is no mesh."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    # shorthand specs -----------------------------------------------------
    @property
    def batch_spec(self):
        """Spec entry that shards a batch dimension."""
        return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]

    def model_spec_if(self, dim_size: int):
        """'model' if dim divisible by the model-axis size, else None."""
        m = self.axis_size(self.model_axis)
        if m > 1 and dim_size % m == 0:
            return self.model_axis
        return None

    def shard_batch(self, x):
        """Shard the leading (batch) dim; replicate the rest."""
        if self.mesh is None:
            return x
        bsz = x.shape[0]
        spec = [None] * x.ndim
        if bsz % self.batch_size_divisor == 0:
            spec[0] = self.batch_spec
        return self.constrain(x, *spec)

    def shard_activation(self, x):
        """(B, S, D) activations at residual boundaries.

        Batch over the data axes; sequence over the model axis when it
        divides (Megatron-style sequence parallelism) — the residual stream
        saved per scanned layer for backward then costs 1/|model| of the
        replicated footprint. GSPMD inserts the all-gather at each layer's
        first matmul and the reduce-scatter after the residual add.
        """
        if self.mesh is None:
            return x
        spec = [None] * x.ndim
        if x.shape[0] % self.batch_size_divisor == 0:
            spec[0] = self.batch_spec
        m = self.axis_size(self.model_axis)
        if (x.ndim == 3 and m > 1 and x.shape[1] > 1
                and x.shape[1] % m == 0):
            spec[1] = self.model_axis
        return self.constrain(x, *spec)


def cpu_context(**kw) -> ParallelContext:
    return ParallelContext(mesh=None, batch_axes=(), model_axis=None, **kw)


# ---------------------------------------------------------------------------
# Name-based parameter sharding rules (tensor parallelism over "model")
# ---------------------------------------------------------------------------

# Each rule: (leaf-name, ndim) -> index of the dim sharded over "model".
# Column-parallel projections shard their output dim; row-parallel their
# input dim, so matmul chains avoid resharding (Megatron layout).
_COL_PARALLEL = {
    "wq", "wk", "wv", "w_up", "w_gate", "wq_b", "wkv_b", "wx", "wz",
    "w_rec_in", "w_gate_in",
}
_ROW_PARALLEL = {"wo", "w_down", "out_proj"}
_VOCAB_PARALLEL = {"embed", "unembed"}
_EXPERT_STACKED_COL = {"we_up", "we_gate"}   # (E, D, F): shard F
_EXPERT_STACKED_ROW = {"we_down"}            # (E, F, D): shard F


def spec_for_param(path: Sequence, leaf) -> P:
    """PartitionSpec for one parameter leaf, by name + rank.

    Divisibility is NOT checked here — ``apply_param_specs`` downgrades any
    non-divisible entry to replication against a concrete mesh.
    """
    name = None
    for entry in reversed(path):
        if hasattr(entry, "key"):
            name = entry.key
            break
        if hasattr(entry, "name"):
            name = entry.name
            break
    nd = leaf.ndim if hasattr(leaf, "ndim") else 0
    if name is None or nd == 0:
        return P()
    spec = [None] * nd
    if name in _VOCAB_PARALLEL and nd >= 2:
        spec[nd - 2] = "model"
    elif name in _COL_PARALLEL:
        spec[nd - 1] = "model"
    elif name in _ROW_PARALLEL:
        spec[nd - 2] = "model"
    elif name in _EXPERT_STACKED_COL:
        spec[nd - 1] = "model"
    elif name in _EXPERT_STACKED_ROW:
        spec[nd - 2] = "model"
    return P(*spec)


def param_specs(params_shapes, ctx: ParallelContext):
    """Tree of PartitionSpecs matching a params(-shapes) pytree."""

    def fix(path, leaf):
        spec = spec_for_param(path, leaf)
        if ctx.mesh is None:
            return P()
        out = []
        for dim, entry in enumerate(spec):
            if entry is None:
                out.append(None)
            else:
                ax = ctx.axis_size(entry)
                out.append(entry if leaf.shape[dim] % ax == 0 else None)
        # pad (P() shorter than rank is fine, but keep explicit)
        while len(out) < leaf.ndim:
            out.append(None)
        return P(*out)

    return jax.tree_util.tree_map_with_path(fix, params_shapes)


def param_shardings(params_shapes, ctx: ParallelContext):
    specs = param_specs(params_shapes, ctx)
    if ctx.mesh is None:
        return specs
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_shardings(params_shapes, ctx: ParallelContext):
    """ZeRO-1-style specs for optimizer moments: the tensor-parallel param
    spec plus the data axes on the first additionally-divisible dim. Adam
    math is elementwise, so moments never need gathering — only the final
    param delta is resharded (one all-gather per step)."""
    specs = param_specs(params_shapes, ctx)
    if ctx.mesh is None:
        return specs
    dsize = 1
    for a in ctx.batch_axes:
        dsize *= ctx.axis_size(a)

    def widen(path, leaf):
        spec = list(_lookup_spec(specs, path))
        while len(spec) < leaf.ndim:
            spec.append(None)
        for dim in range(leaf.ndim):
            if spec[dim] is None and leaf.shape[dim] % dsize == 0 \
                    and leaf.shape[dim] >= dsize:
                spec[dim] = self_batch = ctx.batch_spec
                break
        return NamedSharding(ctx.mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(widen, params_shapes)


def _lookup_spec(specs, path):
    node = specs
    for entry in path:
        key = getattr(entry, "key", getattr(entry, "idx", None))
        node = node[key]
    return node
