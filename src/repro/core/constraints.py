"""Constraint-satisfaction mechanism (paper Eq. 2 constraints + Eq. 3 f(y)).

C1: per-service processing time within its requirement D^Δ
C2: assigned compute within the server's available compute
C3: assigned uplink bandwidth within the server's available bandwidth
C4: exactly one server per service (structural — enforced by the action
    space, every action assigns exactly one server).
C5: assigned KV-cache blocks within the server's free block pool — only
    evaluated when the runtime models KV memory (`view.kv_total_blocks`);
    otherwise the slack is a vacuous 1.0 and nothing changes.

`f(y) = min(normalized slacks)`; a scheme satisfies all constraints iff
f(y) >= 0. The same function is used (a) as the feasibility filter before
arm selection and (b) as the reward shaping term λ·f(y) in Eq. 4.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.cluster.workload import ServiceRequest
from repro.core.api import Allocation, ClusterView


@dataclasses.dataclass(frozen=True)
class ConstraintSlacks:
    time: float        # (D^Δ − D̂) / D^Δ
    compute: float     # (C_max − ΣC) / C_max
    bandwidth: float   # (B_max − ΣB) / B_max
    kv: float = 1.0    # (KV_free − KV_need) / KV_total; 1.0 = unmodeled

    @property
    def f(self) -> float:
        """Eq. 3: minimum normalized slack."""
        return min(self.time, self.compute, self.bandwidth, self.kv)

    @property
    def satisfied(self) -> bool:
        return self.f >= 0.0


def evaluate_constraints(req: ServiceRequest, j: int, view: ClusterView,
                         predicted_time: Optional[float] = None,
                         alloc: Optional[Allocation] = None,
                         ) -> ConstraintSlacks:
    """Normalized slacks for assigning `req` to server `j` given residuals.

    `predicted_time` lets CS-UCB substitute its *learned* processing-time
    estimate for C1; the default is the nominal analytic predictor.
    `alloc` evaluates feasibility *at that allocation*: a slow DVFS tier
    stretches both the C1 completion estimate and the C2 lane-seconds the
    request needs — a slow tier that still fits is feasible (and cheaper),
    which is exactly the arm space the tier-aware CS-UCB searches.
    """
    spec = view.specs[j]
    d_hat = (view.predict_total(req, j, alloc) if predicted_time is None
             else predicted_time)
    time_slack = (req.deadline - d_hat) / req.deadline

    # C2 — compute: lane-seconds already committed within the deadline
    # horizon vs. available lane-seconds. A slowed (low-tier / sub-lane)
    # allocation occupies its lane for the stretched window, so it needs
    # proportionally more of the horizon.
    horizon = req.deadline
    lanes = view.lane_free[j]
    committed = sum(max(lf - view.t, 0.0) for lf in lanes)
    capacity = spec.max_concurrency * horizon
    need = view.predict_infer(req, j, alloc)
    compute_slack = (capacity - committed - need) / capacity

    # C3 — bandwidth: uplink backlog + this payload vs. deliverable bits
    # within the deadline.
    backlog_s = max(view.uplink_free_at[j] - view.t, 0.0)
    bw = spec.bandwidth * view.bw_factor[j]
    need_bits = req.payload_bytes * 8.0
    cap_bits = bw * horizon
    used_bits = backlog_s * bw
    bw_slack = (cap_bits - used_bits - need_bits) / cap_bits

    # C5 — KV memory: blocks this request would pin (prompt + decode)
    # vs the server's free pool. A request already holding pages on j
    # (preserved across a preemption) needs nothing new — resuming is free.
    kv_slack = 1.0
    totals = view.kv_total_blocks
    if totals is not None and totals[j] > 0:
        if getattr(req, "kv_server", -1) == j \
                and getattr(req, "kv_blocks", 0) > 0:
            kv_need = 0
        else:
            kv_need = spec.kv_blocks_needed(req.prompt_tokens,
                                            req.output_tokens)
            # shared-prefix pages already resident on j shrink the
            # request's unique footprint — a prefix hit charges only the
            # suffix blocks, so the slack reflects what admission will
            # actually claim
            hit_fn = getattr(view, "prefix_hit_tokens", None)
            if hit_fn is not None:
                kv_need -= hit_fn(req, j) // max(spec.kv_block_tokens, 1)
        kv_slack = (view.kv_free_blocks[j] - kv_need) / totals[j]

    return ConstraintSlacks(time=time_slack, compute=compute_slack,
                            bandwidth=bw_slack, kv=kv_slack)
