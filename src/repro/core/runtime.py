"""Event-driven runtime API: one event loop behind `drive_slot`.

The scheduling contract (`repro.core.api`) says *what* a policy decides;
this module says *when* the runtime asks. Both runtimes — the cluster
`Simulator` and the live `PerLLMServer` — are `Runtime`s: they own a
heap-ordered `EventLoop` of typed events, build a **fresh** `ClusterView`
at each arrival's actual timestamp, call `policy.assign` through
`drive_slot`, apply commit/deferral themselves, and emit `feedback` at the
request's true completion time. Arrivals, bandwidth fluctuation, dispatch
deferral and completions are all just event streams, so scenario shaping
(bursty/diurnal/trace arrivals, mid-run bandwidth drops) composes with any
runtime for free via `Scenario` hooks.

Event taxonomy
    Arrival          one or more requests hit the front door
    Deferred         a routed request's batching window opened
    TxDone           a request's uplink transfer completed
    InferStart       a batch lane began prefill/decode for a request
    InferDone        inference finished; the realized Outcome exists
    Reject           admission control shed the request (Decision.admit
                     False): the runtime emits a rejected Outcome with an
                     SLO-violation cost instead of queueing it forever
    Preempt          a running victim's batch lane is returned
                     (Decision.preempt_victim); its remaining decode
                     tokens are requeued as a new Arrival
    KvMigrate        a request's preserved KV pages finished transferring
                     across the link topology to another server
                     (Decision.migrate_kv); the request resumes there
                     with zero re-prefill
    BandwidthChange  a link's bandwidth factor changed (model resample or
                     scenario-injected multiplicative scale, per server
                     index or per named topology link)

Ordering: the loop pops by (time, kind-priority, insertion seq). Equal-time
ties resolve completions before new arrivals (feedback precedes the next
assign) and FIFO within a kind — which is what keeps shared uplinks FIFO
when arrival events are inserted out of order.

Layering: like `core.api`, this module is structural — it knows Decisions,
views and events, never server specs or engines. Physics (transmission,
lanes, energy) live in each runtime's subclass hooks.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.api import ClusterView, Decision, drive_slot, ensure_policy


# ---------------------------------------------------------------------------
# Events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Event:
    """Base event: something happens at `time` (seconds)."""

    time: float
    priority = 5            # class-level tie-break; lower pops first


@dataclasses.dataclass(frozen=True)
class BandwidthChange(Event):
    """A link's bandwidth factor changes.

    `scale` maps server index -> multiplicative overlay on the bandwidth
    model's own factor (scenario-injected congestion/outage); `link_scale`
    does the same for named `LinkTopology` links (runtimes without a
    topology map unknown names onto nothing); `resample` marks the
    runtime's periodic re-draw of the fluctuating model itself.
    """

    scale: Optional[Dict[int, float]] = None
    link_scale: Optional[Dict[str, float]] = None
    resample: bool = False
    priority = 0


@dataclasses.dataclass(frozen=True)
class Reject(Event):
    """Admission control shed `request` at `time` (Decision.admit False).

    The runtime's `on_reject` emits a rejected Outcome — success False,
    an SLO-violation processing-time cost, zero server energy — so the
    policy's `feedback` still fires and aggregate metrics count the miss.
    """

    request: Any = None
    decision: Optional[Decision] = None
    priority = 1


@dataclasses.dataclass(frozen=True)
class Preempt(Event):
    """`request` (the preemptor) reclaims `victim`'s batch lane at `time`.

    Handled synchronously inside `Runtime.place`, *before* the preemptor
    dispatches, so the victim's lane is provably free by the preemptor's
    `InferStart`. The runtime requeues the victim's remaining decode
    tokens as a new Arrival at `time`. `drop_kv` is the KV-resume info
    (from `Decision.preempt_drop_kv`): False keeps the victim's KV pages
    resident — a same-server requeue then resumes without re-prefill —
    while True frees them immediately (memory-pressure eviction).
    """

    victim: Any = None          # victim request sid
    request: Any = None         # the preemptor
    decision: Optional[Decision] = None
    drop_kv: bool = False
    priority = 1


@dataclasses.dataclass(frozen=True)
class InferDone(Event):
    """Inference finished at `time`; feedback fires here."""

    request: Any = None
    context: Any = None     # runtime-private realization payload
    priority = 1


@dataclasses.dataclass(frozen=True)
class InferStart(Event):
    """A batch lane starts working. For the live server this is also the
    engine's decode tick (one real `ServingEngine.step`)."""

    request: Any = None
    server: int = -1
    context: Any = None
    priority = 2


@dataclasses.dataclass(frozen=True)
class KvMigrate(Event):
    """`request`'s preserved KV pages finished their cross-server
    transfer at `time` (booked on every link of the migration path when
    the move was decided — `Decision.migrate_kv`). The runtime's
    `on_kv_migrate` frees the source pages and resumes the request on
    the destination with zero re-prefill. `context` is runtime-private
    (source/destination bookkeeping)."""

    request: Any = None
    decision: Optional[Decision] = None
    context: Any = None
    priority = 2


@dataclasses.dataclass(frozen=True)
class TxDone(Event):
    """Uplink transfer complete; the request is on the server."""

    request: Any = None
    decision: Optional[Decision] = None
    context: Any = None
    priority = 3


@dataclasses.dataclass(frozen=True)
class Deferred(Event):
    """A routed request's dispatch window opened (`Decision.defer_until`)."""

    request: Any = None
    decision: Optional[Decision] = None
    priority = 4


@dataclasses.dataclass(frozen=True)
class Arrival(Event):
    """Requests arrive. Pure event-driven runtimes push one request per
    Arrival at its true timestamp; the slotted-compat mode pushes one
    Arrival per slot carrying the slot's whole batch (quantized arrivals),
    which is exactly the legacy semantics expressed as an event."""

    requests: Tuple[Any, ...] = ()
    slot_index: int = -1    # slotted-compat bookkeeping; -1 in event mode
    priority = 5


# ---------------------------------------------------------------------------
# EventLoop — a stable heap of events
# ---------------------------------------------------------------------------


class EventLoop:
    """Min-heap of events ordered by (time, kind priority, FIFO seq)."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, int, Event]] = []
        self._seq = 0

    def push(self, event: Event) -> None:
        heapq.heappush(self._heap,
                       (event.time, event.priority, self._seq, event))
        self._seq += 1

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[-1]

    def __iter__(self):
        """Pending events, in no particular order (inspection only)."""
        return (item[-1] for item in self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


# ---------------------------------------------------------------------------
# Runtime — the event-driven side of the scheduling contract
# ---------------------------------------------------------------------------


class Runtime:
    """Owns the loop and the `ClusterView`; drives the policy.

    The generic machinery here is the contract's runtime half: per Arrival
    it builds a fresh view at the event's actual timestamp, collects one
    Decision per request via `drive_slot` (which commits residuals between
    requests), and applies each Decision's deferral by scheduling a
    `Deferred` event. Subclasses supply the physics:

        build_view(t)        fresh ClusterView from real state at time t
        dispatch(t, req, d)  start the request's transmission/execution
        on_tx_done / on_infer_start / on_infer_done / on_bandwidth_change
    """

    def __init__(self, policy, trace=None) -> None:
        self.policy = ensure_policy(policy)
        self.loop = EventLoop()
        self.clock = 0.0
        # optional repro.obs.TraceRecorder; every emission site is
        # guarded by `if self.trace is not None` so the hot path is
        # untouched when tracing is off (docs/observability.md)
        self.trace = trace

    # ---------------- physics hooks (subclass) ---------------------------
    def build_view(self, t: float) -> ClusterView:
        raise NotImplementedError

    def dispatch(self, t: float, request, decision: Decision) -> None:
        raise NotImplementedError

    def on_tx_done(self, ev: TxDone) -> None:
        pass

    def on_infer_start(self, ev: InferStart) -> None:
        pass

    def on_infer_done(self, ev: InferDone) -> None:
        pass

    def on_bandwidth_change(self, ev: BandwidthChange) -> None:
        pass

    def on_reject(self, ev: Reject) -> None:
        pass

    def on_preempt(self, ev: Preempt) -> None:
        pass

    def on_kv_migrate(self, ev: "KvMigrate") -> None:
        pass

    # ---------------- generic driving ------------------------------------
    def slot_index(self, t: float) -> int:
        """Slot ordinal forwarded to `drive_slot` (diagnostics only);
        event-driven runtimes have no slots, so default to whole
        seconds."""
        return int(t)

    def on_arrival(self, ev: Arrival) -> None:
        view = self.build_view(ev.time)
        t_slot = ev.slot_index if ev.slot_index >= 0 \
            else self.slot_index(ev.time)
        decisions = drive_slot(self.policy, ev.requests, view, t_slot)
        for req, d in zip(ev.requests, decisions, strict=True):
            self.place(ev.time, req, d)

    def place(self, t: float, request, decision: Decision) -> None:
        """Apply one Decision: reject, preempt-then-dispatch, or defer.

        Rejections and preemptions are routed through `handle` as typed
        events — synchronously, so a preempted victim's lane is free
        before the preemptor's dispatch books it, and a rejection's
        feedback precedes any later arrival's `assign`."""
        if not decision.admit:
            self.handle(Reject(t, request=request, decision=decision))
            return
        if decision.preempt_victim is not None:
            self.handle(Preempt(t, victim=decision.preempt_victim,
                                request=request, decision=decision,
                                drop_kv=decision.preempt_drop_kv))
        when = max(t, decision.defer_until)
        if when > t:
            self.defer(t, when, request, decision)
        else:
            self.dispatch(t, request, decision)

    def defer(self, t: float, when: float, request,
              decision: Decision) -> None:
        self.loop.push(Deferred(when, request=request, decision=decision))

    def on_deferred(self, ev: Deferred) -> None:
        self.dispatch(ev.time, ev.request, ev.decision)

    _HANDLERS = {
        Arrival: "on_arrival", Deferred: "on_deferred",
        TxDone: "on_tx_done", InferStart: "on_infer_start",
        InferDone: "on_infer_done", BandwidthChange: "on_bandwidth_change",
        Reject: "on_reject", Preempt: "on_preempt",
        KvMigrate: "on_kv_migrate",
    }

    def handle(self, ev: Event) -> None:
        self.clock = max(self.clock, ev.time)
        for klass in type(ev).__mro__:       # subclassed events route to
            name = self._HANDLERS.get(klass)  # their base handler
            if name is not None:
                getattr(self, name)(ev)
                return
        raise TypeError(f"no handler for event {type(ev).__name__}")

    def step_event(self) -> Optional[Event]:
        """Pop and handle the next event; None when the loop is empty."""
        if not self.loop:
            return None
        ev = self.loop.pop()
        self.handle(ev)
        return ev

    def drain(self, max_events: int = 10_000_000) -> None:
        """Run until only housekeeping (BandwidthChange) events remain."""
        for _ in range(max_events):
            if not self.loop:
                return
            if all(isinstance(e, BandwidthChange) for e in self.loop):
                return
            self.handle(self.loop.pop())
        raise RuntimeError(f"runtime did not drain in {max_events} events")


# ---------------------------------------------------------------------------
# Scenario — event streams that shape a run
# ---------------------------------------------------------------------------


class Scenario:
    """Hooks that shape a run's arrival and bandwidth event streams.

    `arrival_times(n, rate, rng)` returns n monotone arrival timestamps —
    the workload generator calls it so a scenario changes *when* services
    arrive. `shape_requests(services, rng)` may additionally reshape what
    they ask for (prompt/payload mixes — e.g. `kv-pressure`'s long-context
    documents); the default is a no-op, so scenarios that only retime
    arrivals keep request draws bit-identical to the baseline.
    `bandwidth_events(horizon, n_servers)` returns `BandwidthChange`
    events the runtime injects (multiplicative overlay on the bandwidth
    model), enabling mid-run congestion/outage studies in either runtime
    mode.
    """

    name = "poisson"

    def arrival_times(self, n: int, rate: float, rng) -> np.ndarray:
        return np.cumsum(rng.exponential(1.0 / rate, size=n))

    def shape_requests(self, services: Sequence[Any], rng) -> None:
        """Mutate per-request requirements in place (default: none)."""

    def bandwidth_events(self, horizon: float,
                         n_servers: int) -> List[BandwidthChange]:
        return []


class PoissonScenario(Scenario):
    """The default stationary Poisson process (the paper's §4.2 workload)."""


class BurstScenario(Scenario):
    """Markov-modulated Poisson: calm/burst phases with exponential dwell
    times. The burst rate is `burst_factor`× the calm rate, with both
    scaled so the long-run (time-average) rate stays `rate` for any
    `burst_factor` and dwell mix."""

    name = "burst"

    def __init__(self, burst_factor: float = 4.0, calm_dwell: float = 20.0,
                 burst_dwell: float = 5.0):
        assert burst_factor > 0
        self.burst_factor = burst_factor
        self.calm_dwell = calm_dwell
        self.burst_dwell = burst_dwell

    def arrival_times(self, n: int, rate: float, rng) -> np.ndarray:
        # expected time in burst; solve frac*B + (1-frac)*C = rate with
        # B = burst_factor*C, so the long-run average rate is preserved
        frac = self.burst_dwell / (self.burst_dwell + self.calm_dwell)
        calm_rate = rate / (frac * self.burst_factor + (1.0 - frac))
        burst_rate = self.burst_factor * calm_rate
        times = np.empty(n)
        t, i = 0.0, 0
        burst = False
        phase_end = rng.exponential(self.calm_dwell)
        while i < n:
            r = burst_rate if burst else calm_rate
            t_next = t + rng.exponential(1.0 / r)
            if t_next >= phase_end:
                t = phase_end
                burst = not burst
                phase_end = t + rng.exponential(
                    self.burst_dwell if burst else self.calm_dwell)
                continue
            t = t_next
            times[i] = t
            i += 1
        return times


class DiurnalScenario(Scenario):
    """Sinusoidal rate modulation (a compressed day/night cycle), sampled
    by thinning a Poisson process at the peak rate."""

    name = "diurnal"

    def __init__(self, period: float = 120.0, depth: float = 0.8):
        assert 0.0 <= depth <= 1.0
        self.period = period
        self.depth = depth

    def arrival_times(self, n: int, rate: float, rng) -> np.ndarray:
        peak = rate * (1.0 + self.depth)
        times = np.empty(n)
        t, i = 0.0, 0
        while i < n:
            t += rng.exponential(1.0 / peak)
            lam = rate * (1.0 + self.depth
                          * np.sin(2.0 * np.pi * t / self.period))
            if rng.uniform() * peak <= lam:
                times[i] = t
                i += 1
        return times


class TraceScenario(Scenario):
    """Trace-driven arrivals: replay explicit timestamps (cycled if the
    requested workload outgrows the trace)."""

    name = "trace"

    def __init__(self, times: Sequence[float]):
        if len(times) == 0:
            raise ValueError("TraceScenario needs at least one timestamp")
        self.times = np.sort(np.asarray(times, dtype=float))

    def arrival_times(self, n: int, rate: float, rng) -> np.ndarray:
        reps = -(-n // len(self.times))          # ceil division
        span = float(self.times[-1]) + 1.0 / max(rate, 1e-9)
        tiled = np.concatenate([self.times + k * span for k in range(reps)])
        return tiled[:n]


class OverloadScenario(Scenario):
    """Sustained λ above aggregate service capacity.

    Arrivals are Poisson at `factor ×` the nominal rate for the whole run
    — unlike `burst` there is no calm phase to drain the backlog, so
    queues grow without bound and *every* admitted-by-default request
    eventually misses its SLO. This is the regime where admission control
    is the only way to keep admitted-request SLOs (paper §3.3's
    constraint-satisfaction claim under overload)."""

    name = "overload"

    def __init__(self, factor: float = 3.0):
        assert factor > 0
        self.factor = factor

    def arrival_times(self, n: int, rate: float, rng) -> np.ndarray:
        return np.cumsum(rng.exponential(1.0 / (rate * self.factor), size=n))


class CloudOutageScenario(Scenario):
    """Mid-run cloud-uplink outage on the link topology.

    Scales the shared `edge-cloud` backhaul (and the `user-cloud` access
    link) to `scale` over the middle `[start_frac, stop_frac]` window —
    the link-topology generalization of `bwdrop`: with a `LinkTopology`
    every cloud-bound transfer contends on the dying backhaul; without
    one the per-server fallback scales the last server's link."""

    name = "cloud-outage"

    def __init__(self, scale: float = 0.05, start_frac: float = 0.3,
                 stop_frac: float = 0.6):
        self.scale = scale
        self.start_frac = start_frac
        self.stop_frac = stop_frac

    def bandwidth_events(self, horizon: float,
                         n_servers: int) -> List[BandwidthChange]:
        links_down = {"edge-cloud": self.scale, "user-cloud": self.scale}
        links_up = {name: 1.0 for name in links_down}
        j = n_servers - 1          # per-server fallback: the cloud
        return [
            BandwidthChange(self.start_frac * horizon,
                            scale={j: self.scale}, link_scale=links_down),
            BandwidthChange(self.stop_frac * horizon,
                            scale={j: 1.0}, link_scale=links_up),
        ]


class KVPressureScenario(Scenario):
    """Long-context load that exhausts KV *memory* before bandwidth.

    Prompts are stretched by `prompt_scale` (context-document services —
    the workload class that pins KV blocks for its whole lifetime) while
    payloads shrink by `payload_scale` (the documents are token-cheap to
    ship but block-expensive to hold), and arrivals run at a mild
    `factor ×` the nominal rate. On a testbed whose `ServerSpec`s model a
    block pool (`kv_blocks > 0`), admission and preemption are driven by
    `kv_free_blocks` exhaustion rather than uplink congestion — the edge
    regime the paged cache exists for. Without KV-modeled specs it is just
    a heavier, low-payload workload.
    """

    name = "kv-pressure"

    def __init__(self, prompt_scale: float = 4.0, payload_scale: float = 0.1,
                 factor: float = 1.5, max_prompt: int = 8192):
        assert prompt_scale > 0 and factor > 0
        self.prompt_scale = prompt_scale
        self.payload_scale = payload_scale
        self.factor = factor
        self.max_prompt = max_prompt

    def arrival_times(self, n: int, rate: float, rng) -> np.ndarray:
        return np.cumsum(rng.exponential(1.0 / (rate * self.factor), size=n))

    def shape_requests(self, services, rng) -> None:
        for r in services:
            r.prompt_tokens = int(min(r.prompt_tokens * self.prompt_scale,
                                      self.max_prompt))
            r.payload_bytes = float(r.payload_bytes * self.payload_scale)


class SharedPrefixScenario(Scenario):
    """System-prompt reuse: the ROADMAP's "millions of users" regime where
    most requests open with one of a small set of shared system prompts.

    Each request draws a prompt pool from a Zipf-like law over `n_pools`
    pools (rank-`zipf_a` weights — a few pools dominate, a long tail is
    nearly unique) and *prepends* a `prefix_tokens`-token system prompt:
    `prompt_tokens` grows by the prefix and the request carries
    (`prefix_id`, `prefix_tokens`) so KV-modeled runtimes know which
    admissions share resident pages. Arrivals stay the baseline Poisson
    process, so wins against the unshared baseline are request-for-request
    comparable.
    """

    name = "shared-prefix"

    def __init__(self, n_pools: int = 32, zipf_a: float = 1.2,
                 prefix_tokens: int = 256):
        assert n_pools > 0 and prefix_tokens > 0
        self.n_pools = n_pools
        self.zipf_a = zipf_a
        self.prefix_tokens = prefix_tokens

    def shape_requests(self, services, rng) -> None:
        w = 1.0 / np.arange(1, self.n_pools + 1) ** self.zipf_a
        pools = rng.choice(self.n_pools, size=len(services), p=w / w.sum())
        for r, pid in zip(services, pools, strict=True):
            r.prefix_id = int(pid)
            r.prefix_tokens = self.prefix_tokens
            r.prompt_tokens = int(r.prompt_tokens) + self.prefix_tokens


class BandwidthDropScenario(Scenario):
    """Poisson arrivals plus a mid-run uplink degradation: the last server
    (the cloud, by testbed convention) drops to `scale` over the middle
    `[start_frac, stop_frac]` window of the run — the paper's Fig. 2 cloud
    congestion, injected as BandwidthChange events."""

    name = "bwdrop"

    def __init__(self, scale: float = 0.35, start_frac: float = 0.3,
                 stop_frac: float = 0.6, server: int = -1):
        self.scale = scale
        self.start_frac = start_frac
        self.stop_frac = stop_frac
        self.server = server

    def bandwidth_events(self, horizon: float,
                         n_servers: int) -> List[BandwidthChange]:
        j = self.server % n_servers
        return [
            BandwidthChange(self.start_frac * horizon, scale={j: self.scale}),
            BandwidthChange(self.stop_frac * horizon, scale={j: 1.0}),
        ]


# ---------------------------------------------------------------------------
# Scenario registry (same idiom as the policy registry)
# ---------------------------------------------------------------------------

_SCENARIOS: Dict[str, Tuple[str, Callable[..., Scenario]]] = {}


def _normalize(name: str) -> str:
    return "".join(c for c in name.lower() if c.isalnum())


def register_scenario(name: str, factory: Optional[Callable] = None):
    """Register a scenario factory under `name` (usable as a decorator)."""
    def _register(fac):
        _SCENARIOS[_normalize(name)] = (name, fac)
        return fac

    return _register(factory) if factory is not None else _register


def available_scenarios() -> List[str]:
    return sorted(display for display, _ in _SCENARIOS.values())


def make_scenario(name: str, **kwargs) -> Scenario:
    key = _normalize(name)
    if key not in _SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; available: "
                       + ", ".join(available_scenarios()))
    return _SCENARIOS[key][1](**kwargs)


register_scenario("poisson", PoissonScenario)
register_scenario("burst", BurstScenario)
register_scenario("diurnal", DiurnalScenario)
register_scenario("trace", TraceScenario)
register_scenario("bwdrop", BandwidthDropScenario)
register_scenario("overload", OverloadScenario)
register_scenario("cloud-outage", CloudOutageScenario)
register_scenario("kv-pressure", KVPressureScenario)
register_scenario("shared-prefix", SharedPrefixScenario)


__all__ = [
    "Arrival", "BandwidthChange", "BandwidthDropScenario", "BurstScenario",
    "CloudOutageScenario", "Deferred", "DiurnalScenario", "Event",
    "EventLoop", "InferDone", "InferStart", "KVPressureScenario",
    "KvMigrate", "OverloadScenario", "PoissonScenario", "Preempt",
    "Reject", "Runtime", "Scenario", "SharedPrefixScenario",
    "TraceScenario", "TxDone", "available_scenarios", "make_scenario",
    "register_scenario",
]
