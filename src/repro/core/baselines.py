"""Baseline schedulers the paper compares against (§4.1).

All three are `SchedulingPolicy` implementations: they return `Decision`
objects and never touch the view's residuals themselves (the runtime
commits between `assign` calls, so within-slot load observations still
reflect the policy's own earlier placements).

FineInfer [He et al., EuroMLSys'24] — cloud-only with *deferred continuous
batching*: requests are held and dispatched at batching-window boundaries
(expressed as `Decision.defer_until`, applied by the runtime).

AGOD [Du et al., TMC'24] — edge-only; the diffusion-model + DRL offloading
policy is represented by its decision rule: an ε-greedy learned value per
(class, edge) with least-loaded tie-breaking (the published behavior:
learns edge selection, cannot use the cloud).

RewardlessGuidance [Fang et al., VTC'23] — edge-cloud active inference:
picks the server minimizing expected free energy = normalized *nominal*
expected completion time + normalized expected energy. No reward learning
(that is the method's premise) — so it cannot adapt to hidden efficiency or
congestion dynamics, which is exactly what the paper exploits.

All three baselines are *allocation-blind*: their Decisions carry the
default nominal `Allocation` (nominal DVFS tier, full lane/uplink shares),
because none of the published methods models per-request compute
allocation. On a tiered testbed that is precisely the energy PerLLM's
(class, server, tier) arm space gets to claw back.
"""
from __future__ import annotations

import math

import numpy as np

from repro.cluster.workload import N_CLASSES
from repro.core.api import ClusterView, Decision, SchedulingPolicy, \
    register_policy


@register_policy("fineinfer")
class FineInfer(SchedulingPolicy):
    name = "FineInfer"

    def __init__(self, n_servers: int, batch_window: float = 1.0, **_):
        self.n_servers = n_servers
        self.cloud = n_servers - 1          # convention: last server = cloud
        self.batch_window = batch_window

    def assign(self, req, view: ClusterView) -> Decision:
        # deferred batching: requests are held until the next batching
        # window boundary before dispatch
        defer = math.ceil(req.arrival / self.batch_window) * self.batch_window
        return Decision(server=self.cloud, defer_until=defer)


@register_policy("agod")
class AGOD(SchedulingPolicy):
    name = "AGOD"

    def __init__(self, n_servers: int, epsilon: float = 0.08, seed: int = 0,
                 **_):
        self.n_edges = n_servers - 1
        self.eps = epsilon
        self.rng = np.random.default_rng(seed)
        self.value = np.zeros((N_CLASSES, self.n_edges))
        self.count = np.zeros((N_CLASSES, self.n_edges), np.int64)

    def assign(self, req, view: ClusterView) -> Decision:
        if self.rng.uniform() < self.eps:
            j = int(self.rng.integers(self.n_edges))
        else:
            load = np.array([min(view.lane_free[e]) for e
                             in range(self.n_edges)])
            score = self.value[req.class_id] - 0.2 * (load - view.t)
            j = int(np.argmax(score))
        return Decision(server=j)

    def feedback(self, req, out) -> None:
        if out.server >= self.n_edges:
            return
        cls = req.class_id
        r = 1.0 if out.success else -1.0
        self.count[cls, out.server] += 1
        n = self.count[cls, out.server]
        self.value[cls, out.server] += (r - self.value[cls, out.server]) / n


@register_policy("rewardless-guidance")
class RewardlessGuidance(SchedulingPolicy):
    name = "RewardlessGuidance"

    def __init__(self, n_servers: int, w_time: float = 0.6,
                 w_energy: float = 0.4, belief_rate: float = 0.006,
                 temp: float = 0.5, seed: int = 0, **_):
        self.n_servers = n_servers
        self.w_time = w_time
        self.w_energy = w_energy
        # active inference keeps an *epistemic* (exploration) drive: actions
        # are sampled from the EFE softmax rather than argmin'd, and the
        # drive never anneals (there is no reward signal to converge on)
        self.temp = temp
        self.rng = np.random.default_rng(seed)
        # active-inference state belief: slow EMA of observed lag vs the
        # nominal model (beliefs about hidden state, not reward learning)
        self.belief_rate = belief_rate
        self.lag_belief = np.zeros(n_servers)

    def _expected_energy(self, req, j: int, view: ClusterView) -> float:
        spec = view.specs[j]
        t_inf = view.predict_infer(req, j)
        t_tx = req.payload_bytes * 8.0 / spec.bandwidth
        # nominal-tier dynamic energy — the one formula runtimes charge
        return spec.infer_energy(t_inf) + spec.tx_power * t_tx

    def assign(self, req, view: ClusterView) -> Decision:
        # expected free energy from *static nominal* models (rewardless:
        # no learning, no live congestion state — the method's premise)
        efe = []
        for j in range(self.n_servers):
            spec = view.specs[j]
            t_stat = (view.predict_infer(req, j)
                      + req.payload_bytes * 8.0 / spec.bandwidth
                      + self.lag_belief[j])
            t = t_stat / max(req.deadline, 1e-9)
            e = self._expected_energy(req, j, view) / 500.0
            efe.append(self.w_time * t + self.w_energy * e)
        efe = np.asarray(efe)
        p = np.exp(-(efe - efe.min()) / self.temp)
        p /= p.sum()
        return Decision(server=int(self.rng.choice(self.n_servers, p=p)))

    def feedback(self, req, out) -> None:
        j = out.server
        spec_nominal = out.infer_time  # realized; belief tracks extra lag
        lag = max(out.processing_time - spec_nominal, 0.0)
        self.lag_belief[j] += self.belief_rate * (lag - self.lag_belief[j])


def make_baselines(n_servers: int, seed: int = 0):
    return [FineInfer(n_servers), AGOD(n_servers, seed=seed),
            RewardlessGuidance(n_servers)]
