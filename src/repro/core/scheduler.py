"""PerLLM scheduler: CS-UCB service scheduling + resource allocation.

Implements paper Algorithm 1 as a `SchedulingPolicy`. Per slot, arrivals
are assigned sequentially (building the super arm): for each service the
constraint-satisfaction mechanism filters the feasible servers using
*learned* processing-time estimates and CS-UCB picks the feasible arm with
the best UCB score. The runtime commits each `Decision`'s residuals before
asking for the next one, so later services in the same slot see the reduced
capacity (C2/C3 accounting).

Observed outcomes arrive via `feedback`: reward = −energy_norm + λ·f(y)
(Eq. 4), plus a violation-severity update that drives the penalty term P(t).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from repro.cluster.workload import N_CLASSES
from repro.core.api import ClusterView, Decision, SchedulingPolicy, \
    register_policy
from repro.core.bandit import CSUCB, CSUCBParams
from repro.core.constraints import ConstraintSlacks, evaluate_constraints

# Energy normalization scale (J) — a typical per-service energy magnitude;
# keeps the two reward terms in Eq. 4 comparable.
E_SCALE = 100.0


@register_policy("perllm")
class PerLLMScheduler(SchedulingPolicy):
    """`admission=True` turns the C1 failover into admission control: when
    no server can satisfy the constraints, the request is shed
    (`Decision.admit=False`) instead of being dumped on the least-bad
    server — under sustained overload this is what keeps *admitted*
    requests inside their SLOs. `preempt=True` additionally lets an
    otherwise-infeasible request reclaim a lane from a running task that
    is already doomed to miss its own deadline (`Decision.preempt_victim`,
    event-driven runtimes only)."""

    name = "PerLLM"

    def __init__(self, n_servers: int, params: Optional[CSUCBParams] = None,
                 seed: int = 0, admission: bool = False,
                 preempt: bool = False):
        self.n_servers = n_servers
        self.admission = admission
        self.preempt = preempt
        self.bandit = CSUCB(N_CLASSES, n_servers, params, seed=seed)
        # learned per-(class, server) processing-time ratio vs the nominal
        # analytic estimate (captures hidden efficiency + congestion)
        self.time_ratio = np.ones((N_CLASSES, n_servers), np.float64)
        self.ratio_count = np.zeros((N_CLASSES, n_servers), np.int64)
        # prediction-error second moment -> pessimistic C1 margin
        self.err_var = np.zeros((N_CLASSES, n_servers), np.float64)
        # per-(class, server) inference-time ratio (hidden efficiency)
        self.infer_ratio = np.ones((N_CLASSES, n_servers), np.float64)
        self._pending_slacks: Dict[int, ConstraintSlacks] = {}
        self._nominal_pred: Dict[int, float] = {}
        self._last_nominal_infer: Dict[int, float] = {}

    # ------------------------------------------------------------------
    # C1 safety margin: guards against realization noise and within-slot
    # queue drift when checking the processing-time constraint.
    SAFETY = 1.05

    def predicted_time(self, req, j: int, view: ClusterView) -> float:
        cls = req.class_id
        d_hat = (view.predict_tx(req, j) + view.predict_queue(req, j)
                 + view.predict_infer(req, j) * self.infer_ratio[cls, j])
        margin = math.sqrt(self.err_var[cls, j])
        return d_hat * self.time_ratio[cls, j] * self.SAFETY + margin

    def assign(self, req, view: ClusterView) -> Decision:
        slacks: List[ConstraintSlacks] = []
        feasible = np.zeros(self.n_servers, bool)
        for j in range(self.n_servers):
            d_hat = self.predicted_time(req, j, view)
            s = evaluate_constraints(req, j, view, predicted_time=d_hat)
            slacks.append(s)
            feasible[j] = s.satisfied
        admit = True
        victim = None
        drop_kv = False
        kv_home = getattr(req, "kv_server", -1)
        if 0 <= kv_home < self.n_servers and feasible[kv_home] \
                and getattr(req, "kv_blocks", 0) > 0:
            # KV affinity: this request's pages survived a preemption on
            # kv_home — resuming there skips the whole re-prefill, which
            # no other feasible server can offer. Requeues are rare, so
            # bypassing the bandit here costs negligible exploration.
            j = kv_home
        elif feasible.any():
            j = self.bandit.select(req.class_id, feasible)
        else:
            # C1 failover (paper §3.1): no feasible server -> assign to
            # the most resource-rich one, i.e. minimum predicted time
            j = int(np.argmin([self.predicted_time(req, jj, view)
                               for jj in range(self.n_servers)]))
            if self.preempt:
                victim = self._find_victim(req, view)
            if victim is not None:
                j = victim.server
                # KV-resume info: when the victim's server is out of KV
                # *memory* (not just lanes), evicting the lane alone frees
                # nothing — drop the victim's pages so the preemptor's
                # blocks fit, accepting the victim's re-prefill elsewhere
                drop_kv = slacks[j].kv < 0.0
            elif self.admission:
                # admission control: shedding beats dumping doomed work on
                # the least-bad server — the runtime emits the rejected
                # Outcome (SLO-violation cost) and frees no capacity
                admit = False
        self._pending_slacks[req.sid] = slacks[j]
        self._nominal_pred[req.sid] = self.predicted_time(req, j, view) \
            / self.SAFETY
        self._last_nominal_infer[req.sid] = view.predict_infer(req, j)
        return Decision(server=j,
                        infer_scale=float(self.infer_ratio[req.class_id, j]),
                        slacks=slacks[j], admit=admit,
                        preempt_victim=None if victim is None
                        else victim.sid,
                        preempt_drop_kv=drop_kv)

    def _find_victim(self, req, view: ClusterView):
        """A running task worth preempting for `req`, or None.

        Only *doomed* tasks qualify (their estimated finish already misses
        their own deadline — evicting them costs no extra SLO violation),
        and only where `req` could actually meet its deadline once the
        lane is free (transmission + inference, no lane wait). Among
        qualifying victims, reclaim the most-doomed lane first."""
        if not view.running:
            return None
        cls = req.class_id
        best, best_over = None, 0.0
        for tasks in view.running:
            for task in tasks:
                if not task.doomed or task.sid == req.sid:
                    continue
                j = task.server
                d_no_queue = (view.predict_tx(req, j)
                              + view.predict_infer(req, j)
                              * self.infer_ratio[cls, j]) \
                    * self.time_ratio[cls, j] * self.SAFETY
                if d_no_queue > req.deadline:
                    continue
                over = task.finish_est - task.deadline_at
                if over > best_over:
                    best, best_over = task, over
        return best

    def feedback(self, req, out) -> None:
        slacks = self._pending_slacks.pop(req.sid, None)
        nominal = self._nominal_pred.pop(req.sid, None)
        if getattr(out, "rejected", False):
            # the SLO-violation cost of a shed request is a system metric,
            # not an observation: nothing ran, so there is no realized
            # time/energy to learn from (and a zero infer_time would
            # poison the efficiency estimators)
            self._last_nominal_infer.pop(req.sid, None)
            return
        cls, j = req.class_id, out.server

        # realized constraint slack (C1 realized; C2/C3 from decision time)
        time_slack = (req.deadline - out.processing_time) / req.deadline
        f_y = min(time_slack,
                  slacks.compute if slacks else 0.0,
                  slacks.bandwidth if slacks else 0.0,
                  slacks.kv if slacks else 1.0)
        reward = self.bandit.shaped_reward(out.energy / E_SCALE, f_y)
        violation = max(-f_y, 0.0)
        self.bandit.update(cls, j, reward, violation)

        # update learned estimators: per-server efficiency (from pure
        # inference time), per-class residual bias, and error variance
        nom_inf = out.infer_time  # realized
        # realized/nominal inference ratio: EMA, robust to noise
        # (predict_infer is deterministic given the request)
        self.infer_ratio[cls, j] += 0.1 * (
            out.infer_time / max(self._last_nominal_infer.pop(req.sid, nom_inf),
                                 1e-9) - self.infer_ratio[cls, j])
        if nominal and nominal > 0:
            ratio = out.processing_time / nominal
            self.ratio_count[cls, j] += 1
            n = self.ratio_count[cls, j]
            self.time_ratio[cls, j] += (ratio - self.time_ratio[cls, j]) / n
            err = out.processing_time - nominal * self.time_ratio[cls, j]
            self.err_var[cls, j] += (err * err - self.err_var[cls, j]) \
                / max(n, 1)

    # ------------------------------------------------------------------
    @property
    def regret_trace(self) -> List[float]:
        return self.bandit.regret_trace
