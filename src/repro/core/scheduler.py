"""PerLLM scheduler: CS-UCB service scheduling + resource allocation.

Implements paper Algorithm 1 as a `SchedulingPolicy`. Per slot, arrivals
are assigned sequentially (building the super arm): for each service the
constraint-satisfaction mechanism filters the feasible (server, DVFS tier)
pairs using *learned* processing-time estimates, and CS-UCB picks the
feasible arm with the best UCB score — placement and compute allocation
are one joint decision (paper Eq. 1). The runtime commits each `Decision`'s
residuals before asking for the next one, so later services in the same
slot see the reduced capacity (C2/C3 accounting).

Tier selection is where the energy story lives: a slower tier stretches
inference (time ∝ 1/f) but cuts dynamic power cubically, so energy per
token falls as f² — the bandit's reward (−energy + λ·f(y)⁻) converges to
the *cheapest* feasible allocation per (class, server), not the fastest.
On a single-tier testbed the arm space degenerates to (class, server) and
the trajectory is bit-exact with the placement-only scheduler.

Observed outcomes arrive via `feedback`: reward = −energy_norm + λ·f(y)
(Eq. 4, f(y) clipped into [−1, 0] — see `repro.core.bandit`), plus a
violation-severity update that drives the penalty term P(t).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from repro.cluster.workload import N_CLASSES
from repro.core.api import Allocation, ClusterView, Decision, \
    SchedulingPolicy, register_policy
from repro.core.bandit import CSUCB, CSUCBParams
from repro.core.constraints import ConstraintSlacks, evaluate_constraints

# Energy normalization scale (J) — a typical per-service energy magnitude;
# keeps the two reward terms in Eq. 4 comparable. Calibrated so the
# energy differences between DVFS tiers of one server are visible above
# the UCB exploration term (with f(y) clipped into [−1, 0] the energy
# term is what ranks feasible arms).
E_SCALE = 60.0


@register_policy("perllm")
class PerLLMScheduler(SchedulingPolicy):
    """`admission=True` turns the C1 failover into admission control: when
    no server can satisfy the constraints, the request is shed
    (`Decision.admit=False`) instead of being dumped on the least-bad
    server — under sustained overload this is what keeps *admitted*
    requests inside their SLOs. `preempt=True` additionally lets an
    otherwise-infeasible request reclaim a lane from a running task that
    is already doomed to miss its own deadline (`Decision.preempt_victim`,
    event-driven runtimes only). `tiers=False` pins every decision to the
    nominal DVFS tier — the fixed-frequency comparator the energy
    benchmarks (and the nominal-tier golden test) run against."""

    name = "PerLLM"

    def __init__(self, n_servers: int, params: Optional[CSUCBParams] = None,
                 seed: int = 0, admission: bool = False,
                 preempt: bool = False, tiers: bool = True):
        self.n_servers = n_servers
        self.admission = admission
        self.preempt = preempt
        self.tiers = tiers
        self._seed = seed
        self._params = params
        self.bandit = CSUCB(N_CLASSES, n_servers, params, seed=seed)
        # learned per-(class, server) processing-time ratio vs the nominal
        # analytic estimate (captures hidden efficiency + congestion)
        self.time_ratio = np.ones((N_CLASSES, n_servers), np.float64)
        self.ratio_count = np.zeros((N_CLASSES, n_servers), np.int64)
        # prediction-error second moment -> pessimistic C1 margin
        self.err_var = np.zeros((N_CLASSES, n_servers), np.float64)
        # per-(class, server) inference-time ratio (hidden efficiency)
        self.infer_ratio = np.ones((N_CLASSES, n_servers), np.float64)
        self._pending_slacks: Dict[int, ConstraintSlacks] = {}
        self._pending_tier: Dict[int, int] = {}
        self._nominal_pred: Dict[int, float] = {}
        self._last_nominal_infer: Dict[int, float] = {}
        # static per-(server, tier) arm table, built on first view contact
        self._arm_cache = None
        self._init_mirrors()

    # ------------------------------------------------------------------
    # C1 safety margin: guards against realization noise and within-slot
    # queue drift when checking the processing-time constraint.
    SAFETY = 1.05
    # Non-nominal DVFS tiers deliberately spend deadline slack for energy,
    # so they get a stricter bar than bare feasibility: the predicted time
    # must leave TIER_GUARD relative headroom, and the (class, server)
    # estimators must have seen a few calibration outcomes first (slowing
    # a server down before its hidden efficiency is known converts
    # prediction error straight into SLO misses).
    TIER_GUARD = 0.05
    TIER_WARMUP = 3
    # ... and the server must retain lane-capacity headroom (C2 slack):
    # downtiering occupies the lane longer, and on a loaded server that
    # stolen lane-time surfaces as queue drift for *later, nominal-tier*
    # requests — the misses show up far from the arm that caused them, so
    # the bandit's own penalty cannot learn them away.
    TIER_COMPUTE_GUARD = 0.25
    # Adaptive component: the time-headroom bar rises with the
    # (class, server)'s observed violation severity (the bandit's V̄,
    # congestion-coupled across tiers), so a host whose requests have been
    # missing deadlines stops being downtiered until it cools off.
    TIER_VIOL_GAIN = 2.0
    # Allocation-aware admission: with DVFS tiers in play, committed lane
    # windows are stretched and queue-drift error correspondingly larger,
    # so an admission-enabled tiered scheduler demands this much positive
    # C1 headroom on the arm it admits on — slack is spent on energy, not
    # on risky admits. Inactive without `admission` or on untiered specs.
    TIER_ADMIT_GUARD = 0.02

    def _tier_table(self, view: ClusterView) -> List[List[int]]:
        """Per-server candidate tier indices (just the nominal tier when
        tier selection is disabled), sizing the bandit's arm space on
        first contact with the cluster's specs."""
        if not self.tiers:
            return [[spec_nominal(view.specs[j])]
                    for j in range(self.n_servers)]
        table = [list(range(view.n_tiers(j)))
                 for j in range(self.n_servers)]
        width = max(len(t) for t in table)
        if width != self.bandit.n_tiers:
            # first view revealed the real tier count: rebuild the (so far
            # unpulled) bandit over the (class, server, tier) arm space,
            # carrying over any attached trace recorder
            trace = self.bandit.trace
            self.bandit = CSUCB(N_CLASSES, self.n_servers, self._params,
                                seed=self._seed, n_tiers=width)
            self.bandit.trace = trace
        return table

    def _arm_table(self, view: ClusterView):
        """Static arm geometry for a cluster: the tier table plus, per
        (server, slot), the reusable Allocation object, its time-stretch
        denominator freq·lane_share, and that denominator's reciprocal
        (the C1 margin stretch). Allocation objects and these floats are
        pure functions of the specs, so they are computed once per cluster
        instead of once per arrival — keyed on the identity of
        `view.specs`, which every view of one simulation shares."""
        cache = self._arm_cache
        if cache is not None and cache[0] is view.specs:
            return cache
        table = self._tier_table(view)   # may rebuild the bandit
        width = self.bandit.n_tiers
        nominals = [spec_nominal(view.specs[j])
                    for j in range(self.n_servers)]
        allocs, denoms, inv_stretch = [], [], []
        svc = []
        for j in range(self.n_servers):
            spec = view.specs[j]
            row_a: List[Allocation] = []
            row_d: List[float] = []
            row_i: List[float] = []
            for k in table[j]:
                a = Allocation(freq_tier=k)
                d = a.freq(spec) * a.lane_share
                row_a.append(a)
                row_d.append(d)
                row_i.append(1.0 / d)
            allocs.append(row_a)
            denoms.append(row_d)
            inv_stretch.append(row_i)
            # nominal service_time(p, o) unrolled to (2A·p)/flops + o·dst
            # — the same left-associated ops as prefill_time + decode_time
            # at tier −1 (whose ÷tier_freq is an exact ÷1.0), with the
            # request-independent factors hoisted
            svc.append((2.0 * spec._active_params, spec.flops,
                        spec.decode_step_time(1, -1)))
        self._init_mirrors()   # bandit may have been swapped above
        cache = (view.specs, table, width, nominals, allocs, denoms,
                 inv_stretch, svc)
        self._arm_cache = cache
        return cache

    # ------------------------------------------------------------------
    # Scalar read-mirrors of the learned numpy state. The numpy arrays
    # stay the single source of truth (every update in `feedback` touches
    # them exactly as before); the mirrors are plain-python copies
    # refreshed per feedback so the per-arrival hot loop in `assign` reads
    # floats instead of paying numpy scalar-indexing overhead ~100× per
    # arrival. Values are bit-identical by construction.
    def _init_mirrors(self) -> None:
        b = self.bandit
        self._b_mean = b.mean.tolist()
        self._b_count = b.count.tolist()
        self._b_viol = b.violation.tolist()
        self._viol_mean = [[float(np.mean(b.violation[c, j]))
                           for j in range(self.n_servers)]
                          for c in range(N_CLASSES)]
        self._warm = [[bool(self.ratio_count[c, j] >= self.TIER_WARMUP)
                       for j in range(self.n_servers)]
                      for c in range(N_CLASSES)]
        self._time_ratio_f = self.time_ratio.tolist()
        self._err_sqrt = np.sqrt(self.err_var).tolist()
        self._infer_ratio_f = self.infer_ratio.tolist()

    def _refresh_mirrors(self, cls: int, j: int) -> None:
        b = self.bandit
        self._b_mean[cls][j] = b.mean[cls, j].tolist()
        self._b_count[cls][j] = b.count[cls, j].tolist()
        self._b_viol[cls][j] = vrow = b.violation[cls, j].tolist()
        # == float(np.mean(...)): sequential left sum for < 8 elements
        self._viol_mean[cls][j] = sum(vrow) / len(vrow)
        self._warm[cls][j] = bool(self.ratio_count[cls, j]
                                  >= self.TIER_WARMUP)
        self._time_ratio_f[cls][j] = float(self.time_ratio[cls, j])
        self._err_sqrt[cls][j] = math.sqrt(float(self.err_var[cls, j]))
        self._infer_ratio_f[cls][j] = float(self.infer_ratio[cls, j])

    def predicted_time(self, req, j: int, view: ClusterView,
                       alloc: Optional[Allocation] = None) -> float:
        cls = req.class_id
        d_hat = (view.predict_tx(req, j, alloc)
                 + view.predict_queue(req, j, alloc)
                 + view.predict_infer(req, j, alloc)
                 * self.infer_ratio[cls, j])
        # the pessimistic margin grows with the allocation's stretch:
        # realization error is proportional to how long the work runs, so
        # a half-frequency tier doubles the guard band (exact at nominal)
        stretch = 1.0 if alloc is None \
            else 1.0 / (alloc.freq(view.specs[j]) * alloc.lane_share)
        margin = math.sqrt(self.err_var[cls, j]) * stretch
        return d_hat * self.time_ratio[cls, j] * self.SAFETY + margin

    def assign(self, req, view: ClusterView) -> Decision:
        """Hot path: one fused pass per (server, tier) arm — constraint
        filter, C1 prediction and the CS-UCB score are evaluated together
        and only the running best arm is tracked, so nothing is stored
        per arm. Decision branches that need the whole feasibility grid
        (KV-affinity resumes, prefix routing, allocation-aware admission)
        divert to `_assign_scan`, which keeps the array-building
        formulation. Both paths replicate the float operations of
        predicted_time + evaluate_constraints + CSUCB.select term for
        term, so trajectories are bit-identical to the reference
        formulation — pinned by the golden suites and
        tests/test_scale_equivalence.py."""
        kv_home = getattr(req, "kv_server", -1)
        n = self.n_servers
        if ((0 <= kv_home < n and getattr(req, "kv_blocks", 0) > 0)
                or (self.admission and self.bandit.n_tiers > 1)
                or (getattr(req, "prefix_id", -1) >= 0
                    and getattr(view, "prefix_hit_tokens", None)
                    is not None)):
            return self._assign_scan(req, view)
        specs_ref, tier_table, width, nominals, allocs, denoms, \
            inv_stretch, svc = self._arm_table(view)
        cls = req.class_id
        specs = view.specs
        t = view.t
        deadline = req.deadline
        need_bits = req.payload_bytes * 8.0
        p_tok = req.prompt_tokens
        o_tok = req.output_tokens
        lane_free = view.lane_free
        uplink = view.uplink_free_at
        bw_factor = view.bw_factor
        kv_totals = view.kv_total_blocks
        time_ratio = self._time_ratio_f[cls]
        err_sqrt = self._err_sqrt[cls]
        infer_r = self._infer_ratio_f[cls]
        viol_mean = self._viol_mean[cls]
        warm = self._warm[cls]
        SAFETY = self.SAFETY
        b_mean = self._b_mean[cls]
        b_count = self._b_count[cls]
        b_viol = self._b_viol[cls]
        p = self.bandit.p
        delta = p.delta
        neg_theta = -p.theta
        bt = self.bandit.t
        logt = math.log(bt if bt > 2 else 2)
        e0 = delta * math.sqrt(logt)   # == delta * sqrt(logt / max(0, 1))
        tg = self.TIER_GUARD
        tvg = self.TIER_VIOL_GAIN
        tcg = self.TIER_COMPUTE_GUARD
        txq = [0.0] * n
        infer0 = [0.0] * n
        ks_arr = [1.0] * n
        have = False
        best = 0.0
        j = 0
        slot = 0
        c_ts = c_cs = c_bs = c_pred = c_inf = 0.0
        c_ks = 1.0
        for jj in range(n):
            spec = specs[jj]
            lanes = lane_free[jj]
            u = uplink[jj]
            backlog = u - t if u > t else 0.0
            bwj = spec.bandwidth * bw_factor[jj]
            tx = backlog + need_bits / bwj
            ready = t + tx
            lane_min = min(lanes)
            q = lane_min - ready
            if q < 0.0:
                q = 0.0
            cap_bits = bwj * deadline
            used_bits = backlog * bwj
            twoa, flops, dst = svc[jj]
            nominal_inf = twoa * p_tok / flops + o_tok * dst
            txq[jj] = txq_j = tx + q
            infer0[jj] = nominal_inf
            ks = 1.0
            if kv_totals is not None and kv_totals[jj] > 0:
                # no resume case here: requests holding KV pages divert
                # to _assign_scan above
                kv_need = spec.kv_blocks_needed(p_tok, o_tok)
                hit_fn = getattr(view, "prefix_hit_tokens", None)
                if hit_fn is not None:
                    kv_need -= hit_fn(req, jj) \
                        // max(spec.kv_block_tokens, 1)
                ks = (view.kv_free_blocks[jj] - kv_need) / kv_totals[jj]
            ks_arr[jj] = ks
            bs = (cap_bits - used_bits - need_bits) / cap_bits
            if bs < 0.0 or ks < 0.0:
                # tier-independent C3/C5 violation: no tier of this
                # server can be feasible, and unchosen arms leave no
                # other trace on this path
                continue
            committed = 0.0
            for lf in lanes:
                d_ = lf - t
                if d_ > 0.0:
                    committed += d_
            capacity = spec.max_concurrency * deadline
            nominal_k = nominals[jj]
            guard = None
            w_j = warm[jj]
            tr = time_ratio[jj]
            es = err_sqrt[jj]
            ir = infer_r[jj]
            row_table = tier_table[jj]
            row_denom = denoms[jj]
            row_inv = inv_stretch[jj]
            mrow = b_mean[jj]
            crow = b_count[jj]
            vrow = b_viol[jj]
            for s_ in range(len(row_table)):
                inf_a = nominal_inf / row_denom[s_]
                d_hat = (txq_j + inf_a * ir) * tr * SAFETY \
                    + es * row_inv[s_]
                ts = (deadline - d_hat) / deadline
                cs = (capacity - committed - inf_a) / capacity
                ok = ts >= 0.0 and cs >= 0.0 and bs >= 0.0 and ks >= 0.0
                if ok and row_table[s_] != nominal_k:
                    if guard is None:
                        guard = tg + tvg * viol_mean[jj]
                    ok = w_j and ts >= guard and cs >= tcg
                if not ok:
                    continue
                cnt = crow[s_]
                if cnt == 0:
                    sc = mrow[s_] + e0 + 1e3 + neg_theta * vrow[s_]
                else:
                    sc = mrow[s_] + delta * math.sqrt(logt / cnt) \
                        + neg_theta * vrow[s_]
                if not have or sc > best:
                    best = sc
                    have = True
                    j = jj
                    slot = s_
                    c_ts, c_cs, c_bs, c_ks = ts, cs, bs, ks
                    c_pred, c_inf = d_hat, inf_a
        admit = True
        victim = None
        drop_kv = False
        if not have:
            # C1 failover (paper §3.1): predicted_time(alloc=None)
            # argmin, inlined from the per-server terms of the scan
            best_d = math.inf
            j = 0
            for jj in range(n):
                d0 = (txq[jj] + infer0[jj] * infer_r[jj]) \
                    * time_ratio[jj] * SAFETY + err_sqrt[jj]
                if d0 < best_d:
                    best_d, j = d0, jj
            slot = tier_table[j].index(nominals[j]) \
                if nominals[j] in tier_table[j] else 0
            if self.preempt:
                victim = self._find_victim(req, view)
            if victim is not None:
                j = victim.server
                slot = tier_table[j].index(nominals[j]) \
                    if nominals[j] in tier_table[j] else 0
                drop_kv = ks_arr[j] < 0.0
            elif self.admission:
                admit = False
            # slacks/prediction of the (infeasible) chosen arm, computed
            # exactly as the scan would have
            spec = specs[j]
            lanes = lane_free[j]
            committed = 0.0
            for lf in lanes:
                d_ = lf - t
                if d_ > 0.0:
                    committed += d_
            capacity = spec.max_concurrency * deadline
            u = uplink[j]
            backlog = u - t if u > t else 0.0
            bwj = spec.bandwidth * bw_factor[j]
            c_inf = infer0[j] / denoms[j][slot]
            c_pred = (txq[j] + c_inf * infer_r[j]) * time_ratio[j] \
                * SAFETY + err_sqrt[j] * inv_stretch[j][slot]
            c_ts = (deadline - c_pred) / deadline
            c_cs = (capacity - committed - c_inf) / capacity
            c_bs = (bwj * deadline - backlog * bwj - need_bits) \
                / (bwj * deadline)
            c_ks = ks_arr[j]
        alloc = allocs[j][slot]
        slacks = ConstraintSlacks(time=c_ts, compute=c_cs,
                                  bandwidth=c_bs, kv=c_ks)
        self._pending_slacks[req.sid] = slacks
        self._pending_tier[req.sid] = slot
        self._nominal_pred[req.sid] = c_pred / SAFETY
        self._last_nominal_infer[req.sid] = c_inf
        # migrate_kv needs a KV home, which diverts to _assign_scan
        return Decision(server=j, alloc=alloc,
                        infer_scale=infer_r[j],
                        slacks=slacks, admit=admit,
                        preempt_victim=None if victim is None
                        else victim.sid,
                        preempt_drop_kv=drop_kv,
                        migrate_kv=False)

    def _assign_scan(self, req, view: ClusterView) -> Decision:
        # Full-grid scan: builds the complete feasibility/slack arrays the
        # rare decision branches need (KV-affinity resume, prefix routing,
        # allocation-aware admission, preemption bookkeeping). Arithmetic
        # is the scalar unrolling of predicted_time + evaluate_constraints
        # replicated term for term (same association order, same max/min
        # semantics) so trajectories stay bit-identical to the vector
        # formulation — see the golden suites.
        specs_ref, tier_table, width, nominals, allocs, denoms, \
            inv_stretch, svc = self._arm_table(view)
        cls = req.class_id
        n = self.n_servers
        specs = view.specs
        t = view.t
        deadline = req.deadline
        need_bits = req.payload_bytes * 8.0
        p_tok = req.prompt_tokens
        o_tok = req.output_tokens
        lane_free = view.lane_free
        uplink = view.uplink_free_at
        bw_factor = view.bw_factor
        kv_totals = view.kv_total_blocks
        time_ratio = self._time_ratio_f[cls]
        err_sqrt = self._err_sqrt[cls]
        infer_r = self._infer_ratio_f[cls]
        viol_mean = self._viol_mean[cls]
        warm = self._warm[cls]
        SAFETY = self.SAFETY
        nw = n * width
        feas = [False] * nw
        s_time = [0.0] * nw
        s_comp = [0.0] * nw
        s_bw = [0.0] * nw
        s_kv = [1.0] * nw
        pred = [0.0] * nw
        infer_nom = [0.0] * nw
        txq = [0.0] * n
        infer0 = [0.0] * n
        feas_any = False
        for j in range(n):
            spec = specs[j]
            lanes = lane_free[j]
            u = uplink[j]
            backlog = u - t if u > t else 0.0
            bwj = spec.bandwidth * bw_factor[j]
            tx = backlog + need_bits / bwj
            ready = t + tx
            lane_min = min(lanes)
            q = lane_min - ready
            if q < 0.0:
                q = 0.0
            committed = 0.0
            for lf in lanes:
                d_ = lf - t
                if d_ > 0.0:
                    committed += d_
            capacity = spec.max_concurrency * deadline
            cap_bits = bwj * deadline
            used_bits = backlog * bwj
            twoa, flops, dst = svc[j]
            nominal_inf = twoa * p_tok / flops + o_tok * dst
            txq[j] = txq_j = tx + q
            infer0[j] = nominal_inf
            guard = self.TIER_GUARD + self.TIER_VIOL_GAIN * viol_mean[j]
            nominal_k = nominals[j]
            w_j = warm[j]
            tr = time_ratio[j]
            es = err_sqrt[j]
            ir = infer_r[j]
            base = j * width
            row_table = tier_table[j]
            row_denom = denoms[j]
            row_inv = inv_stretch[j]
            ks = 1.0
            if kv_totals is not None and kv_totals[j] > 0:
                # tier-invariant, so computed once per server
                if getattr(req, "kv_server", -1) == j \
                        and getattr(req, "kv_blocks", 0) > 0:
                    kv_need = 0
                else:
                    kv_need = spec.kv_blocks_needed(p_tok, o_tok)
                    hit_fn = getattr(view, "prefix_hit_tokens", None)
                    if hit_fn is not None:
                        kv_need -= hit_fn(req, j) \
                            // max(spec.kv_block_tokens, 1)
                ks = (view.kv_free_blocks[j] - kv_need) / kv_totals[j]
            for slot in range(len(row_table)):
                inf_a = nominal_inf / row_denom[slot]
                d_hat = (txq_j + inf_a * ir) * tr * SAFETY \
                    + es * row_inv[slot]
                ts = (deadline - d_hat) / deadline
                cs = (capacity - committed - inf_a) / capacity
                bs = (cap_bits - used_bits - need_bits) / cap_bits
                ok = ts >= 0.0 and cs >= 0.0 and bs >= 0.0 and ks >= 0.0
                if ok and row_table[slot] != nominal_k:
                    ok = w_j and ts >= guard \
                        and cs >= self.TIER_COMPUTE_GUARD
                idx = base + slot
                feas[idx] = ok
                s_time[idx] = ts
                s_comp[idx] = cs
                s_bw[idx] = bs
                s_kv[idx] = ks
                pred[idx] = d_hat
                infer_nom[idx] = inf_a
                if ok:
                    feas_any = True
        admit = True
        victim = None
        drop_kv = False
        kv_home = getattr(req, "kv_server", -1)
        if 0 <= kv_home < n and getattr(req, "kv_blocks", 0) > 0 \
                and any(feas[kv_home * width:
                             kv_home * width + len(tier_table[kv_home])]):
            # KV affinity: this request's pages survived a preemption on
            # kv_home — resuming there skips the whole re-prefill, which
            # no other feasible server can offer. Requeues are rare, so
            # bypassing the bandit here costs negligible exploration; take
            # the lowest-frequency (cheapest) feasible tier on the KV home
            # — by actual frequency, not table position (tables need not
            # be sorted).
            j = kv_home
            base = j * width
            row_table = tier_table[j]
            ft = specs[j].freq_tiers
            slot = -1
            best_f = 0.0
            for s_ in range(len(row_table)):
                if feas[base + s_]:
                    fv = ft[row_table[s_]]
                    if slot < 0 or fv < best_f:
                        slot, best_f = s_, fv
        elif feas_any:
            hit_fn = getattr(view, "prefix_hit_tokens", None)
            prefix_case = hit_fn is not None \
                and getattr(req, "prefix_id", -1) >= 0
            admit_case = self.admission and self.bandit.n_tiers > 1
            if prefix_case or admit_case:
                # rare branches keep the vectorized formulation verbatim
                feasible = np.array(
                    [[feas[jj * width + s_] for s_ in range(width)]
                     for jj in range(n)], bool)
                guarded = feasible
                if prefix_case:
                    # prefix-affinity routing: among feasible servers,
                    # prefer the ones already holding this request's
                    # shared system prompt — landing there skips that much
                    # prefill and pins only the unique suffix. Ties leave
                    # the bandit's arm space untouched.
                    hits = np.array([hit_fn(req, jj) for jj in range(n)])
                    if hits.max() > 0:
                        aff = guarded & (hits == hits.max())[:, None]
                        if aff.any():
                            guarded = aff
                if admit_case:
                    # allocation-aware admission: prefer arms that leave
                    # TIER_ADMIT_GUARD of C1 headroom; shed only when *no*
                    # feasible arm has it (a bare-feasible arm is never
                    # shed while a roomier alternative exists — rejected
                    # outcomes carry no bandit update, so shedding the
                    # deterministic first pick would starve a class
                    # forever)
                    roomy = np.array(
                        [[s_ < len(tier_table[jj])
                          and s_time[jj * width + s_]
                          >= self.TIER_ADMIT_GUARD
                          for s_ in range(width)] for jj in range(n)],
                        bool)
                    if (guarded & roomy).any():
                        guarded = guarded & roomy
                    elif (feasible & roomy).any():
                        # roomy arms exist only off the prefix-affine
                        # servers: admitting elsewhere beats shedding
                        guarded = feasible & roomy
                    else:
                        admit = False
                j, slot = self.bandit.select(cls, guarded)
            else:
                # scalar CS-UCB select (same score, same first-max tie
                # break as CSUCB.select's argmax over the masked grid)
                b_mean = self._b_mean[cls]
                b_count = self._b_count[cls]
                b_viol = self._b_viol[cls]
                p = self.bandit.p
                delta = p.delta
                neg_theta = -p.theta
                bt = self.bandit.t
                logt = math.log(bt if bt > 2 else 2)
                best = 0.0
                have = False
                j = 0
                slot = 0
                for jj in range(n):
                    base = jj * width
                    mrow = b_mean[jj]
                    crow = b_count[jj]
                    vrow = b_viol[jj]
                    for s_ in range(width):
                        if not feas[base + s_]:
                            continue
                        cnt = crow[s_]
                        if cnt == 0:
                            sc = mrow[s_] + delta * math.sqrt(logt) \
                                + 1e3 + neg_theta * vrow[s_]
                        else:
                            sc = mrow[s_] \
                                + delta * math.sqrt(logt / cnt) \
                                + neg_theta * vrow[s_]
                        if not have or sc > best:
                            best, j, slot, have = sc, jj, s_, True
        else:
            # C1 failover (paper §3.1): no feasible server -> assign to
            # the most resource-rich one, i.e. minimum predicted time, at
            # the nominal tier (the fastest calibrated operating point).
            # predicted_time(alloc=None) inlined from the scan's per-
            # server terms: no tier stretch, so infer is undivided and
            # the margin stretch is an exact ×1.0.
            best_d = math.inf
            j = 0
            for jj in range(n):
                d0 = (txq[jj] + infer0[jj] * infer_r[jj]) \
                    * time_ratio[jj] * SAFETY + err_sqrt[jj]
                if d0 < best_d:
                    best_d, j = d0, jj
            slot = tier_table[j].index(nominals[j]) \
                if nominals[j] in tier_table[j] else 0
            if self.preempt:
                victim = self._find_victim(req, view)
            if victim is not None:
                j = victim.server
                slot = tier_table[j].index(nominals[j]) \
                    if nominals[j] in tier_table[j] else 0
                # KV-resume info: when the victim's server is out of KV
                # *memory* (not just lanes), evicting the lane alone frees
                # nothing — drop the victim's pages so the preemptor's
                # blocks fit, accepting the victim's re-prefill elsewhere
                drop_kv = s_kv[j * width + slot] < 0.0
            elif self.admission:
                # admission control: shedding beats dumping doomed work on
                # the least-bad server — the runtime emits the rejected
                # Outcome (SLO-violation cost) and frees no capacity
                admit = False
        migrate = False
        if admit and 0 <= kv_home < n and j != kv_home \
                and getattr(req, "kv_blocks", 0) > 0:
            migrate = self._migration_pays(req, j, view)
        idx = j * width + slot
        alloc = allocs[j][slot]
        slacks = ConstraintSlacks(time=s_time[idx], compute=s_comp[idx],
                                  bandwidth=s_bw[idx], kv=s_kv[idx])
        self._pending_slacks[req.sid] = slacks
        self._pending_tier[req.sid] = slot
        self._nominal_pred[req.sid] = pred[idx] / SAFETY
        self._last_nominal_infer[req.sid] = infer_nom[idx]
        return Decision(server=j, alloc=alloc,
                        infer_scale=infer_r[j],
                        slacks=slacks, admit=admit,
                        preempt_victim=None if victim is None
                        else victim.sid,
                        preempt_drop_kv=drop_kv,
                        migrate_kv=migrate)

    def _migration_pays(self, req, j, view):
        """Ship preserved pages to the chosen server instead of abandoning
        them? Yes iff the destination can host them and the transfer (at
        the topology's current bottleneck bandwidth, behind its current
        backlog) beats the re-prefill it avoids."""
        totals = getattr(view, "kv_total_blocks", None)
        if totals is None or totals[j] <= 0:
            return False
        spec = view.specs[j]
        need = spec.kv_blocks_needed(req.prompt_tokens, req.output_tokens)
        free = view.kv_free_blocks[j]
        if free is None or free < need:
            return False
        mig_fn = getattr(view, "kv_migration_s", None)
        cost = mig_fn(req, j) if mig_fn is not None else None
        if cost is None:
            return False
        return cost < spec.prefill_time(req.prompt_tokens)

    def _find_victim(self, req, view: ClusterView):
        """A running task worth preempting for `req`, or None.

        Only *doomed* tasks qualify (their estimated finish already misses
        their own deadline — evicting them costs no extra SLO violation),
        and only where `req` could actually meet its deadline once the
        lane is free (transmission + inference at the nominal tier, no
        lane wait). Among qualifying victims, reclaim the most-doomed lane
        first."""
        if not view.running:
            return None
        cls = req.class_id
        best, best_over = None, 0.0
        for tasks in view.running:
            for task in tasks:
                if not task.doomed or task.sid == req.sid:
                    continue
                j = task.server
                d_no_queue = (view.predict_tx(req, j)
                              + view.predict_infer(req, j)
                              * self.infer_ratio[cls, j]) \
                    * self.time_ratio[cls, j] * self.SAFETY
                if d_no_queue > req.deadline:
                    continue
                over = task.finish_est - task.deadline_at
                if over > best_over:
                    best, best_over = task, over
        return best

    def feedback(self, req, out) -> None:
        slacks = self._pending_slacks.pop(req.sid, None)
        nominal = self._nominal_pred.pop(req.sid, None)
        tier_slot = self._pending_tier.pop(req.sid, 0)
        if getattr(out, "rejected", False):
            # the SLO-violation cost of a shed request is a system metric,
            # not an observation: nothing ran, so there is no realized
            # time/energy to learn from (and a zero infer_time would
            # poison the efficiency estimators)
            self._last_nominal_infer.pop(req.sid, None)
            return
        cls, j = req.class_id, out.server

        # realized constraint slack (C1 realized; C2/C3 from decision time)
        time_slack = (req.deadline - out.processing_time) / req.deadline
        f_y = min(time_slack,
                  slacks.compute if slacks else 0.0,
                  slacks.bandwidth if slacks else 0.0,
                  slacks.kv if slacks else 1.0)
        reward = self.bandit.shaped_reward(out.energy / E_SCALE, f_y)
        violation = max(-f_y, 0.0)
        self.bandit.update(cls, j, reward, violation, tier=tier_slot)

        # update learned estimators: per-server efficiency (from pure
        # inference time), per-class residual bias, and error variance
        nom_inf = out.infer_time  # realized
        # realized/nominal inference ratio: EMA, robust to noise
        # (predict_infer is deterministic given the request + allocation,
        # so the ratio isolates the hidden efficiency at any tier)
        self.infer_ratio[cls, j] += 0.1 * (
            out.infer_time / max(self._last_nominal_infer.pop(req.sid, nom_inf),
                                 1e-9) - self.infer_ratio[cls, j])
        if nominal and nominal > 0:
            ratio = out.processing_time / nominal
            self.ratio_count[cls, j] += 1
            n = self.ratio_count[cls, j]
            self.time_ratio[cls, j] += (ratio - self.time_ratio[cls, j]) / n
            err = out.processing_time - nominal * self.time_ratio[cls, j]
            self.err_var[cls, j] += (err * err - self.err_var[cls, j]) \
                / max(n, 1)
        self._refresh_mirrors(cls, j)

    # ------------------------------------------------------------------
    @property
    def regret_trace(self) -> List[float]:
        return self.bandit.regret_trace


def spec_nominal(spec) -> int:
    """Index of a spec's nominal DVFS tier (0 for pre-tier specs)."""
    return getattr(spec, "nominal_tier", 0)
