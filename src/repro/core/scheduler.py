"""PerLLM scheduler: CS-UCB service scheduling + resource allocation.

Implements paper Algorithm 1 as a `SchedulingPolicy`. Per slot, arrivals
are assigned sequentially (building the super arm): for each service the
constraint-satisfaction mechanism filters the feasible (server, DVFS tier)
pairs using *learned* processing-time estimates, and CS-UCB picks the
feasible arm with the best UCB score — placement and compute allocation
are one joint decision (paper Eq. 1). The runtime commits each `Decision`'s
residuals before asking for the next one, so later services in the same
slot see the reduced capacity (C2/C3 accounting).

Tier selection is where the energy story lives: a slower tier stretches
inference (time ∝ 1/f) but cuts dynamic power cubically, so energy per
token falls as f² — the bandit's reward (−energy + λ·f(y)⁻) converges to
the *cheapest* feasible allocation per (class, server), not the fastest.
On a single-tier testbed the arm space degenerates to (class, server) and
the trajectory is bit-exact with the placement-only scheduler.

Observed outcomes arrive via `feedback`: reward = −energy_norm + λ·f(y)
(Eq. 4, f(y) clipped into [−1, 0] — see `repro.core.bandit`), plus a
violation-severity update that drives the penalty term P(t).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from repro.cluster.workload import N_CLASSES
from repro.core.api import Allocation, ClusterView, Decision, \
    SchedulingPolicy, register_policy
from repro.core.bandit import CSUCB, CSUCBParams
from repro.core.constraints import ConstraintSlacks, evaluate_constraints

# Energy normalization scale (J) — a typical per-service energy magnitude;
# keeps the two reward terms in Eq. 4 comparable. Calibrated so the
# energy differences between DVFS tiers of one server are visible above
# the UCB exploration term (with f(y) clipped into [−1, 0] the energy
# term is what ranks feasible arms).
E_SCALE = 60.0


@register_policy("perllm")
class PerLLMScheduler(SchedulingPolicy):
    """`admission=True` turns the C1 failover into admission control: when
    no server can satisfy the constraints, the request is shed
    (`Decision.admit=False`) instead of being dumped on the least-bad
    server — under sustained overload this is what keeps *admitted*
    requests inside their SLOs. `preempt=True` additionally lets an
    otherwise-infeasible request reclaim a lane from a running task that
    is already doomed to miss its own deadline (`Decision.preempt_victim`,
    event-driven runtimes only). `tiers=False` pins every decision to the
    nominal DVFS tier — the fixed-frequency comparator the energy
    benchmarks (and the nominal-tier golden test) run against."""

    name = "PerLLM"

    def __init__(self, n_servers: int, params: Optional[CSUCBParams] = None,
                 seed: int = 0, admission: bool = False,
                 preempt: bool = False, tiers: bool = True):
        self.n_servers = n_servers
        self.admission = admission
        self.preempt = preempt
        self.tiers = tiers
        self._seed = seed
        self._params = params
        self.bandit = CSUCB(N_CLASSES, n_servers, params, seed=seed)
        # learned per-(class, server) processing-time ratio vs the nominal
        # analytic estimate (captures hidden efficiency + congestion)
        self.time_ratio = np.ones((N_CLASSES, n_servers), np.float64)
        self.ratio_count = np.zeros((N_CLASSES, n_servers), np.int64)
        # prediction-error second moment -> pessimistic C1 margin
        self.err_var = np.zeros((N_CLASSES, n_servers), np.float64)
        # per-(class, server) inference-time ratio (hidden efficiency)
        self.infer_ratio = np.ones((N_CLASSES, n_servers), np.float64)
        self._pending_slacks: Dict[int, ConstraintSlacks] = {}
        self._pending_tier: Dict[int, int] = {}
        self._nominal_pred: Dict[int, float] = {}
        self._last_nominal_infer: Dict[int, float] = {}

    # ------------------------------------------------------------------
    # C1 safety margin: guards against realization noise and within-slot
    # queue drift when checking the processing-time constraint.
    SAFETY = 1.05
    # Non-nominal DVFS tiers deliberately spend deadline slack for energy,
    # so they get a stricter bar than bare feasibility: the predicted time
    # must leave TIER_GUARD relative headroom, and the (class, server)
    # estimators must have seen a few calibration outcomes first (slowing
    # a server down before its hidden efficiency is known converts
    # prediction error straight into SLO misses).
    TIER_GUARD = 0.05
    TIER_WARMUP = 3
    # ... and the server must retain lane-capacity headroom (C2 slack):
    # downtiering occupies the lane longer, and on a loaded server that
    # stolen lane-time surfaces as queue drift for *later, nominal-tier*
    # requests — the misses show up far from the arm that caused them, so
    # the bandit's own penalty cannot learn them away.
    TIER_COMPUTE_GUARD = 0.25
    # Adaptive component: the time-headroom bar rises with the
    # (class, server)'s observed violation severity (the bandit's V̄,
    # congestion-coupled across tiers), so a host whose requests have been
    # missing deadlines stops being downtiered until it cools off.
    TIER_VIOL_GAIN = 2.0
    # Allocation-aware admission: with DVFS tiers in play, committed lane
    # windows are stretched and queue-drift error correspondingly larger,
    # so an admission-enabled tiered scheduler demands this much positive
    # C1 headroom on the arm it admits on — slack is spent on energy, not
    # on risky admits. Inactive without `admission` or on untiered specs.
    TIER_ADMIT_GUARD = 0.02

    def _tier_table(self, view: ClusterView) -> List[List[int]]:
        """Per-server candidate tier indices (just the nominal tier when
        tier selection is disabled), sizing the bandit's arm space on
        first contact with the cluster's specs."""
        if not self.tiers:
            return [[spec_nominal(view.specs[j])]
                    for j in range(self.n_servers)]
        table = [list(range(view.n_tiers(j)))
                 for j in range(self.n_servers)]
        width = max(len(t) for t in table)
        if width != self.bandit.n_tiers:
            # first view revealed the real tier count: rebuild the (so far
            # unpulled) bandit over the (class, server, tier) arm space
            self.bandit = CSUCB(N_CLASSES, self.n_servers, self._params,
                                seed=self._seed, n_tiers=width)
        return table

    def predicted_time(self, req, j: int, view: ClusterView,
                       alloc: Optional[Allocation] = None) -> float:
        cls = req.class_id
        d_hat = (view.predict_tx(req, j, alloc)
                 + view.predict_queue(req, j, alloc)
                 + view.predict_infer(req, j, alloc)
                 * self.infer_ratio[cls, j])
        # the pessimistic margin grows with the allocation's stretch:
        # realization error is proportional to how long the work runs, so
        # a half-frequency tier doubles the guard band (exact at nominal)
        stretch = 1.0 if alloc is None \
            else 1.0 / (alloc.freq(view.specs[j]) * alloc.lane_share)
        margin = math.sqrt(self.err_var[cls, j]) * stretch
        return d_hat * self.time_ratio[cls, j] * self.SAFETY + margin

    def assign(self, req, view: ClusterView) -> Decision:
        tier_table = self._tier_table(view)
        width = self.bandit.n_tiers
        slacks: List[List[Optional[ConstraintSlacks]]] = \
            [[None] * width for _ in range(self.n_servers)]
        feasible = np.zeros((self.n_servers, width), bool)
        allocs: List[List[Optional[Allocation]]] = \
            [[None] * width for _ in range(self.n_servers)]
        for j in range(self.n_servers):
            nominal_k = spec_nominal(view.specs[j])
            warmed = self.ratio_count[req.class_id, j] >= self.TIER_WARMUP
            guard = self.TIER_GUARD + self.TIER_VIOL_GAIN \
                * float(np.mean(self.bandit.violation[req.class_id, j]))
            for slot, k in enumerate(tier_table[j]):
                alloc = Allocation(freq_tier=k)
                d_hat = self.predicted_time(req, j, view, alloc)
                s = evaluate_constraints(req, j, view, predicted_time=d_hat,
                                         alloc=alloc)
                allocs[j][slot] = alloc
                slacks[j][slot] = s
                ok = s.satisfied
                if ok and k != nominal_k:
                    ok = warmed and s.time >= guard \
                        and s.compute >= self.TIER_COMPUTE_GUARD
                feasible[j, slot] = ok
        admit = True
        victim = None
        drop_kv = False
        kv_home = getattr(req, "kv_server", -1)
        if 0 <= kv_home < self.n_servers and feasible[kv_home].any() \
                and getattr(req, "kv_blocks", 0) > 0:
            # KV affinity: this request's pages survived a preemption on
            # kv_home — resuming there skips the whole re-prefill, which
            # no other feasible server can offer. Requeues are rare, so
            # bypassing the bandit here costs negligible exploration; take
            # the lowest-frequency (cheapest) feasible tier on the KV home
            # — by actual frequency, not table position (tables need not
            # be sorted).
            j = kv_home
            slot = min((s for s in range(len(tier_table[j]))
                        if feasible[j, s]),
                       key=lambda s: view.specs[j].freq_tiers[
                           tier_table[j][s]])
        elif feasible.any():
            guarded = feasible
            hit_fn = getattr(view, "prefix_hit_tokens", None)
            if hit_fn is not None and getattr(req, "prefix_id", -1) >= 0:
                # prefix-affinity routing: among feasible servers, prefer
                # the ones already holding this request's shared system
                # prompt — landing there skips that much prefill and pins
                # only the unique suffix. Ties (several servers hold the
                # same span, or none holds any) leave the bandit's arm
                # space untouched.
                hits = np.array([hit_fn(req, jj)
                                 for jj in range(self.n_servers)])
                if hits.max() > 0:
                    aff = guarded & (hits == hits.max())[:, None]
                    if aff.any():
                        guarded = aff
            if self.admission and self.bandit.n_tiers > 1:
                # allocation-aware admission: prefer arms that leave
                # TIER_ADMIT_GUARD of C1 headroom; shed only when *no*
                # feasible arm has it (a bare-feasible arm is never shed
                # while a roomier alternative exists — rejected outcomes
                # carry no bandit update, so shedding the deterministic
                # first pick would starve a class forever)
                roomy = np.array(
                    [[s is not None and s.time >= self.TIER_ADMIT_GUARD
                      for s in row] for row in slacks], bool)
                if (guarded & roomy).any():
                    guarded = guarded & roomy
                elif (feasible & roomy).any():
                    # roomy arms exist only off the prefix-affine servers:
                    # admitting elsewhere beats shedding
                    guarded = feasible & roomy
                else:
                    admit = False
            j, slot = self.bandit.select(req.class_id, guarded)
        else:
            # C1 failover (paper §3.1): no feasible server -> assign to
            # the most resource-rich one, i.e. minimum predicted time, at
            # the nominal tier (the fastest calibrated operating point)
            j = int(np.argmin([self.predicted_time(req, jj, view)
                               for jj in range(self.n_servers)]))
            slot = tier_table[j].index(spec_nominal(view.specs[j])) \
                if spec_nominal(view.specs[j]) in tier_table[j] else 0
            if allocs[j][slot] is None:
                allocs[j][slot] = Allocation(freq_tier=tier_table[j][slot])
            if self.preempt:
                victim = self._find_victim(req, view)
            if victim is not None:
                j = victim.server
                slot = tier_table[j].index(spec_nominal(view.specs[j])) \
                    if spec_nominal(view.specs[j]) in tier_table[j] else 0
                # KV-resume info: when the victim's server is out of KV
                # *memory* (not just lanes), evicting the lane alone frees
                # nothing — drop the victim's pages so the preemptor's
                # blocks fit, accepting the victim's re-prefill elsewhere
                drop_kv = slacks[j][slot].kv < 0.0
            elif self.admission:
                # admission control: shedding beats dumping doomed work on
                # the least-bad server — the runtime emits the rejected
                # Outcome (SLO-violation cost) and frees no capacity
                admit = False
        migrate = False
        if admit and 0 <= kv_home < self.n_servers and j != kv_home \
                and getattr(req, "kv_blocks", 0) > 0:
            migrate = self._migration_pays(req, j, view)
        alloc = allocs[j][slot]
        self._pending_slacks[req.sid] = slacks[j][slot]
        self._pending_tier[req.sid] = slot
        self._nominal_pred[req.sid] = \
            self.predicted_time(req, j, view, alloc) / self.SAFETY
        self._last_nominal_infer[req.sid] = view.predict_infer(req, j, alloc)
        return Decision(server=j, alloc=alloc,
                        infer_scale=float(self.infer_ratio[req.class_id, j]),
                        slacks=slacks[j][slot], admit=admit,
                        preempt_victim=None if victim is None
                        else victim.sid,
                        preempt_drop_kv=drop_kv,
                        migrate_kv=migrate)

    def _migration_pays(self, req, j, view):
        """Ship preserved pages to the chosen server instead of abandoning
        them? Yes iff the destination can host them and the transfer (at
        the topology's current bottleneck bandwidth, behind its current
        backlog) beats the re-prefill it avoids."""
        totals = getattr(view, "kv_total_blocks", None)
        if totals is None or totals[j] <= 0:
            return False
        spec = view.specs[j]
        need = spec.kv_blocks_needed(req.prompt_tokens, req.output_tokens)
        free = view.kv_free_blocks[j]
        if free is None or free < need:
            return False
        mig_fn = getattr(view, "kv_migration_s", None)
        cost = mig_fn(req, j) if mig_fn is not None else None
        if cost is None:
            return False
        return cost < spec.prefill_time(req.prompt_tokens)

    def _find_victim(self, req, view: ClusterView):
        """A running task worth preempting for `req`, or None.

        Only *doomed* tasks qualify (their estimated finish already misses
        their own deadline — evicting them costs no extra SLO violation),
        and only where `req` could actually meet its deadline once the
        lane is free (transmission + inference at the nominal tier, no
        lane wait). Among qualifying victims, reclaim the most-doomed lane
        first."""
        if not view.running:
            return None
        cls = req.class_id
        best, best_over = None, 0.0
        for tasks in view.running:
            for task in tasks:
                if not task.doomed or task.sid == req.sid:
                    continue
                j = task.server
                d_no_queue = (view.predict_tx(req, j)
                              + view.predict_infer(req, j)
                              * self.infer_ratio[cls, j]) \
                    * self.time_ratio[cls, j] * self.SAFETY
                if d_no_queue > req.deadline:
                    continue
                over = task.finish_est - task.deadline_at
                if over > best_over:
                    best, best_over = task, over
        return best

    def feedback(self, req, out) -> None:
        slacks = self._pending_slacks.pop(req.sid, None)
        nominal = self._nominal_pred.pop(req.sid, None)
        tier_slot = self._pending_tier.pop(req.sid, 0)
        if getattr(out, "rejected", False):
            # the SLO-violation cost of a shed request is a system metric,
            # not an observation: nothing ran, so there is no realized
            # time/energy to learn from (and a zero infer_time would
            # poison the efficiency estimators)
            self._last_nominal_infer.pop(req.sid, None)
            return
        cls, j = req.class_id, out.server

        # realized constraint slack (C1 realized; C2/C3 from decision time)
        time_slack = (req.deadline - out.processing_time) / req.deadline
        f_y = min(time_slack,
                  slacks.compute if slacks else 0.0,
                  slacks.bandwidth if slacks else 0.0,
                  slacks.kv if slacks else 1.0)
        reward = self.bandit.shaped_reward(out.energy / E_SCALE, f_y)
        violation = max(-f_y, 0.0)
        self.bandit.update(cls, j, reward, violation, tier=tier_slot)

        # update learned estimators: per-server efficiency (from pure
        # inference time), per-class residual bias, and error variance
        nom_inf = out.infer_time  # realized
        # realized/nominal inference ratio: EMA, robust to noise
        # (predict_infer is deterministic given the request + allocation,
        # so the ratio isolates the hidden efficiency at any tier)
        self.infer_ratio[cls, j] += 0.1 * (
            out.infer_time / max(self._last_nominal_infer.pop(req.sid, nom_inf),
                                 1e-9) - self.infer_ratio[cls, j])
        if nominal and nominal > 0:
            ratio = out.processing_time / nominal
            self.ratio_count[cls, j] += 1
            n = self.ratio_count[cls, j]
            self.time_ratio[cls, j] += (ratio - self.time_ratio[cls, j]) / n
            err = out.processing_time - nominal * self.time_ratio[cls, j]
            self.err_var[cls, j] += (err * err - self.err_var[cls, j]) \
                / max(n, 1)

    # ------------------------------------------------------------------
    @property
    def regret_trace(self) -> List[float]:
        return self.bandit.regret_trace


def spec_nominal(spec) -> int:
    """Index of a spec's nominal DVFS tier (0 for pre-tier specs)."""
    return getattr(spec, "nominal_tier", 0)
