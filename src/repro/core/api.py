"""The unified scheduling contract shared by the simulator and live server.

One protocol, two runtimes. A scheduler is a `SchedulingPolicy`: per
request it returns a `Decision` (server, optional dispatch deferral, an
inference-time correction, and per-constraint slack diagnostics); after the
request completes it receives the realized `feedback`. The *runtime* — the
discrete-event `Simulator` or the live `PerLLMServer` — owns the
`ClusterView` it exposes, applies each Decision's residual accounting via
`ClusterView.commit`, and applies the deferral. Policies never mutate
requests or runtime state directly; the old protocol's bare server indices
plus `req.defer_until` side effects are gone.

Layering: this module is the bottom of the scheduling stack. It imports
nothing from `repro.cluster`; server specs and requests are structural
(anything with `bandwidth`, `max_concurrency`, `service_time`, …), so both
the simulated testbed and the live engine fleet satisfy it.

Policies register themselves by name (`@register_policy("perllm")`) and are
constructed with `make_policy(name, n_servers, **kw)` — benchmarks,
examples, and the serve CLI all go through the registry.

A thin deprecation shim keeps out-of-tree `SchedulerBase` subclasses (the
old batch `schedule() -> List[int]` protocol) runnable: `as_policy()` wraps
them and `drive_slot()` routes them through their original batch call.
"""
from __future__ import annotations

import dataclasses
from typing import (
    TYPE_CHECKING, Any, Callable, Dict, List, Optional, Sequence, Tuple,
)

import numpy as np

if TYPE_CHECKING:  # type-only: keeps core.api free of upward imports
    from repro.core.constraints import ConstraintSlacks


# ---------------------------------------------------------------------------
# Decision — what a policy returns for one request
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Decision:
    """One request's placement, returned by `SchedulingPolicy.assign`.

    server          index of the chosen server (C4: exactly one per
                    request; for a rejection it names the server the
                    policy *would* have used — learners need an arm index)
    defer_until     earliest dispatch time; 0.0 = dispatch on arrival (used
                    by deferred-batching policies such as FineInfer)
    infer_scale     multiplicative correction the policy has learned for
                    the nominal inference-time model on this server; the
                    runtime commits lane residuals scaled by it
    slacks          per-constraint slack diagnostics (C1/C2/C3) at decision
                    time, if the policy evaluated them — observational
    admit           False = admission control sheds the request: the
                    runtime emits a rejected Outcome (SLO-violation cost,
                    zero server energy) instead of queueing it
    preempt_victim  sid of a running request whose batch lane should be
                    returned before this request dispatches; the victim's
                    remaining decode tokens are requeued as a new Arrival
    preempt_drop_kv KV-resume info carried with the preemption: False
                    (default) keeps the victim's KV pages resident on its
                    server, so a same-server requeue resumes decode with
                    zero re-prefill; True frees the pages immediately —
                    the right call when the preemption is relieving KV
                    *memory* exhaustion rather than reclaiming a lane
                    (ignored on servers that don't model KV)
    """

    server: int
    defer_until: float = 0.0
    infer_scale: float = 1.0
    slacks: Optional["ConstraintSlacks"] = None
    admit: bool = True
    preempt_victim: Optional[int] = None
    preempt_drop_kv: bool = False


# ---------------------------------------------------------------------------
# ClusterView — the one observation object both runtimes build
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunningTask:
    """One in-flight request, as exposed to preemption-capable policies.

    `finish_est` is the runtime's current completion estimate for the
    booked lane; `deadline_at` is the absolute SLO instant
    (arrival + deadline). A task with `finish_est > deadline_at` is doomed
    — preempting it frees its lane without costing an extra SLO miss.
    """

    sid: int
    server: int
    class_id: int
    deadline_at: float
    begin: float        # when its lane booking starts (may be in the past)
    finish_est: float

    @property
    def doomed(self) -> bool:
        return self.finish_est > self.deadline_at


@dataclasses.dataclass
class ClusterView:
    """What a policy may observe when assigning one slot's arrivals.

    Built by the runtime from *real* state: per-server uplink occupancy,
    batch-lane occupancy, and the current bandwidth factor of each link.
    Mutable residuals (`uplink_free_at`, `lane_free`) are advanced by the
    runtime's `commit` after each Decision, so later requests in the same
    slot see the reduced capacity (the combinatorial super-arm accounting).
    Hidden runtime state (efficiency, noise) is NOT here.

    Per-server `bw_factor` / `uplink_free_at` are *path-effective* values
    when the runtime models a `LinkTopology` (bottleneck bandwidth over
    the server's link path, latest path-link backlog), so the nominal
    predictors work unchanged. Topology-aware policies can additionally
    read the per-link fields:

    link_bw     observed bits/s per named link (capacity × factor × scale)
    link_queue  seconds of serialized backlog per named link
    paths       link names each server's ingress traffic traverses
    running     per-server in-flight tasks (`RunningTask`) — what a
                preemption-capable policy may name as `preempt_victim`;
                None when the runtime does not support preemption

    KV memory — the binding resource for LLM decode on edge hardware — is
    first-class when the runtime models it (paged engines / `ServerSpec`s
    with a block pool):

    kv_free_blocks   free KV-cache blocks per server right now
    kv_total_blocks  each server's block-pool size; an entry of 0 means
                     that server does not model KV (its kv_free_blocks
                     entry is meaningless and the KV constraint is vacuous)
    """

    t: float
    specs: Sequence[Any]            # ServerSpec-shaped objects
    bw_factor: List[float]
    uplink_free_at: List[float]
    lane_free: List[List[float]]
    link_bw: Optional[Dict[str, float]] = None
    link_queue: Optional[Dict[str, float]] = None
    paths: Optional[Sequence[Sequence[str]]] = None
    running: Optional[List[List[RunningTask]]] = None
    kv_free_blocks: Optional[List[int]] = None
    kv_total_blocks: Optional[List[int]] = None

    @property
    def n_servers(self) -> int:
        return len(self.specs)

    # ---------------- nominal predictors (no hidden factors) -------------
    def predict_tx(self, req, j: int) -> float:
        spec = self.specs[j]
        start = max(self.t, self.uplink_free_at[j])
        dur = req.payload_bytes * 8.0 / (spec.bandwidth * self.bw_factor[j])
        return (start - self.t) + dur

    def predict_queue(self, req, j: int) -> float:
        ready = self.t + self.predict_tx(req, j)
        lane = min(self.lane_free[j])
        return max(lane - ready, 0.0)

    def predict_infer(self, req, j: int) -> float:
        return self.specs[j].service_time(req.prompt_tokens,
                                          req.output_tokens)

    def predict_total(self, req, j: int) -> float:
        return (self.predict_tx(req, j) + self.predict_queue(req, j)
                + self.predict_infer(req, j))

    # ---------------- residual accounting (runtime-applied) --------------
    def commit(self, req, j: int, infer_scale: float = 1.0) -> None:
        """Update residuals as if req were placed on j.

        Called by the runtime (`drive_slot`), not by policies — that is what
        guarantees C2/C3 accounting cannot be silently skipped."""
        spec = self.specs[j]
        start = max(self.t, self.uplink_free_at[j])
        dur = req.payload_bytes * 8.0 / (spec.bandwidth * self.bw_factor[j])
        self.uplink_free_at[j] = start + dur
        ready = start + dur
        lanes = self.lane_free[j]
        li = int(np.argmin(lanes))
        begin = max(ready, lanes[li])
        lanes[li] = begin + self.predict_infer(req, j) * infer_scale

    def apply(self, req, decision: Decision) -> None:
        """Commit one Decision's residuals."""
        self.commit(req, decision.server, infer_scale=decision.infer_scale)


# ---------------------------------------------------------------------------
# SchedulingPolicy — the contract
# ---------------------------------------------------------------------------


class SchedulingPolicy:
    """Per-request scheduling contract.

    Subclasses implement `assign` (pure with respect to the view: no
    `commit`, no request mutation) and optionally `feedback`. The legacy
    batch entry points `schedule`/`observe` are provided for backward
    compatibility and route through the runtime driver.
    """

    name = "policy"

    def assign(self, request, view: ClusterView) -> Decision:
        raise NotImplementedError

    def feedback(self, request, outcome) -> None:
        """Realized outcome for a previously assigned request."""

    # ---------------- deprecated batch protocol (shim) -------------------
    def schedule(self, arrivals: Sequence[Any], view: ClusterView,
                 t_slot: int = 0) -> List[int]:
        """Deprecated: old `SchedulerBase.schedule` signature.

        Drives this policy through the runtime loop (commit included) and
        returns bare server indices, so pre-redesign call sites keep
        working."""
        return [d.server for d in drive_slot(self, arrivals, view, t_slot)]

    def observe(self, request, outcome) -> None:
        """Deprecated alias for `feedback`."""
        self.feedback(request, outcome)


class SchedulerBase:
    """Deprecated legacy contract (batch `schedule() -> List[int]` with
    policy-side `view.commit` and `req.defer_until` mutation).

    Kept so out-of-tree subclasses still run: both runtimes wrap instances
    with `as_policy()` and drive them through their original batch call.
    New code should subclass `SchedulingPolicy`."""

    name = "base"

    def schedule(self, arrivals: List[Any], view: ClusterView,
                 t_slot: int) -> List[int]:
        raise NotImplementedError

    def observe(self, request, outcome) -> None:
        pass


class LegacyPolicyAdapter(SchedulingPolicy):
    """Wraps an old-protocol scheduler as a `SchedulingPolicy`.

    Inside `drive_slot` the wrapped scheduler runs through its original
    batch `schedule` call (committing on the view itself, exactly as
    before); its side effects are lifted into `Decision` objects. The
    per-request `assign` below honors the new contract instead: the legacy
    scheduler runs on a *shadow copy* of the view, so the caller's view is
    untouched and the runtime's `view.apply` commits exactly once.
    `assign` passes `int(view.t)` as a pseudo slot index (the adapter
    cannot know the runtime's slot length); exact slot indices flow through
    `drive_slot`'s batch path, and no in-repo scheduler reads `t_slot`."""

    def __init__(self, legacy):
        self.legacy = legacy

    @property
    def name(self) -> str:  # type: ignore[override]
        return getattr(self.legacy, "name", type(self.legacy).__name__)

    def assign(self, request, view: ClusterView) -> Decision:
        shadow = ClusterView(
            t=view.t, specs=view.specs, bw_factor=list(view.bw_factor),
            uplink_free_at=list(view.uplink_free_at),
            lane_free=[list(lf) for lf in view.lane_free])
        (j,) = self.legacy.schedule([request], shadow, int(view.t))
        j = int(j)
        # Lift the legacy commit's lane booking into the Decision so the
        # runtime's single commit reproduces it (the old protocol let the
        # scheduler scale the nominal inference time, e.g. the seed
        # PerLLMScheduler's learned infer_ratio).
        infer_scale = 1.0
        changed = [i for i, (a, b) in
                   enumerate(zip(view.lane_free[j], shadow.lane_free[j]))
                   if a != b]
        if len(changed) == 1:
            li = changed[0]
            begin = max(shadow.uplink_free_at[j], view.lane_free[j][li])
            nominal = view.predict_infer(request, j)
            booked = shadow.lane_free[j][li] - begin
            if nominal > 0 and booked > 0:
                infer_scale = booked / nominal
        return Decision(server=j,
                        defer_until=float(getattr(request, "defer_until",
                                                  0.0)),
                        infer_scale=infer_scale)

    def feedback(self, request, outcome) -> None:
        self.legacy.observe(request, outcome)


def as_policy(scheduler) -> SchedulingPolicy:
    """Coerce a scheduler of either protocol into a `SchedulingPolicy`."""
    if isinstance(scheduler, SchedulingPolicy):
        return scheduler
    if callable(getattr(scheduler, "schedule", None)):
        return LegacyPolicyAdapter(scheduler)
    raise TypeError(
        f"{type(scheduler).__name__} implements neither SchedulingPolicy "
        "(.assign) nor the legacy SchedulerBase protocol (.schedule)")


# ---------------------------------------------------------------------------
# Runtime driver — the one place Decisions are applied
# ---------------------------------------------------------------------------


def drive_slot(policy, arrivals: Sequence[Any], view: ClusterView,
               t_slot: int = 0) -> List[Decision]:
    """Ask `policy` for one Decision per arrival and apply each to `view`.

    This is the runtime side of the contract: the policy only *returns*
    Decisions; residual accounting (`view.commit`) happens here, in arrival
    order, so within-slot C2/C3 consumption is always recorded. Legacy
    schedulers (old batch protocol) are driven through their original
    `schedule` call — they commit themselves — and their side effects are
    lifted into Decisions.
    """
    legacy = None
    if isinstance(policy, LegacyPolicyAdapter):
        legacy = policy.legacy
    elif not isinstance(policy, SchedulingPolicy) \
            and callable(getattr(policy, "schedule", None)):
        legacy = policy
    if legacy is not None:
        choices = legacy.schedule(list(arrivals), view, t_slot)
        assert len(choices) == len(arrivals)
        return [Decision(server=int(j),
                         defer_until=float(getattr(r, "defer_until", 0.0)))
                for r, j in zip(arrivals, choices)]

    decisions: List[Decision] = []
    for req in arrivals:
        d = policy.assign(req, view)
        if d.admit:
            # rejected requests consume no capacity: no residual commit
            view.apply(req, d)
        decisions.append(d)
    return decisions


# ---------------------------------------------------------------------------
# Policy registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Tuple[str, Callable[..., SchedulingPolicy]]] = {}


def _normalize(name: str) -> str:
    return "".join(c for c in name.lower() if c.isalnum())


def register_policy(name: str, factory: Optional[Callable] = None):
    """Register a policy factory under `name` (case/punctuation-insensitive).

    Usable as a decorator on a `SchedulingPolicy` subclass::

        @register_policy("perllm")
        class PerLLMScheduler(SchedulingPolicy): ...

    or directly with any callable `factory(n_servers, **kw)`.
    """
    def _register(fac):
        key = _normalize(name)
        _REGISTRY[key] = (name, fac)
        return fac

    return _register(factory) if factory is not None else _register


def available_policies() -> List[str]:
    """Canonical names of every registered policy, sorted."""
    _load_builtin_policies()
    return sorted(display for display, _ in _REGISTRY.values())


def make_policy(name: str, n_servers: int, **kwargs) -> SchedulingPolicy:
    """Construct a registered policy by name.

    Lookup ignores case and punctuation, so "PerLLM", "perllm" and
    "rewardless-guidance" all resolve. Raises KeyError (listing the known
    names) for anything unregistered."""
    _load_builtin_policies()
    key = _normalize(name)
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown scheduling policy {name!r}; available: "
            + ", ".join(available_policies()))
    _, factory = _REGISTRY[key]
    return factory(n_servers, **kwargs)


def _load_builtin_policies() -> None:
    """Import the modules whose import side effect registers the built-ins."""
    import repro.core.baselines  # noqa: F401
    import repro.core.scheduler  # noqa: F401


__all__ = [
    "ClusterView", "Decision", "LegacyPolicyAdapter", "RunningTask",
    "SchedulerBase", "SchedulingPolicy", "as_policy", "available_policies",
    "drive_slot", "make_policy", "register_policy",
]
