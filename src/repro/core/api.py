"""The unified scheduling contract shared by the simulator and live server.

One protocol, two runtimes. A scheduler is a `SchedulingPolicy`: per
request it returns a `Decision` (server, a resource `Allocation`, optional
dispatch deferral, an inference-time correction, and per-constraint slack
diagnostics); after the request completes it receives the realized
`feedback`. The *runtime* — the discrete-event `Simulator` or the live
`PerLLMServer` — owns the `ClusterView` it exposes, applies each Decision's
residual accounting via `ClusterView.commit`, and applies the deferral.
Policies never mutate requests or runtime state directly.

Scheduling *and* resource allocation are one decision (paper Eq. 1 jointly
minimizes energy over both): a `Decision` names not just *where* a request
runs but *how* — the server's DVFS frequency tier and the lane/uplink
shares granted to it. Runtimes scale realized time, energy and ledger
bookings by the allocation; the default `Allocation()` is the nominal tier
with full shares and reproduces the placement-only behavior bit-exactly.

Layering: this module is the bottom of the scheduling stack. It imports
nothing from `repro.cluster`; server specs and requests are structural
(anything with `bandwidth`, `max_concurrency`, `service_time`, …), so both
the simulated testbed and the live engine fleet satisfy it.

Policies register themselves by name (`@register_policy("perllm")`) and are
constructed with `make_policy(name, n_servers, **kw)` — benchmarks,
examples, and the serve CLI all go through the registry.

The pre-PR-1 `SchedulerBase` batch protocol and its `as_policy` shim are
retired: nothing in-tree (or in the docs) subclasses it anymore, and
`drive_slot` drives `SchedulingPolicy.assign` only.
"""
from __future__ import annotations

import dataclasses
from typing import (
    TYPE_CHECKING, Any, Callable, Dict, List, Optional, Sequence, Tuple,
)

import numpy as np

if TYPE_CHECKING:  # type-only: keeps core.api free of upward imports
    from repro.core.constraints import ConstraintSlacks


# ---------------------------------------------------------------------------
# Allocation — how much of the chosen server a request gets
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Allocation:
    """Per-request resource vector carried by a `Decision`.

    freq_tier   index into the server's `spec.freq_tiers` DVFS table;
                -1 selects the nominal tier (frequency 1.0) regardless of
                the table, so allocation-blind policies never need to know
                a server's tier count. At frequency f, inference time
                scales as 1/f and dynamic power as f³ — so energy *per
                token* scales as f²: a slow tier that still meets the
                deadline is strictly cheaper.
    lane_share  fraction of one batch lane's compute granted, in (0, 1]; a
                share s stretches inference by 1/s while drawing s of the
                lane's dynamic power (per-request energy is
                share-invariant — the share is a latency/capacity knob)
    bw_share    fraction of the (factor-adjusted) uplink granted to the
                transfer, in (0, 1]; stretches the transfer by 1/s while
                the radio draws s of `tx_power`

    Shares use exclusive-window semantics: the lane/link is booked for the
    stretched duration, so concurrently committed shares can never
    oversubscribe a resource (property-tested in
    `tests/test_allocation.py`).
    """

    freq_tier: int = -1
    lane_share: float = 1.0
    bw_share: float = 1.0

    def __post_init__(self):
        if not (0.0 < self.lane_share <= 1.0):
            raise ValueError(f"lane_share must be in (0, 1], got "
                             f"{self.lane_share}")
        if not (0.0 < self.bw_share <= 1.0):
            raise ValueError(f"bw_share must be in (0, 1], got "
                             f"{self.bw_share}")

    def freq(self, spec) -> float:
        """Resolved frequency on `spec` (1.0 for the nominal tier)."""
        if self.freq_tier < 0:
            return 1.0
        return float(spec.freq_tiers[self.freq_tier])


#: The nominal allocation: nominal frequency tier, full lane and uplink.
NOMINAL = Allocation()


# ---------------------------------------------------------------------------
# Decision — what a policy returns for one request
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, slots=True)
class Decision:
    """One request's placement + allocation, returned by
    `SchedulingPolicy.assign`.

    server          index of the chosen server (C4: exactly one per
                    request; for a rejection it names the server the
                    policy *would* have used — learners need an arm index)
    alloc           the resource `Allocation` granted on that server
                    (DVFS tier, lane share, uplink share); the default is
                    nominal-everything, which runtimes honor bit-exactly
                    as the placement-only behavior
    defer_until     earliest dispatch time; 0.0 = dispatch on arrival (used
                    by deferred-batching policies such as FineInfer)
    infer_scale     multiplicative correction the policy has learned for
                    the nominal inference-time model on this server; the
                    runtime commits lane residuals scaled by it (applied
                    on top of the allocation's 1/(f·lane_share) stretch)
    slacks          per-constraint slack diagnostics (C1/C2/C3/C5) at
                    decision time, evaluated *at the chosen allocation* —
                    observational
    admit           False = admission control sheds the request: the
                    runtime emits a rejected Outcome (SLO-violation cost,
                    zero server energy) instead of queueing it
    preempt_victim  sid of a running request whose batch lane should be
                    returned before this request dispatches; the victim's
                    remaining decode tokens are requeued as a new Arrival
    preempt_drop_kv KV-resume info carried with the preemption: False
                    (default) keeps the victim's KV pages resident on its
                    server, so a same-server requeue resumes decode with
                    zero re-prefill; True frees the pages immediately —
                    the right call when the preemption is relieving KV
                    *memory* exhaustion rather than reclaiming a lane
                    (ignored on servers that don't model KV)
    migrate_kv      the request holds preserved KV pages on another server
                    (`req.kv_server`) and the policy wants them *shipped*
                    to `server` over the link topology instead of
                    re-prefilled: the runtime books the transfer bytes on
                    every link of the migration path and the request
                    resumes decode on `server` with zero re-prefill once
                    the `KvMigrate` event lands. Ignored when the request
                    holds no pages, when `server` IS the KV home (a plain
                    resume is free), or when the destination cannot host
                    the pages (the legacy orphan-and-re-prefill path runs
                    instead). Event-driven runtimes only.
    """

    server: int
    alloc: Allocation = NOMINAL
    defer_until: float = 0.0
    infer_scale: float = 1.0
    slacks: Optional["ConstraintSlacks"] = None
    admit: bool = True
    preempt_victim: Optional[int] = None
    preempt_drop_kv: bool = False
    migrate_kv: bool = False


# ---------------------------------------------------------------------------
# ClusterView — the one observation object both runtimes build
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunningTask:
    """One in-flight request, as exposed to preemption-capable policies.

    `finish_est` is the runtime's current completion estimate for the
    booked lane; `deadline_at` is the absolute SLO instant
    (arrival + deadline). A task with `finish_est > deadline_at` is doomed
    — preempting it frees its lane without costing an extra SLO miss.
    `tier` is the DVFS tier the task is running at (-1 = nominal).
    """

    sid: int
    server: int
    class_id: int
    deadline_at: float
    begin: float        # when its lane booking starts (may be in the past)
    finish_est: float
    tier: int = -1

    @property
    def doomed(self) -> bool:
        return self.finish_est > self.deadline_at


@dataclasses.dataclass
class ClusterView:
    """What a policy may observe when assigning one slot's arrivals.

    Built by the runtime from *real* state: per-server uplink occupancy,
    batch-lane occupancy, and the current bandwidth factor of each link.
    Mutable residuals (`uplink_free_at`, `lane_free`) are advanced by the
    runtime's `commit` after each Decision, so later requests in the same
    slot see the reduced capacity (the combinatorial super-arm accounting).
    Hidden runtime state (efficiency, noise) is NOT here.

    Per-server `bw_factor` / `uplink_free_at` are *path-effective* values
    when the runtime models a `LinkTopology` (bottleneck bandwidth over
    the server's link path, latest path-link backlog), so the nominal
    predictors work unchanged. Topology-aware policies can additionally
    read the per-link fields:

    link_bw     observed bits/s per named link (capacity × factor × scale)
    link_queue  seconds of serialized backlog per named link
    paths       link names each server's ingress traffic traverses
    running     per-server in-flight tasks (`RunningTask`, including the
                tier each runs at) — what a preemption-capable policy may
                name as `preempt_victim`; None when the runtime does not
                support preemption

    KV memory — the binding resource for LLM decode on edge hardware — is
    first-class when the runtime models it (paged engines / `ServerSpec`s
    with a block pool):

    kv_free_blocks   free KV-cache blocks per server right now
    kv_total_blocks  each server's block-pool size; an entry of 0 means
                     that server does not model KV (its kv_free_blocks
                     entry is meaningless and the KV constraint is vacuous)
    kv_prefix_tokens per-server map of shared-prefix pool id ->
                     resident *ready* prefix tokens: how much of that
                     system prompt's KV is already prefilled on the
                     server. `prefix_hit_tokens(req, j)` turns it into
                     the prefill tokens request `req` would skip on j;
                     None when the runtime models no prefix sharing.

    Allocation state — the committed-share ledger IS `uplink_free_at` /
    `lane_free` (shares use exclusive stretched-window bookings, so a
    resource is never >100% committed); `tier_load`, when the runtime
    models multiple DVFS tiers, additionally splits each server's
    committed lane-seconds by frequency tier (advanced by `commit`), so
    tier-aware policies can see how a server's capacity is currently
    paced.
    """

    t: float
    specs: Sequence[Any]            # ServerSpec-shaped objects
    bw_factor: List[float]
    uplink_free_at: List[float]
    lane_free: List[List[float]]
    link_bw: Optional[Dict[str, float]] = None
    link_queue: Optional[Dict[str, float]] = None
    paths: Optional[Sequence[Sequence[str]]] = None
    running: Optional[List[List[RunningTask]]] = None
    kv_free_blocks: Optional[List[int]] = None
    kv_total_blocks: Optional[List[int]] = None
    kv_prefix_tokens: Optional[List[Dict[int, int]]] = None
    tier_load: Optional[List[List[float]]] = None

    @property
    def n_servers(self) -> int:
        return len(self.specs)

    # ---------------- KV affinity helpers --------------------------------
    def prefix_hit_tokens(self, req, j: int) -> int:
        """Prefill tokens `req` would skip on server j thanks to resident
        shared-prefix pages (0 without prefix modeling or a match).

        Clipped to full blocks of the request's *own* shared prefix and
        to strictly less than its prompt (>= 1 token must still prefill
        to produce logits)."""
        if self.kv_prefix_tokens is None:
            return 0
        pid = getattr(req, "prefix_id", -1)
        if pid < 0:
            return 0
        resident = self.kv_prefix_tokens[j].get(pid, 0)
        if resident <= 0:
            return 0
        bt = getattr(self.specs[j], "kv_block_tokens", 0)
        if bt <= 0:
            return 0
        own = min(getattr(req, "prefix_tokens", 0), req.prompt_tokens - 1)
        return min(resident, (own // bt) * bt)

    def kv_migration_s(self, req, dst: int) -> Optional[float]:
        """Predicted seconds to ship `req`'s preserved KV pages from
        their current home to server `dst` over the link topology —
        the migration-cost slack policies weigh against re-prefill.
        None when the request holds no pages or links aren't modeled."""
        src = getattr(req, "kv_server", -1)
        n_blocks = getattr(req, "kv_blocks", 0)
        if src < 0 or n_blocks <= 0 or src == dst:
            return None
        if self.link_bw is None or self.paths is None:
            return None
        src_spec = self.specs[src]
        bt = getattr(src_spec, "kv_block_tokens", 0)
        per_tok = getattr(src_spec, "kv_bytes_per_token", None)
        if bt <= 0 or per_tok is None:
            return None
        path: List[str] = []
        for name in list(self.paths[src]) + list(self.paths[dst]):
            if name not in path:
                path.append(name)
        bw = min(self.link_bw[name] for name in path)
        if bw <= 0:
            return None
        queue = max((self.link_queue or {}).get(name, 0.0)
                    for name in path)
        bits = n_blocks * bt * per_tok() * 8.0
        return queue + bits / bw

    def n_tiers(self, j: int) -> int:
        """Size of server j's DVFS table (1 when the spec predates tiers)."""
        return len(getattr(self.specs[j], "freq_tiers", (1.0,)))

    # ---------------- nominal predictors (no hidden factors) -------------
    def predict_tx(self, req, j: int,
                   alloc: Optional[Allocation] = None) -> float:
        spec = self.specs[j]
        share = 1.0 if alloc is None else alloc.bw_share
        start = max(self.t, self.uplink_free_at[j])
        dur = req.payload_bytes * 8.0 \
            / (spec.bandwidth * self.bw_factor[j] * share)
        return (start - self.t) + dur

    def predict_queue(self, req, j: int,
                      alloc: Optional[Allocation] = None) -> float:
        ready = self.t + self.predict_tx(req, j, alloc)
        lane = min(self.lane_free[j])
        return max(lane - ready, 0.0)

    def predict_infer(self, req, j: int,
                      alloc: Optional[Allocation] = None) -> float:
        nominal = self.specs[j].service_time(req.prompt_tokens,
                                             req.output_tokens)
        if alloc is None:
            return nominal
        return nominal / (alloc.freq(self.specs[j]) * alloc.lane_share)

    def predict_total(self, req, j: int,
                      alloc: Optional[Allocation] = None) -> float:
        return (self.predict_tx(req, j, alloc)
                + self.predict_queue(req, j, alloc)
                + self.predict_infer(req, j, alloc))

    # ---------------- residual accounting (runtime-applied) --------------
    def commit(self, req, j: int, infer_scale: float = 1.0,
               alloc: Optional[Allocation] = None) -> None:
        """Update residuals as if req were placed on j under `alloc`.

        Called by the runtime (`drive_slot`), not by policies — that is what
        guarantees C2/C3 accounting cannot be silently skipped. Allocation
        shares book their *stretched* windows exclusively (a half-share
        transfer occupies the uplink twice as long), so the committed-share
        ledger can never oversubscribe; a non-nominal tier books the
        slowed lane window and is tallied in `tier_load`."""
        spec = self.specs[j]
        share = 1.0 if alloc is None else alloc.bw_share
        start = max(self.t, self.uplink_free_at[j])
        dur = req.payload_bytes * 8.0 \
            / (spec.bandwidth * self.bw_factor[j] * share)
        self.uplink_free_at[j] = start + dur
        ready = start + dur
        lanes = self.lane_free[j]
        # first-occurrence min, same lane np.argmin picked; a plain loop
        # skips the list->ndarray round-trip that dominated this method
        li = 0
        lane_min = lanes[0]
        for k in range(1, len(lanes)):
            if lanes[k] < lane_min:
                li = k
                lane_min = lanes[k]
        begin = max(ready, lane_min)
        # predict_infer, inlined (hot path: once per admitted request)
        nominal = spec.service_time(req.prompt_tokens, req.output_tokens)
        if alloc is not None:
            nominal = nominal / (alloc.freq(spec) * alloc.lane_share)
        booked = nominal * infer_scale
        lanes[li] = begin + booked
        if self.tier_load is not None:
            tier = -1 if alloc is None else alloc.freq_tier
            if tier < 0:
                tier = getattr(spec, "nominal_tier", 0)
            self.tier_load[j][tier] += booked

    def apply(self, req, decision: Decision) -> None:
        """Commit one Decision's residuals (placement + allocation)."""
        self.commit(req, decision.server, infer_scale=decision.infer_scale,
                    alloc=decision.alloc)


# ---------------------------------------------------------------------------
# SchedulingPolicy — the contract
# ---------------------------------------------------------------------------


class SchedulingPolicy:
    """Per-request scheduling contract.

    Subclasses implement `assign` (pure with respect to the view: no
    `commit`, no request mutation) and optionally `feedback`.
    """

    name = "policy"

    def assign(self, request, view: ClusterView) -> Decision:
        raise NotImplementedError

    def feedback(self, request, outcome) -> None:
        """Realized outcome for a previously assigned request."""


def ensure_policy(policy) -> SchedulingPolicy:
    """Validate that `policy` implements the `SchedulingPolicy` contract.

    The legacy batch `SchedulerBase` protocol is retired; anything that
    only offers `.schedule` gets a migration-pointing TypeError instead of
    a silent shim. Duck-typed policies must provide the *whole* runtime
    surface (`assign`, `feedback`, `name`) so an incomplete object fails
    here, at run start, rather than mid-simulation at its first completed
    request."""
    if isinstance(policy, SchedulingPolicy):
        return policy
    if callable(getattr(policy, "assign", None)) \
            and callable(getattr(policy, "feedback", None)) \
            and isinstance(getattr(policy, "name", None), str):
        return policy
    raise TypeError(
        f"{type(policy).__name__} does not implement SchedulingPolicy "
        "(.assign/.feedback/.name); the legacy SchedulerBase batch "
        "protocol was removed — see docs/scheduling_api.md for the "
        "migration recipe")


# ---------------------------------------------------------------------------
# Runtime driver — the one place Decisions are applied
# ---------------------------------------------------------------------------


def drive_slot(policy, arrivals: Sequence[Any], view: ClusterView,
               t_slot: int = 0) -> List[Decision]:
    """Ask `policy` for one Decision per arrival and apply each to `view`.

    This is the runtime side of the contract: the policy only *returns*
    Decisions; residual accounting (`view.commit`) happens here, in arrival
    order, so within-slot C2/C3 consumption is always recorded.
    """
    policy = ensure_policy(policy)
    decisions: List[Decision] = []
    for req in arrivals:
        d = policy.assign(req, view)
        if d.admit:
            # rejected requests consume no capacity: no residual commit
            view.apply(req, d)
        decisions.append(d)
    return decisions


# ---------------------------------------------------------------------------
# Policy registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Tuple[str, Callable[..., SchedulingPolicy]]] = {}


def _normalize(name: str) -> str:
    return "".join(c for c in name.lower() if c.isalnum())


def register_policy(name: str, factory: Optional[Callable] = None):
    """Register a policy factory under `name` (case/punctuation-insensitive).

    Usable as a decorator on a `SchedulingPolicy` subclass::

        @register_policy("perllm")
        class PerLLMScheduler(SchedulingPolicy): ...

    or directly with any callable `factory(n_servers, **kw)`.
    """
    def _register(fac):
        key = _normalize(name)
        _REGISTRY[key] = (name, fac)
        return fac

    return _register(factory) if factory is not None else _register


def available_policies() -> List[str]:
    """Canonical names of every registered policy, sorted."""
    _load_builtin_policies()
    return sorted(display for display, _ in _REGISTRY.values())


def make_policy(name: str, n_servers: int, **kwargs) -> SchedulingPolicy:
    """Construct a registered policy by name.

    Lookup ignores case and punctuation, so "PerLLM", "perllm" and
    "rewardless-guidance" all resolve. Raises KeyError (listing the known
    names) for anything unregistered."""
    _load_builtin_policies()
    key = _normalize(name)
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown scheduling policy {name!r}; available: "
            + ", ".join(available_policies()))
    _, factory = _REGISTRY[key]
    return factory(n_servers, **kwargs)


def _load_builtin_policies() -> None:
    """Import the modules whose import side effect registers the built-ins."""
    import repro.core.baselines  # noqa: F401
    import repro.core.scheduler  # noqa: F401


__all__ = [
    "Allocation", "ClusterView", "Decision", "NOMINAL", "RunningTask",
    "SchedulingPolicy", "available_policies", "drive_slot", "ensure_policy",
    "make_policy", "register_policy",
]
