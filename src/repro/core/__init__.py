"""The paper's primary contribution: CS-UCB scheduling with edge-cloud
collaboration (PerLLM, Alg. 1) plus the compared baselines."""
from repro.core.bandit import CSUCB, CSUCBParams
from repro.core.baselines import AGOD, FineInfer, RewardlessGuidance, make_baselines
from repro.core.constraints import ConstraintSlacks, evaluate_constraints
from repro.core.scheduler import PerLLMScheduler

__all__ = [
    "AGOD", "CSUCB", "CSUCBParams", "ConstraintSlacks", "FineInfer",
    "PerLLMScheduler", "RewardlessGuidance", "evaluate_constraints",
    "make_baselines",
]
