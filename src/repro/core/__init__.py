"""The paper's primary contribution: CS-UCB scheduling with edge-cloud
collaboration (PerLLM, Alg. 1), the compared baselines, and the unified
`SchedulingPolicy` API both runtimes drive."""
from repro.core.api import (
    Allocation, ClusterView, Decision, NOMINAL, RunningTask,
    SchedulingPolicy, available_policies, drive_slot, ensure_policy,
    make_policy, register_policy,
)
from repro.core.bandit import CSUCB, CSUCBParams
from repro.core.runtime import (
    Arrival, BandwidthChange, Deferred, Event, EventLoop, InferDone,
    InferStart, KVPressureScenario, Preempt, Reject, Runtime, Scenario,
    TxDone, available_scenarios, make_scenario, register_scenario,
)
from repro.core.baselines import AGOD, FineInfer, RewardlessGuidance, make_baselines
from repro.core.constraints import ConstraintSlacks, evaluate_constraints
from repro.core.scheduler import PerLLMScheduler

__all__ = [
    "AGOD", "Allocation", "Arrival", "BandwidthChange", "CSUCB",
    "CSUCBParams", "ClusterView", "ConstraintSlacks", "Decision", "Deferred",
    "Event", "EventLoop", "FineInfer", "InferDone", "InferStart",
    "KVPressureScenario", "NOMINAL", "PerLLMScheduler",
    "Preempt", "Reject",
    "RewardlessGuidance", "Runtime", "RunningTask", "Scenario",
    "SchedulingPolicy", "TxDone",
    "available_policies", "available_scenarios", "drive_slot",
    "ensure_policy", "evaluate_constraints", "make_baselines", "make_policy",
    "make_scenario", "register_policy", "register_scenario",
]
