"""The paper's primary contribution: CS-UCB scheduling with edge-cloud
collaboration (PerLLM, Alg. 1), the compared baselines, and the unified
`SchedulingPolicy` API both runtimes drive."""
from repro.core.api import (
    ClusterView, Decision, LegacyPolicyAdapter, SchedulerBase,
    SchedulingPolicy, as_policy, available_policies, drive_slot, make_policy,
    register_policy,
)
from repro.core.bandit import CSUCB, CSUCBParams
from repro.core.baselines import AGOD, FineInfer, RewardlessGuidance, make_baselines
from repro.core.constraints import ConstraintSlacks, evaluate_constraints
from repro.core.scheduler import PerLLMScheduler

__all__ = [
    "AGOD", "CSUCB", "CSUCBParams", "ClusterView", "ConstraintSlacks",
    "Decision", "FineInfer", "LegacyPolicyAdapter", "PerLLMScheduler",
    "RewardlessGuidance", "SchedulerBase", "SchedulingPolicy", "as_policy",
    "available_policies", "drive_slot", "evaluate_constraints",
    "make_baselines", "make_policy", "register_policy",
]
