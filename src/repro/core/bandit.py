"""CS-UCB: Constraint-Satisfaction Upper Confidence Bound (paper Alg. 1).

Combinatorial MAB view (§3.2): the per-slot assignment of all arriving
services is a *super arm*; each base action a = (service class, server,
DVFS tier) — the paper's joint "service scheduling and resource
allocation" decision. With a single (nominal) tier this degenerates to the
classic (class, server) arm space. The algorithm keeps, per base action:

    R̄(a)     — running mean of the shaped reward (Eq. 4)
    L(a, t)  — pull count
    V̄(a)     — running mean violation severity (drives the penalty P(t))

and selects, among constraint-satisfying actions,

    a_t = argmax R̄(a) + δ·sqrt(ln t / L(a,t)) + θ·P(a,t)      (Eq. 6)

with P(a,t) = −V̄(a) (penalty proportional to the observed degree of
violation, §3.3). The approximate regret (Eq. 5) is tracked against the
best-in-hindsight arm per class with approximation coefficients α, β < 1.

Reward shaping note: Eq. 4's r = −E_norm + λ·f(y) enters with f(y) clipped
into [−1, 0] — violations are penalized in proportion to their severity,
but *surplus* slack earns nothing. Eq. 1 minimizes energy subject to the
constraints; rewarding surplus slack would make the bandit prefer the
fastest feasible allocation over the cheapest one, which is exactly
backwards for DVFS tier selection (a slower tier deliberately spends slack
to save energy).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple, Union

import numpy as np

from repro.obs.trace import KIND_ARM


@dataclasses.dataclass
class CSUCBParams:
    lam: float = 1.0        # λ — weight of f(y) inside the reward (Eq. 4)
    alpha: float = 0.9      # α — approximation coefficient (Eq. 5)
    beta: float = 0.95      # β — approximation coefficient (Eq. 5)
    delta: float = 0.35     # δ — exploration strength (Eq. 6)
    theta: float = 1.0      # θ — penalty weight (Eq. 6 / Eq. 7)
    optimistic_init: float = 0.5


class CSUCB:
    """Per-(class, server, tier) UCB statistics with constraint shaping.

    `n_tiers=1` (the default) is the placement-only arm space; masks and
    arm indices may then be plain per-server vectors/ints, so existing
    call sites are unchanged. With `n_tiers > 1` masks are
    (n_servers, n_tiers) boolean grids and `select` returns the
    (server, tier) pair.
    """

    def __init__(self, n_classes: int, n_servers: int,
                 params: Optional[CSUCBParams] = None, seed: int = 0,
                 n_tiers: int = 1):
        self.p = params or CSUCBParams()
        self.n_classes = n_classes
        self.n_servers = n_servers
        self.n_tiers = n_tiers
        shape = (n_classes, n_servers, n_tiers)
        self.mean = np.full(shape, self.p.optimistic_init, np.float64)
        self.count = np.zeros(shape, np.int64)
        self.violation = np.zeros(shape, np.float64)
        self.t = 0
        # regret accounting (Eq. 5)
        self.cum_reward = 0.0
        self.cum_best = 0.0
        self.regret_trace: List[float] = []
        # optional repro.obs.TraceRecorder: every `update` (the single
        # arm-pull point) lands one ARM row — pull index, arm coords,
        # reward, violation — for the report CLI's bandit timeline
        self.trace = None

    # ------------------------------------------------------------------
    def _grid_mask(self, feasible_mask: np.ndarray) -> np.ndarray:
        mask = np.asarray(feasible_mask, bool)
        if mask.ndim == 1:
            if self.n_tiers != 1:
                raise ValueError(
                    f"per-server mask of shape {mask.shape} given, but the "
                    f"arm space has {self.n_tiers} tiers — pass a "
                    f"(n_servers, n_tiers) mask")
            mask = mask[:, None]
        return mask

    def ucb(self, cls: int, feasible_mask: np.ndarray) -> np.ndarray:
        """Eq. 6 scores for one service class; −inf outside the mask.

        Pure scoring: bandit time `t` only advances in `update()`, so
        diagnostics (or double scoring) never perturb exploration. The
        returned array matches the mask's shape ((n_servers,) masks come
        back as per-server scores)."""
        mask = self._grid_mask(feasible_mask)
        logt = math.log(max(self.t, 2))
        cnt = np.maximum(self.count[cls], 1)
        explore = self.p.delta * np.sqrt(logt / cnt)
        bonus = np.where(self.count[cls] == 0, 1e3, 0.0)  # force first pull
        penalty = -self.p.theta * self.violation[cls]
        score = self.mean[cls] + explore + bonus + penalty
        score = np.where(mask, score, -np.inf)
        if np.asarray(feasible_mask).ndim == 1:
            return score[:, 0]
        return score

    def select(self, cls: int,
               feasible_mask: np.ndarray) -> Union[int, Tuple[int, int]]:
        """Best arm under Eq. 6. A per-server mask returns the server
        index; a (server, tier) grid mask returns the (server, tier)
        pair."""
        grid = np.asarray(feasible_mask).ndim > 1
        mask = self._grid_mask(feasible_mask)
        score = self.ucb(cls, mask)
        if not np.isfinite(score).any():
            # no feasible arm: fall back to least-violating arm (paper: the
            # service is assigned to the most resource-rich server)
            score = self.mean[cls] - self.p.theta * self.violation[cls]
        j, k = np.unravel_index(int(np.argmax(score)), score.shape)
        return (int(j), int(k)) if grid else int(j)

    # ------------------------------------------------------------------
    def shaped_reward(self, energy_norm: float, f_y: float) -> float:
        """Eq. 4: r = −E_norm + λ·f(y), with f(y) clipped into [−1, 0]
        (violations penalized, surplus slack not rewarded — see module
        docstring)."""
        f = f_y if f_y > -1.0 else -1.0
        if f > 0.0:
            f = 0.0
        return -energy_norm + self.p.lam * f

    def update(self, cls: int, server: int, reward: float,
               violation_severity: float, tier: int = 0) -> None:
        self.t += 1
        a = (cls, server, tier)
        self.count[a] += 1
        n = self.count[a]
        self.mean[a] += (reward - self.mean[a]) / n
        v = self.violation[a]
        self.violation[a] = v + (max(violation_severity, 0.0) - v) / n
        if violation_severity > 0.0 and self.n_tiers > 1:
            # congestion coupling: a C1 violation is a *server*-level event
            # (lane backlog from every tier's bookings), so the penalty
            # P(t) bleeds into the sibling tier arms of (cls, server) at
            # half weight — otherwise slow tiers keep looking safe while
            # their stretched bookings doom later nominal-tier requests on
            # the same host
            for k in range(self.n_tiers):
                if k == tier:
                    continue
                s = (cls, server, k)
                cnt = max(int(self.count[s]), 1)
                self.violation[s] += \
                    (violation_severity - self.violation[s]) / (2 * cnt)

        # Eq. 5 approximate regret vs best-in-hindsight arm of this class
        best = float(np.max(self.mean[cls]))
        self.cum_best += self.p.alpha * self.p.beta * best
        self.cum_reward += reward
        self.regret_trace.append(self.cum_best - self.cum_reward)

        if self.trace is not None:
            # ARM row: sid = pull index (the bandit's clock), energy =
            # reward, value = violation severity
            self.trace.append(KIND_ARM, self.t, float(self.t),
                              float(self.t), server, cls, tier,
                              reward, violation_severity)

    # ------------------------------------------------------------------
    @property
    def regret(self) -> float:
        return self.regret_trace[-1] if self.regret_trace else 0.0

    def regret_bound(self) -> float:
        """Eq. 7: sqrt(2·|A|·log L) + θ·P̄ with L = max pulls.

        |A| is derived from the live arm-space shape (classes × servers ×
        tiers), not hardcoded — expanding the arm space (e.g. enabling
        DVFS tiers) widens the bound accordingly."""
        big_l = max(int(self.count.max()), 2)
        p_bar = float(np.mean(self.violation))
        return math.sqrt(2.0 * self.mean.size
                         * math.log(big_l)) + self.p.theta * p_bar
