"""CS-UCB: Constraint-Satisfaction Upper Confidence Bound (paper Alg. 1).

Combinatorial MAB view (§3.2): the per-slot assignment of all arriving
services is a *super arm*; each base action a = (service class, server).
The algorithm keeps, per base action:

    R̄(a)     — running mean of the shaped reward (Eq. 4)
    L(a, t)  — pull count
    V̄(a)     — running mean violation severity (drives the penalty P(t))

and selects, among constraint-satisfying actions,

    a_t = argmax R̄(a) + δ·sqrt(ln t / L(a,t)) + θ·P(a,t)      (Eq. 6)

with P(a,t) = −V̄(a) (penalty proportional to the observed degree of
violation, §3.3). The approximate regret (Eq. 5) is tracked against the
best-in-hindsight arm per class with approximation coefficients α, β < 1.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class CSUCBParams:
    lam: float = 1.0        # λ — weight of f(y) inside the reward (Eq. 4)
    alpha: float = 0.9      # α — approximation coefficient (Eq. 5)
    beta: float = 0.95      # β — approximation coefficient (Eq. 5)
    delta: float = 0.35     # δ — exploration strength (Eq. 6)
    theta: float = 1.0      # θ — penalty weight (Eq. 6 / Eq. 7)
    optimistic_init: float = 0.5


class CSUCB:
    """Per-(class, server) UCB statistics with constraint shaping."""

    def __init__(self, n_classes: int, n_servers: int,
                 params: Optional[CSUCBParams] = None, seed: int = 0):
        self.p = params or CSUCBParams()
        self.n_classes = n_classes
        self.n_servers = n_servers
        self.mean = np.full((n_classes, n_servers),
                            self.p.optimistic_init, np.float64)
        self.count = np.zeros((n_classes, n_servers), np.int64)
        self.violation = np.zeros((n_classes, n_servers), np.float64)
        self.t = 0
        # regret accounting (Eq. 5)
        self.cum_reward = 0.0
        self.cum_best = 0.0
        self.regret_trace: List[float] = []

    # ------------------------------------------------------------------
    def ucb(self, cls: int, feasible_mask: np.ndarray) -> np.ndarray:
        """Eq. 6 scores for one service class; −inf outside the mask.

        Pure scoring: bandit time `t` only advances in `update()`, so
        diagnostics (or double scoring) never perturb exploration."""
        logt = math.log(max(self.t, 2))
        cnt = np.maximum(self.count[cls], 1)
        explore = self.p.delta * np.sqrt(logt / cnt)
        bonus = np.where(self.count[cls] == 0, 1e3, 0.0)  # force first pull
        penalty = -self.p.theta * self.violation[cls]
        score = self.mean[cls] + explore + bonus + penalty
        return np.where(feasible_mask, score, -np.inf)

    def select(self, cls: int, feasible_mask: np.ndarray) -> int:
        score = self.ucb(cls, feasible_mask)
        if not np.isfinite(score).any():
            # no feasible arm: fall back to least-violating arm (paper: the
            # service is assigned to the most resource-rich server)
            score = self.mean[cls] - self.p.theta * self.violation[cls]
        return int(np.argmax(score))

    # ------------------------------------------------------------------
    def shaped_reward(self, energy_norm: float, f_y: float) -> float:
        """Eq. 4: r = −E_norm + λ·f(y) (f clipped into a bounded range)."""
        return -energy_norm + self.p.lam * float(np.clip(f_y, -1.0, 1.0))

    def update(self, cls: int, server: int, reward: float,
               violation_severity: float) -> None:
        self.t += 1
        self.count[cls, server] += 1
        n = self.count[cls, server]
        self.mean[cls, server] += (reward - self.mean[cls, server]) / n
        v = self.violation[cls, server]
        self.violation[cls, server] = v + (max(violation_severity, 0.0) - v) / n

        # Eq. 5 approximate regret vs best-in-hindsight arm of this class
        best = float(np.max(self.mean[cls]))
        self.cum_best += self.p.alpha * self.p.beta * best
        self.cum_reward += reward
        self.regret_trace.append(self.cum_best - self.cum_reward)

    # ------------------------------------------------------------------
    @property
    def regret(self) -> float:
        return self.regret_trace[-1] if self.regret_trace else 0.0

    def regret_bound(self) -> float:
        """Eq. 7: sqrt(2·M·N·log L) + θ·P̄ with L = max pulls."""
        big_l = max(int(self.count.max()), 2)
        p_bar = float(np.mean(self.violation))
        return math.sqrt(2.0 * self.n_classes * self.n_servers
                         * math.log(big_l)) + self.p.theta * p_bar
