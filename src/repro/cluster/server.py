"""Edge/cloud server model with an analytic LLM cost model.

The per-request cost model is derived from the deployed model's config
(`repro.configs`):  prefill is compute-bound (2·N_active FLOPs/token), decode
is the max of the compute and weight-streaming (memory-bandwidth) terms — the
same roofline logic used for the TPU dry-run, applied to the cluster.

DVFS frequency tiers (`freq_tiers`) make per-request compute allocation a
schedulable resource: at a tier of relative frequency f, inference time
scales as 1/f and dynamic (active-over-idle) power as f³ — the classic
cubic CV²f law — so energy *per token* scales as f². The table's nominal
tier is f = 1.0 and reproduces the untier'd cost model bit-exactly; the
default table is the single nominal tier, so existing testbeds are
unchanged unless tiers are asked for.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Tuple

from repro.configs import get_config

# A defensible DVFS ladder for both Xeon edges and the A100/TPU cloud:
# deep-idle-ish 40%, two intermediate steps, and the nominal clock.
DVFS_TIERS: Tuple[float, ...] = (0.4, 0.55, 0.7, 1.0)


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    name: str
    kind: str                 # "edge" | "cloud"
    arch_id: str              # deployed model
    flops: float              # sustained FLOP/s for LLM inference
    mem_bw: float             # bytes/s effective weight-streaming bandwidth
    power_active: float       # W while computing (at the nominal tier)
    power_idle: float         # W on standby
    tx_power: float           # W attributable to an active transfer
    bandwidth: float          # bits/s uplink capacity
    max_concurrency: int      # batch lanes
    weight_bytes_per_param: float = 1.0   # int8 deployment
    # paged KV-cache pool: 0 blocks = KV memory not modeled (legacy
    # behavior — capacity is lanes only and preemption always re-prefills)
    kv_blocks: int = 0        # block-pool size
    kv_block_tokens: int = 16  # tokens of KV per block
    # DVFS table: selectable relative frequencies, nominal = 1.0. The
    # single-entry default keeps the placement-only cost model bit-exact.
    freq_tiers: Tuple[float, ...] = (1.0,)

    def __post_init__(self):
        if not self.freq_tiers or any(f <= 0.0 for f in self.freq_tiers):
            raise ValueError(f"freq_tiers must be positive, got "
                             f"{self.freq_tiers}")

    # ------------------------------------------------------------------
    @property
    def n_tiers(self) -> int:
        return len(self.freq_tiers)

    @property
    def nominal_tier(self) -> int:
        """Index of the tier closest to frequency 1.0 (the calibration
        point: the spec's flops/mem_bw/power_active describe this tier)."""
        return min(range(len(self.freq_tiers)),
                   key=lambda k: abs(self.freq_tiers[k] - 1.0))

    def tier_freq(self, tier: int = -1) -> float:
        """Relative frequency of `tier`; -1 = nominal (exactly 1.0)."""
        if tier < 0:
            return 1.0
        return float(self.freq_tiers[tier])

    # ------------------------------------------------------------------
    def model_cfg(self):
        return get_config(self.arch_id)

    # The config-derived constants below are immutable per spec but sit on
    # every per-arrival cost prediction; cached_property stores them in the
    # instance __dict__ (legal on a frozen, non-slots dataclass) so the
    # config walk runs once per spec instead of once per predicted time.
    @functools.cached_property
    def _active_params(self) -> float:
        return float(self.model_cfg().active_param_count())

    @functools.cached_property
    def _kv_bytes_per_token(self) -> float:
        return float(self.model_cfg().kv_bytes_per_token())

    @functools.cached_property
    def _decode_weight_stream(self) -> float:
        # same expression decode_step_time evaluated inline before caching
        return (self._active_params * self.weight_bytes_per_param
                / self.mem_bw)

    def active_params(self) -> float:
        return self._active_params

    def prefill_time(self, prompt_tokens: int, tier: int = -1) -> float:
        fl = 2.0 * self._active_params * prompt_tokens
        return fl / self.flops / self.tier_freq(tier)

    def decode_step_time(self, batch: int = 1, tier: int = -1) -> float:
        """Seconds per decode step for a batch (memory- vs compute-bound),
        at DVFS tier `tier` (time ∝ 1/f)."""
        weight_stream = self._decode_weight_stream
        compute = batch * 2.0 * self._active_params / self.flops
        return max(weight_stream, compute) / self.tier_freq(tier)

    def decode_time(self, output_tokens: int, batch: int = 1,
                    tier: int = -1) -> float:
        return output_tokens * self.decode_step_time(batch, tier)

    @functools.cached_property
    def _service_memo(self) -> dict:
        # one-entry memo (cleared on every miss, so it never grows): each
        # dispatched request evaluates service_time twice back-to-back with
        # the same arguments — once in the view's nominal predictor, once
        # in the runtime's realized draw
        return {}

    def service_time(self, prompt_tokens: int, output_tokens: int,
                     batch: int = 1, tier: int = -1) -> float:
        memo = self._service_memo
        key = (prompt_tokens, output_tokens, batch, tier)
        hit = memo.get(key)
        if hit is None:
            hit = self.prefill_time(prompt_tokens, tier) \
                + self.decode_time(output_tokens, batch, tier)
            memo.clear()
            memo[key] = hit
        return hit

    def tx_time(self, payload_bytes: float, share: float = 1.0) -> float:
        """share: fraction of the uplink granted to this transfer."""
        return payload_bytes * 8.0 / (self.bandwidth * max(share, 1e-9))

    def kv_blocks_needed(self, prompt_tokens: int,
                         output_tokens: int) -> int:
        """KV blocks a request occupies end-to-end (prompt + all decoded
        tokens, allocated up front like the paged engine does)."""
        return max(1, math.ceil((prompt_tokens + output_tokens)
                                / self.kv_block_tokens))

    def kv_bytes_per_token(self) -> float:
        """KV-cache bytes one token pins on this server's model — the
        wire size of a KV migration is `blocks × block_tokens × this`."""
        return self._kv_bytes_per_token

    def infer_energy(self, t_inf: float, tier: int = -1,
                     lane_share: float = 1.0) -> float:
        """Active-over-idle energy for `t_inf` seconds on one batch lane —
        the one formula every runtime charges inference with.

        `t_inf` is the *realized* (already tier/share-stretched) window;
        dynamic power scales as f³ with the tier's frequency and linearly
        with the lane share, so per-token energy goes as f² and is
        share-invariant. The nominal tier at full share reproduces the
        untier'd charge bit-exactly."""
        f = self.tier_freq(tier)
        return (self.power_active - self.power_idle) \
            / self.max_concurrency * (f * f * f) * lane_share * t_inf


@dataclasses.dataclass
class ServerState:
    """Mutable per-simulation server bookkeeping."""

    spec: ServerSpec
    busy_until: float = 0.0
    uplink_free_at: float = 0.0
    queued: int = 0
    # accounting
    e_infer: float = 0.0
    e_tx: float = 0.0
    e_idle: float = 0.0
    busy_time: float = 0.0
    tx_busy_time: float = 0.0
    tokens_out: int = 0
    served: int = 0

    def reset(self) -> None:
        self.busy_until = 0.0
        self.uplink_free_at = 0.0
        self.queued = 0
        self.e_infer = self.e_tx = self.e_idle = 0.0
        self.busy_time = self.tx_busy_time = 0.0
        self.tokens_out = 0
        self.served = 0

    def finalize_idle(self, horizon: float) -> None:
        # standby power is a constant baseline over the whole run; dynamic
        # (inference) power is accounted separately in e_infer
        self.e_idle = horizon * self.spec.power_idle

    @property
    def total_energy(self) -> float:
        return self.e_infer + self.e_tx + self.e_idle
