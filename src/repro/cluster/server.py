"""Edge/cloud server model with an analytic LLM cost model.

The per-request cost model is derived from the deployed model's config
(`repro.configs`):  prefill is compute-bound (2·N_active FLOPs/token), decode
is the max of the compute and weight-streaming (memory-bandwidth) terms — the
same roofline logic used for the TPU dry-run, applied to the cluster.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs import get_config


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    name: str
    kind: str                 # "edge" | "cloud"
    arch_id: str              # deployed model
    flops: float              # sustained FLOP/s for LLM inference
    mem_bw: float             # bytes/s effective weight-streaming bandwidth
    power_active: float       # W while computing
    power_idle: float         # W on standby
    tx_power: float           # W attributable to an active transfer
    bandwidth: float          # bits/s uplink capacity
    max_concurrency: int      # batch lanes
    weight_bytes_per_param: float = 1.0   # int8 deployment
    # paged KV-cache pool: 0 blocks = KV memory not modeled (legacy
    # behavior — capacity is lanes only and preemption always re-prefills)
    kv_blocks: int = 0        # block-pool size
    kv_block_tokens: int = 16  # tokens of KV per block

    # ------------------------------------------------------------------
    def model_cfg(self):
        return get_config(self.arch_id)

    def active_params(self) -> float:
        return float(self.model_cfg().active_param_count())

    def prefill_time(self, prompt_tokens: int) -> float:
        fl = 2.0 * self.active_params() * prompt_tokens
        return fl / self.flops

    def decode_step_time(self, batch: int = 1) -> float:
        """Seconds per decode step for a batch (memory- vs compute-bound)."""
        weight_stream = (self.active_params() * self.weight_bytes_per_param
                         / self.mem_bw)
        compute = batch * 2.0 * self.active_params() / self.flops
        return max(weight_stream, compute)

    def decode_time(self, output_tokens: int, batch: int = 1) -> float:
        return output_tokens * self.decode_step_time(batch)

    def service_time(self, prompt_tokens: int, output_tokens: int,
                     batch: int = 1) -> float:
        return self.prefill_time(prompt_tokens) + self.decode_time(
            output_tokens, batch)

    def tx_time(self, payload_bytes: float, share: float = 1.0) -> float:
        """share: fraction of the uplink granted to this transfer."""
        return payload_bytes * 8.0 / (self.bandwidth * max(share, 1e-9))

    def kv_blocks_needed(self, prompt_tokens: int,
                         output_tokens: int) -> int:
        """KV blocks a request occupies end-to-end (prompt + all decoded
        tokens, allocated up front like the paged engine does)."""
        return max(1, math.ceil((prompt_tokens + output_tokens)
                                / self.kv_block_tokens))

    def infer_energy(self, t_inf: float) -> float:
        """Active-over-idle energy for `t_inf` seconds on one batch lane —
        the one formula every runtime charges inference with."""
        return (self.power_active - self.power_idle) \
            / self.max_concurrency * t_inf


@dataclasses.dataclass
class ServerState:
    """Mutable per-simulation server bookkeeping."""

    spec: ServerSpec
    busy_until: float = 0.0
    uplink_free_at: float = 0.0
    queued: int = 0
    # accounting
    e_infer: float = 0.0
    e_tx: float = 0.0
    e_idle: float = 0.0
    busy_time: float = 0.0
    tx_busy_time: float = 0.0
    tokens_out: int = 0
    served: int = 0

    def reset(self) -> None:
        self.busy_until = 0.0
        self.uplink_free_at = 0.0
        self.queued = 0
        self.e_infer = self.e_tx = self.e_idle = 0.0
        self.busy_time = self.tx_busy_time = 0.0
        self.tokens_out = 0
        self.served = 0

    def finalize_idle(self, horizon: float) -> None:
        # standby power is a constant baseline over the whole run; dynamic
        # (inference) power is accounted separately in e_infer
        self.e_idle = horizon * self.spec.power_idle

    @property
    def total_energy(self) -> float:
        return self.e_infer + self.e_tx + self.e_idle
