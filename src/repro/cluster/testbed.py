"""Testbed factories.

`paper_testbed` mirrors PerLLM §4.1: five Xeon-4214R edge servers (one of
{Yi-6B, LLaMA2-7B, LLaMA3-8B, Yi-9B} per experiment) and one A100-40GB cloud
server running LLaMA2-33B; 100 Mbps edge / 300 Mbps cloud links.

`tpu_testbed` is the TPU-native adaptation (DESIGN.md §3): the cloud is a
v5e pod slice whose throughput constants come from this repo's own dry-run
roofline (197 TF/s bf16 and 819 GB/s HBM per chip).
"""
from __future__ import annotations

from typing import List, Tuple

from repro.cluster.server import DVFS_TIERS, ServerSpec

# Sustained-rate calibration (DESIGN.md §3): public spec sheets derated to
# realistic LLM-serving efficiency.
XEON_4214R_FLOPS = 3.0e12       # AVX-512 VNNI int8 effective
XEON_MEM_BW = 80e9              # 6-ch DDR4-2933 @ ~57% efficiency
A100_FLOPS = 150e12             # bf16 sustained (of 312 peak)
A100_MEM_BW = 1.45e12           # of 1.55 TB/s
V5E_FLOPS = 0.55 * 197e12      # bf16 sustained per chip
V5E_MEM_BW = 0.75 * 819e9

MBPS = 1e6  # bits/s


def paper_testbed(edge_arch: str = "llama2-7b", n_edge: int = 5,
                  cloud_arch: str = "llama2-33b", kv_blocks: int = 0,
                  cloud_kv_blocks: int = -1,
                  kv_block_tokens: int = 16,
                  freq_tiers: Tuple[float, ...] = (1.0,),
                  ) -> List[ServerSpec]:
    """`kv_blocks > 0` models each edge's paged KV-cache pool (and the
    cloud's, default 4× the edge pool), making KV memory a schedulable
    resource; the default 0 keeps the legacy lanes-only capacity model.
    `kv_block_tokens` defaults to the `ServerSpec`/`ServingEngine` block
    granularity — keep them equal, C5 slack mixes units otherwise.
    `freq_tiers` is every server's DVFS table (e.g. the stock
    `repro.cluster.server.DVFS_TIERS` ladder); the single-nominal default
    keeps the testbed bit-exact with the pre-allocation cost model."""
    if cloud_kv_blocks < 0:
        cloud_kv_blocks = 4 * kv_blocks
    edges = [
        ServerSpec(
            name=f"edge{i}", kind="edge", arch_id=edge_arch,
            flops=XEON_4214R_FLOPS, mem_bw=XEON_MEM_BW,
            power_active=130.0, power_idle=55.0, tx_power=15.0,
            bandwidth=100 * MBPS, max_concurrency=8,
            weight_bytes_per_param=1.0,     # int8 edge deployment
            kv_blocks=kv_blocks, kv_block_tokens=kv_block_tokens,
            freq_tiers=freq_tiers)
        for i in range(n_edge)
    ]
    cloud = ServerSpec(
        name="cloud", kind="cloud", arch_id=cloud_arch,
        flops=A100_FLOPS, mem_bw=A100_MEM_BW,
        power_active=520.0, power_idle=120.0, tx_power=30.0,
        bandwidth=300 * MBPS, max_concurrency=16,
        weight_bytes_per_param=2.0,         # bf16 cloud deployment
        kv_blocks=cloud_kv_blocks, kv_block_tokens=kv_block_tokens,
        freq_tiers=freq_tiers)
    return edges + [cloud]


def tpu_testbed(edge_arch: str = "gemma-2b", n_edge: int = 5,
                cloud_arch: str = "gemma3-27b",
                cloud_chips: int = 4,
                freq_tiers: Tuple[float, ...] = (1.0,)) -> List[ServerSpec]:
    edges = [
        ServerSpec(
            name=f"edge{i}", kind="edge", arch_id=edge_arch,
            flops=XEON_4214R_FLOPS, mem_bw=XEON_MEM_BW,
            power_active=130.0, power_idle=55.0, tx_power=15.0,
            bandwidth=100 * MBPS, max_concurrency=2,
            weight_bytes_per_param=1.0, freq_tiers=freq_tiers)
        for i in range(n_edge)
    ]
    cloud = ServerSpec(
        name="tpu-cloud", kind="cloud", arch_id=cloud_arch,
        flops=cloud_chips * V5E_FLOPS, mem_bw=cloud_chips * V5E_MEM_BW,
        power_active=cloud_chips * 220.0 + 150.0,
        power_idle=cloud_chips * 60.0 + 80.0, tx_power=30.0,
        bandwidth=300 * MBPS, max_concurrency=8 * cloud_chips,
        weight_bytes_per_param=2.0, freq_tiers=freq_tiers)
    return edges + [cloud]


__all__ = ["DVFS_TIERS", "paper_testbed", "tpu_testbed"]
