from repro.cluster.network import (
    BandwidthModel, Link, LinkStateMixin, LinkTopology, make_topology,
)
from repro.cluster.server import ServerSpec, ServerState
from repro.cluster.simulator import (
    ClusterView, Outcome, SchedulerBase, SimResult, Simulator, SlotView,
)
from repro.cluster.testbed import paper_testbed, tpu_testbed
from repro.cluster.workload import (
    N_CLASSES, ServiceRequest, classify, generate_workload,
)

__all__ = [
    "BandwidthModel", "ClusterView", "Link", "LinkStateMixin",
    "LinkTopology", "N_CLASSES", "Outcome", "SchedulerBase", "ServerSpec",
    "ServerState", "ServiceRequest", "SimResult", "Simulator", "SlotView",
    "classify", "generate_workload", "make_topology", "paper_testbed",
    "tpu_testbed",
]
