from repro.cluster.network import (
    BandwidthModel, Link, LinkStateMixin, LinkTopology, make_topology,
)
from repro.cluster.server import DVFS_TIERS, ServerSpec, ServerState
from repro.cluster.simulator import ClusterView, Outcome, SimResult, Simulator
from repro.cluster.testbed import paper_testbed, tpu_testbed
from repro.cluster.workload import (
    N_CLASSES, ServiceRequest, classify, generate_workload,
)

__all__ = [
    "BandwidthModel", "ClusterView", "DVFS_TIERS", "Link", "LinkStateMixin",
    "LinkTopology", "N_CLASSES", "Outcome", "ServerSpec",
    "ServerState", "ServiceRequest", "SimResult", "Simulator",
    "classify", "generate_workload", "make_topology", "paper_testbed",
    "tpu_testbed",
]
