from repro.cluster.network import BandwidthModel
from repro.cluster.server import ServerSpec, ServerState
from repro.cluster.simulator import (
    ClusterView, Outcome, SchedulerBase, SimResult, Simulator, SlotView,
)
from repro.cluster.testbed import paper_testbed, tpu_testbed
from repro.cluster.workload import (
    N_CLASSES, ServiceRequest, classify, generate_workload,
)

__all__ = [
    "BandwidthModel", "ClusterView", "N_CLASSES", "Outcome", "SchedulerBase",
    "ServerSpec", "ServerState", "ServiceRequest", "SimResult", "Simulator",
    "SlotView", "classify", "generate_workload", "paper_testbed",
    "tpu_testbed",
]
