"""Reference event-driven runtime — the retained slow path.

This is the pre-vectorization `_EventSimRuntime`, kept verbatim as the
semantic oracle for the array-backed fast core in
`repro.cluster.simulator`. `Simulator(core="reference")` runs it; the
property tests in `tests/test_scale_equivalence.py` pin the fast core
result-identical (SimResult counters and per-outcome times) to this
implementation on randomized workloads.

Nothing here is optimized on purpose: every view is materialized eagerly
from scratch and every event is a dataclass through the generic
`Runtime.handle` path, which is exactly what makes it a trustworthy
reference.
"""
from __future__ import annotations

import math
from typing import Dict, List

import numpy as np

from repro.cluster.simulator import (
    Outcome, _Booking, _PrefixEntry, _SimRuntimeBase,
)
from repro.cluster.workload import ServiceRequest
from repro.core.api import ClusterView, Decision, RunningTask
from repro.obs.trace import KIND_MIGRATE, KIND_PREEMPT
from repro.core.runtime import (
    Arrival, BandwidthChange, InferDone, KvMigrate, Preempt, Reject, TxDone,
)


class _ReferenceEventRuntime(_SimRuntimeBase):
    """Pure event-driven semantics.

    Every arrival observes a fresh view of the cluster at its actual
    timestamp; physics are resolved at dispatch (links and lane booked
    immediately, so later arrivals see the consumed capacity) while the
    timeline unfolds as TxDone → InferStart → InferDone events, with energy
    accounting and policy feedback at the times things actually happen.
    Bookings stay in `_inflight` until completion, which is what gives
    views their `running` tasks and `Preempt` a victim ledger to roll back.
    """

    def __init__(self, sim: "Simulator", policy, trace=None) -> None:
        super().__init__(sim, policy, trace=trace)
        self._link_factors: Dict[str, float] = \
            {n: 1.0 for n in self.topo.links}
        self._inflight: Dict[int, _Booking] = {}
        # paged-KV ledger: blocks in use per server, plus the FIFO of
        # routed requests waiting for their server's pool to free up
        self._kv_modeled = any(s.kv_blocks > 0 for s in self.specs)
        self.kv_used = [0] * len(self.specs)
        self.kv_wait: List[List[tuple]] = [[] for _ in self.specs]
        # single-use tokens: preemptor sid -> server whose drop_kv
        # preemption it issued; grants first claim on the freed blocks
        self._kv_express: Dict[int, int] = {}
        # shared-prefix ledger: per-server {prefix_id: _PrefixEntry} of
        # resident system-prompt pages, which dispatched request pins
        # which entry (sid -> (server, prefix_id)), and per-sid prefill
        # tokens the pending dispatch skips (consumed by `dispatch`)
        self._prefix: List[Dict[int, _PrefixEntry]] = \
            [{} for _ in self.specs]
        self._prefix_pin: Dict[int, tuple] = {}
        self._prefix_saved: Dict[int, int] = {}
        if any(link.fluctuating for link in self.topo.links.values()):
            self._resample_factors(0.0)

    # ---------------- bandwidth as an event stream -----------------------
    def _resample_factors(self, t: float) -> None:
        k = int(round(t / self.sim.bw_interval))
        self._link_factors = self.topo.factors(k)
        self.loop.push(BandwidthChange(t + self.sim.bw_interval,
                                       resample=True))

    def on_bandwidth_change(self, ev: BandwidthChange) -> None:
        super().on_bandwidth_change(ev)
        if ev.resample:
            self._resample_factors(ev.time)

    def _factor(self, j: int) -> float:
        return self.server_factor(j, self._link_factors)

    def on_reject(self, ev: Reject) -> None:
        """A previously preempted request shed on requeue must not leak
        the pages preserved for its resume."""
        req = ev.request
        if req.kv_server >= 0 and req.kv_blocks > 0:
            blocks, j = req.kv_blocks, req.kv_server
            req.kv_server, req.kv_blocks = -1, 0
            self._prefix_unpin(req, ev.time)
            self._kv_free(j, blocks, ev.time)
        super().on_reject(ev)

    # ---------------- the Runtime contract -------------------------------
    def slot_index(self, t: float) -> int:
        return int(t / self.sim.bw_interval)

    def build_view(self, t: float) -> ClusterView:
        n = len(self.specs)
        running: List[List[RunningTask]] = [[] for _ in range(n)]
        for sid, b in self._inflight.items():
            running[b.j].append(RunningTask(
                sid=sid, server=b.j, class_id=b.request.class_id,
                deadline_at=b.request.arrival + b.request.deadline,
                begin=b.begin, finish_est=b.finish,
                tier=b.alloc.freq_tier))
        tier_kwargs = {}
        if any(s.n_tiers > 1 for s in self.specs):
            # per-server tier state: committed in-flight lane-seconds per
            # DVFS tier (the within-batch commits stack on via the view's
            # own `commit`)
            tier_load = [[0.0] * s.n_tiers for s in self.specs]
            for b in self._inflight.values():
                k = b.alloc.freq_tier
                if k < 0:
                    k = self.specs[b.j].nominal_tier
                tier_load[b.j][k] += max(b.finish - max(b.begin, t), 0.0)
            tier_kwargs = dict(tier_load=tier_load)
        kv_kwargs = {}
        if self._kv_modeled:
            # idle prefix entries are reclaimable page cache, so the view
            # reports them as free (mirroring PagedKVCache.free_blocks);
            # resident *ready* prefixes are surfaced so policies can rank
            # servers by expected prefix hit
            idle = [sum(e.blocks for e in self._prefix[j].values()
                        if e.refs <= 0) for j in range(n)]
            kv_kwargs = dict(
                kv_free_blocks=[self.specs[j].kv_blocks - self.kv_used[j]
                                + idle[j] for j in range(n)],
                kv_total_blocks=[self.specs[j].kv_blocks
                                 for j in range(n)],
                kv_prefix_tokens=[
                    {pid: e.tokens for pid, e in self._prefix[j].items()
                     if e.ready <= t} for j in range(n)])
        return ClusterView(
            t=t, specs=self.specs,
            bw_factor=[self._factor(j) for j in range(n)],
            uplink_free_at=[self.topo.path_free_at(j, self.link_free)
                            for j in range(n)],
            lane_free=[list(lf) for lf in self.lane_free],
            running=running,
            **tier_kwargs,
            **kv_kwargs,
            **self.link_view_kwargs(t, self._link_factors),
        )

    # ---------------- shared-prefix ledger -------------------------------
    def _prefix_blocks(self, req: ServiceRequest, j: int) -> int:
        """Full KV blocks of `req`'s shared prefix on server j's block
        geometry (capped so at least one suffix token always remains —
        the same cap `PagedKVCache.match_prefix` applies)."""
        if req.prefix_id < 0 or req.prefix_tokens <= 0:
            return 0
        span = min(req.prefix_tokens, req.prompt_tokens - 1)
        return max(span, 0) // self.specs[j].kv_block_tokens

    def _kv_need(self, req: ServiceRequest, j: int, t: float) -> int:
        """Blocks `req` would claim on j right now: full need minus any
        *ready* resident prefix blocks it can share. Pure — admission and
        the kv-wait drain peek both call it at the same instant, so they
        always agree on whether a dispatch is a prefix hit."""
        need = self.specs[j].kv_blocks_needed(req.prompt_tokens,
                                              req.output_tokens)
        entry = self._prefix[j].get(req.prefix_id) \
            if req.prefix_id >= 0 else None
        if entry is not None and entry.ready <= t:
            need -= min(entry.blocks, self._prefix_blocks(req, j))
        return need

    def _prefix_attach(self, t: float, req: ServiceRequest, j: int) -> int:
        """Pin (or create) the prefix entry `req` uses on j; returns the
        prefill tokens this dispatch skips.

        First of its pool: the request becomes the entry's *creator* — the
        entry takes ownership of the prefix blocks out of the creator's
        just-claimed full allocation (`kv_used` already covers them) and
        `dispatch` stamps `ready` once the creator's prefill window is
        known. Later dispatches pin the entry and, when it is ready, skip
        `entry.tokens` of prefill while charging only their suffix."""
        p_blocks = self._prefix_blocks(req, j)
        if p_blocks <= 0:
            return 0
        bt = self.specs[j].kv_block_tokens
        entry = self._prefix[j].get(req.prefix_id)
        if entry is None:
            self._prefix[j][req.prefix_id] = _PrefixEntry(
                blocks=p_blocks, tokens=p_blocks * bt, refs=1,
                ready=float("inf"), stamp=t)
            req.kv_blocks -= p_blocks
            self._prefix_pin[req.sid] = (j, req.prefix_id)
            return 0
        if entry.ready > t:
            return 0         # still prefilling: this dispatch pays in full
        entry.refs += 1
        entry.stamp = t
        self._prefix_pin[req.sid] = (j, req.prefix_id)
        return min(entry.blocks, p_blocks) * bt

    def _prefix_unpin(self, req: ServiceRequest, t: float) -> None:
        """Drop `req`'s pin on its prefix entry. An entry whose prefill
        never completed (creator evicted mid-prefill) is removed outright
        — its pages hold garbage; ready entries linger unpinned as
        reclaimable page cache."""
        pin = self._prefix_pin.pop(req.sid, None)
        if pin is None:
            return
        j, pid = pin
        entry = self._prefix[j].get(pid)
        if entry is None:
            return
        entry.refs -= 1
        entry.stamp = t
        if entry.refs <= 0 and entry.ready > t:
            self.kv_used[j] -= entry.blocks
            del self._prefix[j][pid]

    def _prefix_reclaim(self, j: int, need: int, keep: int = -1) -> None:
        """LRU-evict idle (unpinned) prefix entries on j until `need`
        blocks fit — never the entry `keep`, which the requester is about
        to share."""
        table = self._prefix[j]
        cap = self.specs[j].kv_blocks
        while self.kv_used[j] + need > cap:
            idle = [(e.stamp, pid) for pid, e in table.items()
                    if e.refs <= 0 and pid != keep]
            if not idle:
                return
            _, pid = min(idle)
            self.kv_used[j] -= table.pop(pid).blocks

    # ---------------- paged-KV ledger ------------------------------------
    def _kv_admit(self, t: float, req: ServiceRequest,
                  decision: Decision, from_wait: bool = False) -> bool:
        """Claim KV blocks for `req` on its target server.

        True = blocks held (dispatch may proceed); False = the request
        joined the server's KV-wait queue (re-dispatched by `_kv_free`
        when blocks return). The queue is strictly FIFO with head-of-line
        blocking — a newcomer enqueues behind existing waiters even when
        its own allocation would fit, matching the paged
        `ServingEngine._admit` semantics (`from_wait` marks the drain
        path's own re-dispatches, which must not re-enqueue behind the
        waiters they precede). A requeued request whose preserved pages
        live on the *target* server resumes on its existing blocks; pages
        preserved on any *other* server migrate or are abandoned in
        `dispatch`, before admission runs. A request whose pool already
        holds its shared prefix (ready `_PrefixEntry`) claims only its
        unique suffix blocks and skips that much prefill."""
        j = decision.server
        spec = self.specs[j]
        if req.kv_server == j and req.kv_blocks > 0:
            return True                      # resume on the held pages
        full = spec.kv_blocks_needed(req.prompt_tokens, req.output_tokens)
        if full > spec.kv_blocks:
            # physically unfittable on this server (even an empty pool is
            # too small): a KV-blind policy routed it here, so the runtime
            # sheds it — crashing the run or queueing forever would lose
            # the request silently
            self.handle(Reject(t, request=req, decision=decision))
            return False
        need = self._kv_need(req, j, t)
        express = self._kv_express.pop(req.sid, -1) == j
        if self.kv_used[j] + need > spec.kv_blocks:
            # idle resident prefixes are just page cache — evict LRU ones
            # before making the request wait
            self._prefix_reclaim(j, need, keep=req.prefix_id)
        if self.kv_used[j] + need > spec.kv_blocks \
                or (self.kv_wait[j] and not (from_wait or express)):
            self.kv_wait[j].append((req, decision))
            if self.trace is not None:
                self._kv_wait_since.setdefault(req.sid, t)
            return False
        self.kv_used[j] += need
        req.kv_server, req.kv_blocks = j, need
        saved = self._prefix_attach(t, req, j)
        if saved:
            self._prefix_saved[req.sid] = saved
        return True

    def _kv_free(self, j: int, n_blocks: int, t: float) -> None:
        """Return blocks to server j's pool and re-dispatch every KV-wait
        request that now fits (FIFO, head-of-line blocking)."""
        self.kv_used[j] -= n_blocks
        assert self.kv_used[j] >= 0, (j, self.kv_used[j])
        while self.kv_wait[j]:
            req, decision = self.kv_wait[j][0]
            need = self._kv_need(req, j, t)
            if self.kv_used[j] + need > self.specs[j].kv_blocks:
                self._prefix_reclaim(j, need, keep=req.prefix_id)
                if self.kv_used[j] + need > self.specs[j].kv_blocks:
                    break
            self.kv_wait[j].pop(0)
            self.dispatch(t, req, decision, _from_kv_wait=True)

    def dispatch(self, t: float, req: ServiceRequest,
                 decision: Decision, _from_kv_wait: bool = False) -> None:
        j = decision.server
        spec = self.specs[j]
        st = self.states[j]
        if req.kv_server >= 0 and req.kv_server != j:
            if self._kv_migrate(t, req, decision):
                return       # pages in flight: KvMigrate re-dispatches
            # pages preserved on another server that can't (or weren't
            # asked to) migrate are abandoned: freed on their home server
            # — even when the *target* doesn't model KV, or the old pool
            # leaks those blocks forever — counted, and the request pays
            # full re-prefill wherever it lands
            self.n_kv_orphaned += 1
            self._prefix_unpin(req, t)
            self._kv_free(req.kv_server, req.kv_blocks, t)
            req.kv_server, req.kv_blocks = -1, 0
        kv_resumed = False
        prefix_saved = 0
        if spec.kv_blocks > 0:
            kv_resumed = req.kv_server == j and req.kv_blocks > 0
            if not self._kv_admit(t, req, decision,
                                  from_wait=_from_kv_wait):
                return                       # waiting on KV blocks
            prefix_saved = self._prefix_saved.pop(req.sid, 0)
        if self.trace is not None and (kv_resumed or self._kv_wait_since):
            self._trace_dispatch_kv(t, req, j, kv_resumed)
        alloc = decision.alloc
        tx_start = max(t, self.topo.path_free_at(j, self.link_free))
        # a sub-unit bandwidth share stretches the transfer by 1/share and
        # occupies the path for the whole stretched window (exclusive-
        # window semantics: shares can never oversubscribe a link)
        tx_dur = spec.tx_time(req.payload_bytes,
                              self._factor(j) * alloc.bw_share)
        end = tx_start + tx_dur
        # a transfer occupies its whole path
        for name in self.topo.paths[j]:
            self.link_free[name] = end
        st.uplink_free_at = end
        ready = end
        # the lane is booked at dispatch — the routed request is committed
        # capacity, visible to every later arrival's fresh view — while the
        # events below mark when its phases actually happen
        lanes = self.lane_free[j]
        li = int(np.argmin(lanes))
        lane_prev = lanes[li]
        begin = max(ready, lane_prev)
        t_inf = self.sim._draw_infer(req, j, resume=kv_resumed, alloc=alloc,
                                     prefix_tokens=prefix_saved)
        finish = begin + t_inf
        lanes[li] = finish
        pin = self._prefix_pin.get(req.sid)
        if pin is not None:
            # first dispatch of this pool's creator: the shared pages
            # materialize once its own prefill window has run
            entry = self._prefix[pin[0]].get(pin[1])
            if entry is not None and entry.ready == float("inf"):
                entry.ready = begin + spec.prefill_time(entry.tokens)
        ctx = _Booking(request=req, j=j, li=li, lane_prev=lane_prev,
                       tx_dur=tx_dur,
                       charge_from=t if req.preemptions else req.arrival,
                       ready=ready, begin=begin, t_inf=t_inf, finish=finish,
                       kv_resumed=kv_resumed, prefix_saved=prefix_saved,
                       alloc=alloc)
        self._inflight[req.sid] = ctx
        self.loop.push(TxDone(ready, request=req, decision=decision,
                              context=ctx))
        self.loop.push(InferDone(finish, request=req, context=ctx))

    def _kv_migrate(self, t: float, req: ServiceRequest,
                    decision: Decision) -> bool:
        """Ship `req`'s preserved pages from their home server to
        `decision.server` over the link topology, if asked and affordable.

        The transfer occupies every link on the union of both servers'
        paths (pages travel down one side of the tree and up the other)
        at the path's bottleneck bandwidth, charged against the same
        per-link ledgers payload transfers use — migration and uplink
        traffic genuinely contend. The destination's blocks are claimed
        up front so its pool can't oversubscribe while the pages are in
        flight; when they land (`KvMigrate`) the source frees and the
        request re-dispatches as a zero-re-prefill resume. False = the
        caller falls back to abandoning the pages (full re-prefill)."""
        j = decision.server
        src = req.kv_server
        spec = self.specs[j]
        if not decision.migrate_kv or spec.kv_blocks <= 0:
            return False
        need = spec.kv_blocks_needed(req.prompt_tokens, req.output_tokens)
        if need > spec.kv_blocks or self.kv_wait[j]:
            return False     # destination can't host the pages right now
        if self.kv_used[j] + need > spec.kv_blocks:
            self._prefix_reclaim(j, need, keep=req.prefix_id)
            if self.kv_used[j] + need > spec.kv_blocks:
                return False
        src_spec = self.specs[src]
        n_bytes = req.kv_blocks * src_spec.kv_block_tokens \
            * src_spec.kv_bytes_per_token()
        if n_bytes <= 0.0:
            return False     # nothing to ship (e.g. attention-free arch)
        path = self.topo.migration_path(src, j)
        bw = self.topo.migration_bandwidth(src, j, self._link_factors,
                                           self.link_scale)
        if not path or bw <= 0.0:
            return False
        self.kv_used[j] += need
        start = max(t, max(self.link_free[name] for name in path))
        end = start + n_bytes * 8.0 / bw
        for name in path:
            self.link_free[name] = end
        st = self.states[src]
        # the source's radio pushes the pages; like payload transfers,
        # energy accrues over the whole window including the queue wait
        st.e_tx += (end - t) * src_spec.tx_power
        st.tx_busy_time += end - start
        self.n_kv_migrations += 1
        self.kv_migrated_bytes += n_bytes
        if self.trace is not None:
            self.trace.append(KIND_MIGRATE, req.sid, t, end, j,
                              req.class_id, 0,
                              (end - t) * src_spec.tx_power, n_bytes,
                              self.trace.intern(f"{src}->{j}"))
        self.loop.push(KvMigrate(end, request=req, decision=decision,
                                 context=(src, req.kv_blocks, j, need)))
        return True

    def on_kv_migrate(self, ev: KvMigrate) -> None:
        """Migrated pages landed: free them at the source, hand them to
        the request on the destination, and re-dispatch — the dispatch
        sees `kv_server == server`, so it books a decode-only resume with
        zero re-prefill (the destination's blocks were already claimed
        when the transfer started)."""
        req = ev.request
        src, src_blocks, j, need = ev.context
        self._prefix_unpin(req, ev.time)
        self._kv_free(src, src_blocks, ev.time)
        req.kv_server, req.kv_blocks = j, need
        self.dispatch(ev.time, req, ev.decision)

    def on_tx_done(self, ev: TxDone) -> None:
        b: _Booking = ev.context
        st = self.states[b.j]
        # transmission energy accrues over the whole transfer window,
        # including the congestion queue (paper §2.3); for a preempted
        # continuation the window starts at the requeue instant — the
        # pre-preemption window was billed by the first TxDone. During the
        # transfer itself the radio draws tx_power × bw_share (a granted
        # slice lights up a slice of the link), so a sub-unit share's
        # *transfer* energy is share-invariant and only its queue window
        # still charges full power.
        st.e_tx += (b.ready - b.charge_from) * self.specs[b.j].tx_power \
            - (1.0 - b.alloc.bw_share) * b.tx_dur * self.specs[b.j].tx_power
        st.tx_busy_time += b.tx_dur

    def on_preempt(self, ev: Preempt) -> None:
        """Return the victim's lane and requeue its remaining work.

        Runs synchronously inside the preemptor's `place`, so the freed
        lane is visible before the preemptor's dispatch books it. The
        victim's booking rolls back only if it is still the last booking
        on its lane; partial decode already burned is charged as wasted
        inference energy, and the victim re-enters as a fresh Arrival
        carrying its remaining decode tokens.

        On a KV-modeled server the victim's pages survive the eviction by
        default (`ev.drop_kv` False): they stay allocated, and if the
        requeue lands back on this server the continuation skips prefill
        entirely. `drop_kv` frees them on the spot instead — preemption
        as *memory* relief — at the price of a full re-prefill wherever
        the victim resumes. Servers without a block pool keep the legacy
        semantics: KV is dropped with the lane and preemption is never
        free."""
        b = self._inflight.get(ev.victim)
        if b is None:
            return       # victim already finished (or never dispatched)
        t = ev.time
        if t < b.ready:
            # victim still in transit: its payload occupies the path links
            # and its TxDone will bill the transfer — aborting here would
            # leave ghost link occupancy and double-charge tx energy, so
            # only lane-resident (transfer-complete) victims are preempted
            return
        lanes = self.lane_free[b.j]
        if lanes[b.li] != b.finish:
            # a later booking already stacked onto the victim's lane:
            # cancelling would free no capacity (the stacked booking's
            # start was computed from the victim's finish), so refuse —
            # killing the victim here would be pure wasted work
            return
        del self._inflight[ev.victim]
        b.cancelled = True
        req = b.request
        spec = self.specs[b.j]
        st = self.states[b.j]
        lanes[b.li] = b.lane_prev if t <= b.begin else t
        e_waste = 0.0
        if t > b.begin:
            # wasted partial decode: the server burned real energy on it,
            # at the victim's allocated tier/share
            done = min(t, b.finish) - b.begin
            e_waste = spec.infer_energy(done, tier=b.alloc.freq_tier,
                                        lane_share=b.alloc.lane_share)
            st.e_infer += e_waste
            st.busy_time += done / spec.max_concurrency
            frac_left = max(b.finish - t, 0.0) / b.t_inf
            remaining = max(1, int(math.ceil(req.output_tokens * frac_left)))
        else:
            remaining = req.output_tokens
        if spec.kv_blocks > 0 and req.kv_blocks > 0:
            started = t > b.begin
            # a booking that never began holds prefilled pages only if it
            # was itself a resume (its KV survives from the earlier run)
            prefilled = started or b.kv_resumed
            if ev.drop_kv and ev.request is not None:
                # memory-pressure eviction: the blocks return *undrained*
                # and the preemptor (dispatched synchronously next, inside
                # the same `place`) gets first claim on them — that is the
                # whole point of the drop. Leftovers reach the kv_wait
                # FIFO at the next free event on this server.
                self.kv_used[b.j] -= req.kv_blocks
                req.kv_server, req.kv_blocks = -1, 0
                self._prefix_unpin(req, t)
                self._kv_express[ev.request.sid] = b.j
            elif ev.drop_kv or not prefilled:
                self._prefix_unpin(req, t)
                self._kv_free(b.j, req.kv_blocks, t)
                req.kv_server, req.kv_blocks = -1, 0
            if started:
                self.n_kv_evictions += 1
        req.output_tokens = remaining
        req.preemptions += 1
        self.n_preempted += 1
        if self.trace is not None:
            # span covers the wasted decode window (a point at t when the
            # victim had not yet begun); value = tokens left to requeue
            self.trace.append(KIND_PREEMPT, req.sid,
                              b.begin if t > b.begin else t, t, b.j,
                              req.class_id, b.alloc.freq_tier, e_waste,
                              float(remaining), b.li)
        self.loop.push(Arrival(t, requests=(req,)))

    def on_infer_done(self, ev: InferDone) -> None:
        b: _Booking = ev.context
        if b.cancelled:
            return                       # preempted: the requeue completes
        req = ev.request
        self._inflight.pop(req.sid, None)
        spec = self.specs[b.j]
        st = self.states[b.j]
        finish = ev.time
        st.busy_time += b.t_inf / spec.max_concurrency
        e_inf = spec.infer_energy(b.t_inf, tier=b.alloc.freq_tier,
                                  lane_share=b.alloc.lane_share)
        st.e_infer += e_inf
        st.tokens_out += req.output_tokens
        st.served += 1
        if spec.kv_blocks > 0 and req.kv_blocks > 0:
            blocks, req.kv_server, req.kv_blocks = req.kv_blocks, -1, 0
            self._prefix_unpin(req, finish)
            self._kv_free(b.j, blocks, finish)
        if b.kv_resumed:
            # credited at completion, not dispatch: a resume preempted
            # again before it ran must not bank phantom savings
            self.kv_prefill_tokens_saved += req.prompt_tokens
        elif b.prefix_saved:
            # same late-credit rule for shared-prefix hits
            self.kv_prefill_tokens_saved += b.prefix_saved
            self.n_prefix_hits += 1
        req.finish = finish
        req.server = b.j
        proc = finish - req.arrival
        out = Outcome(
            server=b.j, tx_time=(b.ready - req.arrival),
            queue_time=max(b.begin - b.ready, 0.0), infer_time=b.t_inf,
            finish=finish, processing_time=proc,
            success=proc <= req.deadline,
            energy=b.tx_dur * spec.tx_power * b.alloc.bw_share + e_inf)
        self.outcomes.append(out)
        if self.trace is not None:
            self._trace_complete(req, b.j, b.li, b.alloc.freq_tier,
                                 b.ready, b.begin, finish,
                                 b.tx_dur * spec.tx_power
                                 * b.alloc.bw_share, e_inf, out.success)
        self.policy.feedback(req, out)
