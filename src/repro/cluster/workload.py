"""Diverse LLM service workload generator (paper §4.2).

10,000 services, deadlines ~ U[2s, 6s], heterogeneous prompt/output lengths
and payload sizes (services carry context documents; the payload term is what
creates cloud uplink congestion, the paper's Fig. 2 observation).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass(slots=True)
class ServiceRequest:
    sid: int
    arrival: float           # s
    prompt_tokens: int
    output_tokens: int
    deadline: float          # max acceptable processing time D^Δ (s)
    payload_bytes: float     # uplink payload (prompt + context attachments)
    class_id: int = -1

    # filled by the simulator
    finish: float = -1.0
    server: int = -1
    preemptions: int = 0     # times this request's lane was reclaimed
    # paged-KV bookkeeping: which server currently holds this request's
    # KV pages (running, or preserved across a preemption) and how many —
    # a requeue back to `kv_server` resumes decode with zero re-prefill
    kv_server: int = -1
    kv_blocks: int = 0
    # shared-prefix identity: requests from the same system-prompt pool
    # carry the same `prefix_id` and share their first `prefix_tokens`
    # prompt tokens — a KV-modeled server that already holds that prefix
    # serves them without re-prefilling it (-1/0: no shared prefix)
    prefix_id: int = -1
    prefix_tokens: int = 0

    @property
    def processing_time(self) -> float:
        return self.finish - self.arrival if self.finish >= 0 else float("inf")

    @property
    def success(self) -> bool:
        return self.finish >= 0 and self.processing_time <= self.deadline


def generate_workload(n_services: int = 10_000, rate: float = 10.0,
                      seed: int = 0, scenario=None) -> List[ServiceRequest]:
    """Arrivals at `rate` req/s with diverse requirements.

    `scenario` (a `repro.core.runtime.Scenario` instance or registered
    name, e.g. ``"burst"``/``"diurnal"``/``"trace"``) shapes *when*
    services arrive; `None` keeps the paper's stationary Poisson process.
    Per-request requirements are drawn identically either way — scenarios
    that override `shape_requests` (e.g. ``"kv-pressure"``) then transform
    those base draws in place, from their own rng substream — so two
    scenarios at the same seed start from the same services.
    """
    rng = np.random.default_rng(seed)
    # the Poisson gaps are always drawn so the requirement draws below sit
    # at the same rng state for every scenario (same services, new timing)
    gaps = rng.exponential(1.0 / rate, size=n_services)
    if scenario is not None:
        from repro.core.runtime import Scenario, make_scenario
        if isinstance(scenario, str):
            scenario = make_scenario(scenario)
        if (type(scenario).arrival_times is Scenario.arrival_times
                and type(scenario).shape_requests
                is Scenario.shape_requests):
            # stationary Poisson with unshaped requests (incl. scenarios
            # that only inject bandwidth events, e.g. bwdrop): keep the
            # baseline arrivals so the scenario's effect can be isolated
            # arrival-for-arrival
            scenario = None
    arrivals = (
        np.cumsum(gaps)
        if scenario is None
        or type(scenario).arrival_times is Scenario.arrival_times
        else scenario.arrival_times(
            n_services, rate, np.random.default_rng([seed, 0x5CEA])))
    prompt = np.clip(rng.lognormal(5.0, 0.8, n_services), 32, 2048).astype(int)
    out = np.clip(rng.lognormal(2.8, 0.6, n_services), 4, 96).astype(int)
    deadline = rng.uniform(2.0, 6.0, n_services)
    payload = rng.uniform(0.7e6, 6.7e6, n_services)  # 0.7–6.7 MB context docs
    # bulk-convert once (C loop) instead of one numpy-scalar unboxing per
    # field per request — at 10^6 services the construction loop below is
    # the whole cost of workload generation
    services = [
        ServiceRequest(sid=i, arrival=a, prompt_tokens=p,
                       output_tokens=o, deadline=d, payload_bytes=b)
        for i, (a, p, o, d, b) in enumerate(zip(
            arrivals.tolist(), prompt.tolist(), out.tolist(),
            deadline.tolist(), payload.tolist()))
    ]
    if scenario is not None:
        scenario.shape_requests(services,
                                np.random.default_rng([seed, 0x5D01]))
    return services


# --------------------------------------------------------------------------
# Service classes — PerLLM is *personalized*: the bandit learns per class.
# --------------------------------------------------------------------------

_PROMPT_EDGES = (128, 512)
_DEADLINE_EDGES = (3.0, 4.5)


_P_LO, _P_HI = _PROMPT_EDGES
_D_LO, _D_HI = _DEADLINE_EDGES
_D_BINS = len(_DEADLINE_EDGES) + 1


def classify(req: ServiceRequest) -> int:
    # unrolled histogram binning over the two edge tuples (this runs once
    # per request per simulation, so no generator/sum machinery)
    p = (req.prompt_tokens > _P_LO) + (req.prompt_tokens > _P_HI)
    d = (req.deadline > _D_LO) + (req.deadline > _D_HI)
    return p * _D_BINS + d


N_CLASSES = (len(_PROMPT_EDGES) + 1) * (len(_DEADLINE_EDGES) + 1)
