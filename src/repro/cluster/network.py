"""Network models: per-link bandwidth topology and the legacy per-server
bandwidth model (paper §4.1).

`LinkTopology` is the runtime's network: named directed links (user→edge,
user→cloud, edge→cloud backhaul, ...), each with a capacity, an
*independent* fluctuation substream, and a scenario scale overlay; every
server is reached over a serial path of links. A transfer occupies all
links on its path, serialized per link, and its rate is the path's
bottleneck — so a congested shared uplink slows every server behind it,
which is what lets policies route around a slow *link* rather than a
"slow server".

Fluctuation streams are drawn per (link, sample index) from a dedicated
seed sequence, so a link's factor trace is invariant to how many other
links exist and to how often the others are sampled. (The legacy
`BandwidthModel` draws its uniform noise from one shared RNG, coupling
every link's trace to the cluster size and sampling order; it survives
unchanged as the bit-exact shim behind `LinkTopology.degenerate`, guarded
by the frozen golden tests.)

Factors are resampled on a periodic `BandwidthChange` stream (see
`repro.core.runtime`), and scenario events may overlay multiplicative
scales per server *or per named link* (congestion/outage windows) on
top.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


class BandwidthModel:
    """Per-slot multiplicative bandwidth factor for each server link.

    Legacy model: one shared RNG for every link's noise draw (`factor(t,
    j)` therefore depends on how many factors were sampled before it).
    Kept bit-exact as the degenerate topology's factor source — the frozen
    golden tests pin its stream. New topologies use `LinkTopology`'s
    per-link substreams instead.
    """

    def __init__(self, fluctuating: bool = False, amplitude: float = 0.2,
                 seed: int = 0):
        self.fluctuating = fluctuating
        self.amplitude = amplitude
        self.seed = seed
        self._rng = np.random.default_rng(seed)

    def factor(self, t_slot: int, server_idx: int) -> float:
        if not self.fluctuating:
            return 1.0
        # smooth-ish fluctuation: sinusoid + noise, clipped to ±amplitude
        base = np.sin(0.37 * t_slot + 2.1 * server_idx)
        noise = self._rng.uniform(-1.0, 1.0)
        f = 1.0 + self.amplitude * float(np.clip(0.6 * base + 0.4 * noise,
                                                 -1.0, 1.0))
        return f

    def factors(self, t_slot: int, n_servers: int) -> List[float]:
        """All links' factors for one sample instant (stable draw order:
        server 0 first — both runtimes use this so RNG streams agree)."""
        return [self.factor(t_slot, j) for j in range(n_servers)]


@dataclasses.dataclass(frozen=True)
class Link:
    """One directed network link.

    `capacity` is the link's nominal rate in bits/s; `fluctuating` links
    draw a ±`amplitude` multiplicative factor per sample instant from
    their own substream (index-keyed, so the trace is invariant to the
    rest of the topology).
    """

    name: str
    capacity: float               # bits/s
    fluctuating: bool = False
    amplitude: float = 0.2


class LinkTopology:
    """Named links + per-server serial paths, with observable state.

    The runtime owns the mutable per-link state (`free_at` backlog and
    scenario `scale` overlays); the topology owns the static structure and
    the fluctuation streams. `paths[j]` lists the link names a request
    traverses to reach server `j`; the effective bandwidth of the path is
    its bottleneck `capacity × factor × scale`.
    """

    def __init__(self, links: Sequence[Link], paths: Sequence[Sequence[str]],
                 seed: int = 0, bandwidth: Optional[BandwidthModel] = None):
        self.links: Dict[str, Link] = {lk.name: lk for lk in links}
        if len(self.links) != len(links):
            raise ValueError("duplicate link names in topology")
        self.paths: List[List[str]] = [list(p) for p in paths]
        for p in self.paths:
            for name in p:
                if name not in self.links:
                    raise KeyError(f"path references unknown link {name!r}")
            if not p:
                raise ValueError("every server needs at least one link")
        self.seed = seed
        self._index = {name: i for i, name in enumerate(self.links)}
        # the degenerate shim delegates factor sampling to the legacy
        # shared-RNG model so the frozen golden streams are untouched
        self._legacy = bandwidth

    # ---------------- structure ------------------------------------------
    @property
    def n_servers(self) -> int:
        return len(self.paths)

    @property
    def link_names(self) -> List[str]:
        return list(self.links)

    def server_link(self, j: int) -> str:
        """The server's dedicated access link (first hop of its path) —
        the target of legacy server-indexed `BandwidthChange.scale`."""
        return self.paths[j][0]

    @property
    def is_degenerate(self) -> bool:
        """One private link per server: the legacy per-server model."""
        return self._legacy is not None

    # ---------------- fluctuation ----------------------------------------
    def factor(self, name: str, k: int) -> float:
        """Link `name`'s multiplicative factor at sample instant `k`.

        Per-link substream: the draw is keyed by (seed, link index, k), so
        the trace neither depends on the cluster size nor on how many
        other factors were sampled first — the RNG-coupling fix over
        `BandwidthModel.factor`.
        """
        link = self.links[name]
        if not link.fluctuating:
            return 1.0
        idx = self._index[name]
        base = np.sin(0.37 * k + 2.1 * idx)
        noise = np.random.default_rng([self.seed, idx, k]).uniform(-1.0, 1.0)
        return 1.0 + link.amplitude * float(
            np.clip(0.6 * base + 0.4 * noise, -1.0, 1.0))

    def factors(self, k: int) -> Dict[str, float]:
        """All links' factors at sample instant `k`."""
        if self._legacy is not None:
            legacy = self._legacy.factors(k, self.n_servers)
            return {self.server_link(j): legacy[j]
                    for j in range(self.n_servers)}
        return {name: self.factor(name, k) for name in self.links}

    # ---------------- path queries (pure; state is passed in) -------------
    def path_bandwidth(self, j: int, factors: Dict[str, float],
                       scale: Dict[str, float]) -> float:
        """Bottleneck bits/s of server j's path under factors × scales."""
        return min(self.links[lk].capacity * factors.get(lk, 1.0)
                   * scale.get(lk, 1.0) for lk in self.paths[j])

    def path_free_at(self, j: int, free_at: Dict[str, float]) -> float:
        """Earliest time every link on server j's path is free."""
        return max(free_at[lk] for lk in self.paths[j])

    def migration_path(self, src: int, dst: int) -> List[str]:
        """Links a server-to-server KV migration occupies: the ordered
        deduplicated union of both servers' paths. With user-rooted paths
        this is the conservative route (src egress + dst ingress; a
        shared backhaul appears once) — a migration contends with every
        transfer to either endpoint, which is the cost policies weigh."""
        path: List[str] = []
        for name in self.paths[src] + self.paths[dst]:
            if name not in path:
                path.append(name)
        return path

    def migration_bandwidth(self, src: int, dst: int,
                            factors: Dict[str, float],
                            scale: Dict[str, float]) -> float:
        """Bottleneck bits/s of the src->dst migration path."""
        return min(self.links[lk].capacity * factors.get(lk, 1.0)
                   * scale.get(lk, 1.0)
                   for lk in self.migration_path(src, dst))

    def server_factor(self, j: int, nominal_bw: float,
                      factors: Dict[str, float],
                      scale: Dict[str, float]) -> float:
        """Effective per-server bandwidth factor: path bottleneck over the
        server's nominal uplink. The dedicated-link fast path multiplies
        factor × scale directly — the exact float ops of the legacy
        per-server model, which keeps degenerate runs bit-exact."""
        path = self.paths[j]
        if len(path) == 1 and self.links[path[0]].capacity == nominal_bw:
            name = path[0]
            return factors.get(name, 1.0) * scale.get(name, 1.0)
        return self.path_bandwidth(j, factors, scale) / nominal_bw

    def book(self, j: int, t: float, payload_bytes: float,
             factors: Dict[str, float], scale: Dict[str, float],
             free_at: Dict[str, float]) -> tuple:
        """Serialize one transfer to server j over its path.

        Returns `(tx_start, tx_dur)` and advances every path link's
        `free_at` to the transfer's end (a transfer occupies the whole
        path — the fluid bottleneck model).
        """
        tx_start = max(t, self.path_free_at(j, free_at))
        bw = self.path_bandwidth(j, factors, scale)
        tx_dur = payload_bytes * 8.0 / max(bw, 1e-9)
        end = tx_start + tx_dur
        for lk in self.paths[j]:
            free_at[lk] = end
        return tx_start, tx_dur

    # ---------------- factories ------------------------------------------
    @classmethod
    def degenerate(cls, specs: Sequence,
                   bandwidth: Optional[BandwidthModel] = None,
                   ) -> "LinkTopology":
        """One private link per server — the legacy per-server model.

        Factor sampling delegates to the wrapped `BandwidthModel` (shared
        RNG and all), so runs through the degenerate topology are
        bit-exact with the pre-topology runtime.
        """
        model = bandwidth or BandwidthModel()
        links = [Link(name=f"user-{getattr(s, 'name', f'srv{j}')}",
                      capacity=s.bandwidth, fluctuating=model.fluctuating,
                      amplitude=model.amplitude)
                 for j, s in enumerate(specs)]
        return cls(links, [[lk.name] for lk in links], seed=model.seed,
                   bandwidth=model)

    @classmethod
    def edge_cloud(cls, specs: Sequence, fluctuating: bool = False,
                   amplitude: float = 0.2, seed: int = 0,
                   backhaul_scale: float = 1.5) -> "LinkTopology":
        """The paper's deployment as an explicit link graph.

        Each edge server gets a private `user-edge{j}` access link at its
        spec bandwidth; cloud servers are reached over *two* serial hops —
        the user's `user-cloud` WAN access plus the shared `edge-cloud`
        metro/backhaul aggregation link (capacity `backhaul_scale ×` the
        summed cloud access bandwidth, so it only binds under scenario
        overlays such as a cloud-uplink outage). All links fluctuate on
        independent substreams when `fluctuating` is set.
        """
        links: List[Link] = []
        paths: List[List[str]] = []
        clouds = [j for j, s in enumerate(specs)
                  if getattr(s, "kind", "edge") == "cloud"]
        cloud_bw = sum(specs[j].bandwidth for j in clouds)
        backhaul = Link("edge-cloud", backhaul_scale * max(cloud_bw, 1.0),
                        fluctuating=fluctuating, amplitude=amplitude)
        for j, s in enumerate(specs):
            if j in clouds:
                # a single cloud keeps the canonical "user-cloud" name
                # (what scenario link_scale overlays target); multi-cloud
                # testbeds get indexed names — the shared backhaul is
                # still on every cloud path, so outages bite regardless
                name = "user-cloud" if len(clouds) == 1 \
                    else f"user-cloud{j}"
                links.append(Link(name, s.bandwidth, fluctuating=fluctuating,
                                  amplitude=amplitude))
                paths.append([name, backhaul.name])
            else:
                name = f"user-edge{j}"
                links.append(Link(name, s.bandwidth, fluctuating=fluctuating,
                                  amplitude=amplitude))
                paths.append([name])
        if clouds:
            links.append(backhaul)
        return cls(links, paths, seed=seed)


class LinkStateMixin:
    """The mutable link state a runtime owns on top of a `LinkTopology`:
    per-link serialized backlog (`link_free`) and scenario scale overlays
    (`link_scale`). Shared by the simulator runtimes and the live
    `PerLLMServer` so overlay/observability semantics cannot diverge."""

    def init_link_state(self, topology: LinkTopology) -> None:
        self.topology = topology
        self.link_free: Dict[str, float] = {n: 0.0 for n in topology.links}
        self.link_scale: Dict[str, float] = {n: 1.0 for n in topology.links}

    def apply_bandwidth_scales(self, ev) -> None:
        """Fold a `BandwidthChange`'s overlays into `link_scale` (legacy
        per-server scales land on the server's access link; named link
        scales apply where the topology knows the link)."""
        if ev.scale:
            for j, s in ev.scale.items():
                self.link_scale[self.topology.server_link(j)] = s
        if ev.link_scale:
            for name, s in ev.link_scale.items():
                if name in self.link_scale:
                    self.link_scale[name] = s

    def link_view_kwargs(self, t: float,
                         link_factors: Dict[str, float]) -> dict:
        """Per-link observability for `ClusterView`: observed bandwidth and
        serialized backlog per named link, plus each server's path."""
        topo = self.topology
        return dict(
            link_bw={n: topo.links[n].capacity * link_factors.get(n, 1.0)
                     * self.link_scale[n] for n in topo.links},
            link_queue={n: max(f - t, 0.0)
                        for n, f in self.link_free.items()},
            paths=topo.paths)


_TOPOLOGIES = {
    "degenerate": LinkTopology.degenerate,
    "edge-cloud": LinkTopology.edge_cloud,
}


def make_topology(name: str, specs: Sequence, **kwargs) -> LinkTopology:
    """Construct a named topology (`degenerate` or `edge-cloud`)."""
    key = name.lower().replace("_", "-")
    if key not in _TOPOLOGIES:
        raise KeyError(f"unknown topology {name!r}; available: "
                       + ", ".join(sorted(_TOPOLOGIES)))
    return _TOPOLOGIES[key](specs, **kwargs)
