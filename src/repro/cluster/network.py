"""Bandwidth models: stable and ±20%-fluctuating links (paper §4.1).

In the slotted simulator `factor(t_slot, j)` is sampled once per non-empty
slot; the event-driven runtimes resample on a periodic `BandwidthChange`
stream instead (see `repro.core.runtime`), and scenario events may overlay
additional multiplicative scales (congestion/outage windows) on top.
"""
from __future__ import annotations

from typing import List

import numpy as np


class BandwidthModel:
    """Per-slot multiplicative bandwidth factor for each server link."""

    def __init__(self, fluctuating: bool = False, amplitude: float = 0.2,
                 seed: int = 0):
        self.fluctuating = fluctuating
        self.amplitude = amplitude
        self._rng = np.random.default_rng(seed)

    def factor(self, t_slot: int, server_idx: int) -> float:
        if not self.fluctuating:
            return 1.0
        # smooth-ish fluctuation: sinusoid + noise, clipped to ±amplitude
        base = np.sin(0.37 * t_slot + 2.1 * server_idx)
        noise = self._rng.uniform(-1.0, 1.0)
        f = 1.0 + self.amplitude * float(np.clip(0.6 * base + 0.4 * noise,
                                                 -1.0, 1.0))
        return f

    def factors(self, t_slot: int, n_servers: int) -> List[float]:
        """All links' factors for one sample instant (stable draw order:
        server 0 first — both runtimes use this so RNG streams agree)."""
        return [self.factor(t_slot, j) for j in range(n_servers)]
