"""Bandwidth models: stable and ±20%-fluctuating links (paper §4.1)."""
from __future__ import annotations

import numpy as np


class BandwidthModel:
    """Per-slot multiplicative bandwidth factor for each server link."""

    def __init__(self, fluctuating: bool = False, amplitude: float = 0.2,
                 seed: int = 0):
        self.fluctuating = fluctuating
        self.amplitude = amplitude
        self._rng = np.random.default_rng(seed)

    def factor(self, t_slot: int, server_idx: int) -> float:
        if not self.fluctuating:
            return 1.0
        # smooth-ish fluctuation: sinusoid + noise, clipped to ±amplitude
        base = np.sin(0.37 * t_slot + 2.1 * server_idx)
        noise = self._rng.uniform(-1.0, 1.0)
        f = 1.0 + self.amplitude * float(np.clip(0.6 * base + 0.4 * noise,
                                                 -1.0, 1.0))
        return f
