"""Discrete-event simulator for edge-cloud LLM serving.

Faithful to the paper's evaluation protocol (§4): services arrive in real
time, are scheduled to a server, upload over that server's (shared, possibly
fluctuating) uplink, then occupy a batch lane for prefill+decode. Processing
time = transmission + queue + inference; energy = transmission + inference +
idle (idle accrues over the run's makespan).

The simulator is purely event-driven, on the shared `Runtime` /
`EventLoop` from `repro.core.runtime`: every service is its own `Arrival`
at its true timestamp, observed against a *fresh* view of live uplink/
lane state; transmission and completion unfold as `TxDone`/`InferDone`
events and the policy's `feedback` fires at the request's actual
completion time. Bandwidth fluctuation is a periodic `BandwidthChange`
resample stream. (The historical quantized-slot compat mode was retired
once the array-backed event core became the single measured path; a
numeric `slot=` argument now raises.)

Two interchangeable cores execute those semantics: the default array-
backed core (`core="array"`: flat typed event heap, cached bandwidth/
uplink vectors, lazily materialized views) and the straight-line
reference core (`core="reference"`), kept as the readable specification —
trajectories are bit-identical between them (see
tests/test_scale_equivalence.py).

Scenario hooks (`repro.core.runtime.Scenario`) inject extra event streams —
bursty/diurnal/trace arrivals shape the workload (see
`workload.generate_workload`), and mid-run bandwidth drops arrive as
`BandwidthChange` scale overlays (per server or per named link) honored by
both modes.

The network is a `LinkTopology` (default: the degenerate one-private-link
per server, bit-exact with the legacy per-server `BandwidthModel`):
transfers serialize on every link of the target server's path at the
path's bottleneck bandwidth. Policies may shed arrivals
(`Decision.admit=False` — a `Reject` event emits the SLO-violation
Outcome with zero server energy) and reclaim a running
victim's lane (`Decision.preempt_victim` — the victim's remaining decode
tokens requeue as a fresh Arrival). The KV ledger also models *sharing*
and *mobility*: requests carrying a `prefix_id` reuse resident shared-prefix
pages (skipping that much prefill), and a cross-server requeue with
`Decision.migrate_kv` ships its preserved pages over the link topology
(`KvMigrate`) instead of abandoning them to a full re-prefill.

Servers have *hidden* efficiency factors and per-request noise — schedulers
only observe realized outcomes, which is what makes the bandit formulation
meaningful (and is how the real testbed behaves).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.cluster.network import BandwidthModel, LinkStateMixin, LinkTopology
from repro.cluster.server import ServerSpec, ServerState
from repro.cluster.workload import ServiceRequest, classify
from repro.core.api import (
    NOMINAL, Allocation, ClusterView, Decision, RunningTask, ensure_policy,
)
from repro.core.runtime import (
    Arrival, BandwidthChange, EventLoop, InferDone, KvMigrate, Preempt,
    Reject, Runtime, Scenario, TxDone, make_scenario,
)
from repro.obs.metrics import MetricsRegistry, counter_attr, with_aliases
from repro.obs.trace import (
    KIND_ARRIVAL, KIND_DECISION, KIND_KV_WAIT, KIND_MIGRATE,
    KIND_PREEMPT, KIND_REJECT, KIND_RESUME,
)


@dataclasses.dataclass(slots=True)
class Outcome:
    server: int
    tx_time: float
    queue_time: float
    infer_time: float
    finish: float
    processing_time: float
    success: bool
    energy: float               # incremental (tx + active-infer) energy
    rejected: bool = False      # admission control shed this request


@dataclasses.dataclass
class SimResult:
    name: str
    n_services: int
    success_rate: float
    avg_processing_time: float
    p95_processing_time: float
    throughput_tokens_per_s: float
    makespan: float
    e_tx: float
    e_infer: float
    e_idle: float
    per_server_served: List[int]
    # admission control & preemption (0 when disabled — legacy behavior)
    n_rejected: int = 0
    n_preempted: int = 0
    admitted_success_rate: float = 0.0   # SLO rate among admitted requests
    # paged KV cache (0 when no ServerSpec models a block pool)
    n_kv_evictions: int = 0              # preemptions that touched KV pages
    kv_prefill_tokens_saved: int = 0     # prefill skipped via page resume
    # prefix sharing & KV migration (0 when nothing shares or moves)
    n_prefix_hits: int = 0               # dispatches that reused a resident prefix
    n_kv_orphaned: int = 0               # cross-server requeues that abandoned pages
    n_kv_migrations: int = 0             # page transfers shipped between servers
    kv_migrated_bytes: float = 0.0       # bytes those transfers put on the links
    # directly accumulated prompt+output tokens of served requests (the
    # exact integer `throughput_tokens_per_s * makespan` reconstructs
    # lossily); 0 only for empty runs and legacy-constructed results
    served_tokens: int = 0

    # `metrics` (a repro.obs.MetricsRegistry, attached by `_aggregate`)
    # is a plain attribute, not a dataclass field: it carries the full
    # labeled counter/gauge/histogram registry the scalar fields above
    # are views of, without entering equality comparisons.
    metrics = None

    @property
    def total_energy(self) -> float:
        return self.e_tx + self.e_infer + self.e_idle

    @property
    def energy_per_token(self) -> float:
        """Joules of total (tx + inference + idle) energy per served
        token — the benchmark gate's allocation-efficiency metric."""
        tokens = self.served_tokens if self.served_tokens > 0 \
            else self.throughput_tokens_per_s * self.makespan
        return self.total_energy / tokens if tokens > 0 else 0.0

    def stats(self) -> Dict[str, object]:
        """Canonical-key stats dict (shared naming with
        `PerLLMServer.stats` / `ServingEngine.stats`), with the
        deprecated old-name aliases included for one release."""
        return with_aliases({
            "n_served": sum(self.per_server_served),
            "n_rejected": self.n_rejected,
            "n_preempted": self.n_preempted,
            "n_kv_migrations": self.n_kv_migrations,
            "kv_migrated_bytes": self.kv_migrated_bytes,
            "n_prefix_hits": self.n_prefix_hits,
            "kv_prefill_tokens_saved": self.kv_prefill_tokens_saved,
            "admitted_success_rate": self.admitted_success_rate,
            "avg_processing_time": self.avg_processing_time,
            "per_server_served": list(self.per_server_served),
            "served_tokens": self.served_tokens,
        })

    @classmethod
    def empty(cls, name: str, n_servers: int) -> "SimResult":
        """Zeroed result for a run that produced no outcomes."""
        return cls(name=name, n_services=0, success_rate=0.0,
                   avg_processing_time=0.0, p95_processing_time=0.0,
                   throughput_tokens_per_s=0.0, makespan=0.0,
                   e_tx=0.0, e_infer=0.0, e_idle=0.0,
                   per_server_served=[0] * n_servers)

    def row(self) -> str:
        extra = ""
        if self.n_rejected or self.n_preempted:
            extra = (f" adm_succ={self.admitted_success_rate*100:5.1f}%"
                     f" rej={self.n_rejected} pre={self.n_preempted}")
        if self.n_prefix_hits or self.n_kv_migrations or self.n_kv_orphaned:
            extra += (f" pfx={self.n_prefix_hits}"
                      f" mig={self.n_kv_migrations}"
                      f" orph={self.n_kv_orphaned}")
        return (f"{self.name:22s} succ={self.success_rate*100:5.1f}% "
                f"time={self.avg_processing_time:6.2f}s "
                f"thpt={self.throughput_tokens_per_s:8.1f} tok/s "
                f"energy={self.total_energy/1e3:8.1f} kJ "
                f"(tx={self.e_tx/1e3:.1f} inf={self.e_infer/1e3:.1f} "
                f"idle={self.e_idle/1e3:.1f})" + extra)


# ---------------------------------------------------------------------------
# Runtimes — simulator physics behind the shared event loop
# ---------------------------------------------------------------------------


def rejected_outcome(req, decision: Decision, t: float) -> Outcome:
    """The Outcome admission control emits for a shed request.

    The SLO-violation cost is a full deadline overshoot
    (`processing_time = 2×deadline`, i.e. normalized time slack −1) with
    success False; the request never touches a server, so no transmission
    or inference energy is charged anywhere. One definition shared by the
    simulator and the live server."""
    return Outcome(server=decision.server, tx_time=0.0, queue_time=0.0,
                   infer_time=0.0, finish=t,
                   processing_time=2.0 * req.deadline, success=False,
                   energy=0.0, rejected=True)


class _SimRuntimeBase(Runtime, LinkStateMixin):
    """Shared state for both simulator modes: server bookkeeping, the lane
    ledger, and the link topology's mutable state (per-link backlog and
    scenario scale overlays).

    Run counters live in a `repro.obs.MetricsRegistry` (`self.metrics`):
    the class-level `counter_attr` properties below keep every existing
    ``self.n_rejected += 1`` call site working while `SimResult` /
    exporters read straight out of the registry. The registry slot holds
    the plain Python number assigned, so accumulation order — and
    bit-identity with the pre-registry code — is unchanged.
    """

    n_rejected = counter_attr("n_rejected")
    n_preempted = counter_attr("n_preempted")
    n_kv_evictions = counter_attr("n_kv_evictions")
    kv_prefill_tokens_saved = counter_attr("kv_prefill_tokens_saved")
    n_prefix_hits = counter_attr("n_prefix_hits")
    n_kv_orphaned = counter_attr("n_kv_orphaned")
    n_kv_migrations = counter_attr("n_kv_migrations")
    kv_migrated_bytes = counter_attr("kv_migrated_bytes")

    def __init__(self, sim: "Simulator", policy, trace=None) -> None:
        super().__init__(policy, trace=trace)
        self.sim = sim
        self.specs = sim.specs
        self.init_link_state(sim.topology)
        self.topo = self.topology
        self.states = [ServerState(spec=s) for s in self.specs]
        self.lane_free = [[0.0] * s.max_concurrency for s in self.specs]
        self.outcomes: List[Outcome] = []
        self.metrics = MetricsRegistry()
        self.n_rejected = 0
        self.n_preempted = 0
        self.n_kv_evictions = 0
        self.kv_prefill_tokens_saved = 0
        self.n_prefix_hits = 0
        self.n_kv_orphaned = 0
        self.n_kv_migrations = 0
        self.kv_migrated_bytes = 0.0
        # KV-wait span bookkeeping, written only when tracing is on:
        # sid -> instant the request joined its server's kv_wait FIFO
        self._kv_wait_since: Dict[int, float] = {}

    def on_bandwidth_change(self, ev: BandwidthChange) -> None:
        self.apply_bandwidth_scales(ev)

    def server_factor(self, j: int, link_factors: Dict[str, float]) -> float:
        """Effective per-server bandwidth factor under current overlays."""
        return self.topo.server_factor(j, self.specs[j].bandwidth,
                                       link_factors, self.link_scale)

    def place(self, t: float, request, decision: Decision) -> None:
        # every event-routed arrival (seeded or requeued) lands its
        # ARRIVAL + DECISION rows here; the array core's direct-dispatch
        # fast branch (`_cursor_arrival`) emits its own. The guard
        # mirrors _trace_decision's only-requeues-and-sheds condition so
        # the happy path pays one comparison, not a call.
        if self.trace is not None and (request.preemptions
                                       or not decision.admit):
            self._trace_decision(t, request, decision)
        super().place(t, request, decision)

    def on_reject(self, ev: Reject) -> None:
        """Admission control shed a request: emit the rejected Outcome."""
        req = ev.request
        out = rejected_outcome(req, ev.decision, ev.time)
        req.finish = -1.0
        req.server = -1
        self.n_rejected += 1
        self.outcomes.append(out)
        if self.trace is not None:
            self.trace.append(KIND_REJECT, req.sid, ev.time, ev.time,
                              ev.decision.server, req.class_id)
        self.policy.feedback(req, out)

    # ---------------- trace emission helpers -----------------------------
    # All no-ops unless a recorder is attached; emissions read only plain
    # request/booking fields (no RNG, no lazy views, no ledger writes),
    # which is what keeps traced runs bit-identical to untraced ones.
    def _trace_decision(self, t: float, req, d: Decision) -> None:
        """ARRIVAL/DECISION markers for the *non-implicit* placements:
        requeues after preemption and admission sheds. Happy-path
        decisions emit nothing here — their decision time is the TX
        span's t0 and their server/tier ride on the completion spans —
        which keeps the traced hot path within the CI overhead gate."""
        if not req.preemptions and d.admit:
            return
        alloc = d.alloc
        tier = alloc.freq_tier if alloc is not None else 0
        sid, cls = req.sid, req.class_id
        self.trace.append_rows((
            (KIND_ARRIVAL, sid, t, t, -1, cls, 0, 0.0,
             req.preemptions, -1),
            (KIND_DECISION, sid, t, t, d.server, cls, tier, 0.0,
             d.admit, -1),
        ))

    def _trace_complete(self, req, j: int, lane: int, tier: int,
                        ready: float, begin: float, finish: float,
                        e_tx: float, e_inf: float,
                        success: bool) -> None:
        """Emit one completed request's lifecycle as a single compressed
        completion record (expanded to TX/QUEUE/INFER/DONE rows at
        materialization). TX runs arrival→ready (uplink wait + transfer,
        the Outcome's `tx_time` window), QUEUE ready→begin, INFER
        begin→finish; the three spans telescope to exactly
        `processing_time` (property-tested)."""
        self.trace.complete(req.sid, req.arrival, ready, begin, finish,
                            j, req.class_id, tier, lane, e_tx, e_inf,
                            req.output_tokens, success)

    def _trace_dispatch_kv(self, t: float, req, j: int,
                           kv_resumed: bool) -> None:
        """KV_WAIT span (if the request sat in the kv_wait FIFO) and the
        RESUME marker (zero-re-prefill dispatch on preserved pages)."""
        tr = self.trace
        since = self._kv_wait_since.pop(req.sid, None)
        if since is not None:
            tr.append(KIND_KV_WAIT, req.sid, since, t, j, req.class_id)
        if kv_resumed:
            tr.append(KIND_RESUME, req.sid, t, t, j, req.class_id)


@dataclasses.dataclass(eq=False, slots=True)
class _Booking:
    """One dispatched request's committed physics (identity-hashed so a
    cancelled booking can never be confused with its requeue's)."""

    request: ServiceRequest
    j: int
    li: int                 # lane index booked on server j
    lane_prev: float        # lane value before this booking (for rollback)
    tx_dur: float
    charge_from: float      # tx-energy window start (arrival, or the
    #                         requeue instant for preempted continuations —
    #                         the pre-preemption window was already billed)
    ready: float            # transfer complete (uplink wait + tx)
    begin: float            # lane booking start
    t_inf: float
    finish: float
    cancelled: bool = False
    kv_resumed: bool = False  # decode-only window (pages survived eviction)
    prefix_saved: int = 0     # prompt tokens a resident shared prefix skipped
    alloc: Allocation = NOMINAL  # the Decision's resource allocation


@dataclasses.dataclass
class _PrefixEntry:
    """Resident shared-prefix pages on one server.

    The entry *owns* its blocks in the server's KV ledger — they are
    charged to `kv_used` when the entry is created (out of the creating
    request's full claim) and returned when the entry is reclaimed — so
    every sharer charges only its unique suffix. `ready` is the instant
    the creator's prefill materializes the pages; dispatches before it
    pay full prefill, dispatches after it skip the prefix."""

    blocks: int          # full KV blocks the resident prefix spans
    tokens: int          # blocks × kv_block_tokens
    refs: int            # live dispatched requests pinning the entry
    ready: float         # prefill-complete instant (hits need t >= ready)
    stamp: float         # last touch, for LRU reclaim of idle entries


class _LazyViewList(list):
    """ClusterView list field materialized on first read.

    The fill callback snapshots runtime state; it runs (at most once)
    inside the policy's `assign`, before any state mutates, so the
    content is identical to an eager snapshot at view-build time. Fields
    most policies never touch (`running`, `tier_load`) then cost nothing
    per arrival."""

    __slots__ = ("_fill",)

    def __init__(self, fill):
        super().__init__()
        self._fill = fill

    def _ensure(self):
        fill, self._fill = self._fill, None
        if fill is not None:
            self.extend(fill())

    def __len__(self):
        self._ensure()
        return list.__len__(self)

    def __iter__(self):
        self._ensure()
        return list.__iter__(self)

    def __getitem__(self, i):
        self._ensure()
        return list.__getitem__(self, i)

    def __eq__(self, other):
        self._ensure()
        return list.__eq__(self, other)

    def __ne__(self, other):
        self._ensure()
        return list.__ne__(self, other)

    def __contains__(self, x):
        self._ensure()
        return list.__contains__(self, x)

    def __repr__(self):
        self._ensure()
        return list.__repr__(self)

    def index(self, *a):
        self._ensure()
        return list.index(self, *a)

    def count(self, x):
        self._ensure()
        return list.count(self, x)

    def copy(self):
        self._ensure()
        return list(self)

    __hash__ = None


class _LazyViewDict(dict):
    """ClusterView dict field materialized on first read (same snapshot
    argument as `_LazyViewList`)."""

    __slots__ = ("_fill",)

    def __init__(self, fill):
        super().__init__()
        self._fill = fill

    def _ensure(self):
        fill, self._fill = self._fill, None
        if fill is not None:
            dict.update(self, fill())

    def __len__(self):
        self._ensure()
        return dict.__len__(self)

    def __iter__(self):
        self._ensure()
        return dict.__iter__(self)

    def __getitem__(self, k):
        self._ensure()
        return dict.__getitem__(self, k)

    def __contains__(self, k):
        self._ensure()
        return dict.__contains__(self, k)

    def __eq__(self, other):
        self._ensure()
        return dict.__eq__(self, other)

    def __ne__(self, other):
        self._ensure()
        return dict.__ne__(self, other)

    def __repr__(self):
        self._ensure()
        return dict.__repr__(self)

    def get(self, k, default=None):
        self._ensure()
        return dict.get(self, k, default)

    def keys(self):
        self._ensure()
        return dict.keys(self)

    def values(self):
        self._ensure()
        return dict.values(self)

    def items(self):
        self._ensure()
        return dict.items(self)

    def copy(self):
        self._ensure()
        return dict(self)

    __hash__ = None


class _CountingLoop(EventLoop):
    """EventLoop that tracks how many pending events are real work
    (anything but `BandwidthChange`), so the fast drain's only-
    housekeeping-left termination check is O(1) instead of scanning the
    heap."""

    def __init__(self) -> None:
        super().__init__()
        self.n_work = 0

    def push(self, event) -> None:
        if event.priority != 0:          # BandwidthChange is priority 0
            self.n_work += 1
        super().push(event)


class _EventSimRuntime(_SimRuntimeBase):
    """Pure event-driven semantics — the array-backed fast core.

    Result-identical to `reference_sim._ReferenceEventRuntime` (the
    retained pre-vectorization implementation, pinned by property tests
    and the goldens), but engineered for million-arrival runs:

    * **Ledger vectors, not per-view rebuilds**: the per-server bandwidth
      factor and path-free-at vectors are maintained incrementally —
      updated at the events that change them (bandwidth changes, link
      bookings) — so `build_view` hands out copies instead of recomputing
      dict-driven topology walks per arrival.
    * **Lazy view fields**: `running`, `tier_load`, `link_bw` and
      `link_queue` materialize on first read inside the policy's
      `assign` (before any state mutates, so content is identical);
      policies that never read them no longer pay O(in-flight) snapshot
      cost on every arrival.
    * **Arrival cursor**: the sorted workload is walked with a cursor
      merged against the heap instead of pre-pushing one Arrival dataclass
      per service, with virtual sequence numbers reserved so every
      tie-break matches the seeded-heap ordering.
    * **Flat hot events**: TxDone/InferDone are pushed as raw
      `(time, priority, seq, booking)` heap entries and dispatched by a
      type switch in `drain`, skipping per-event dataclass allocation and
      the generic MRO handler walk. Rare events (bandwidth, deferrals,
      KV migrations, requeues) keep the generic dataclass path.

    Bookings stay in `_inflight` until completion, which is what gives
    views their `running` tasks and `Preempt` a victim ledger to roll
    back.
    """

    def __init__(self, sim: "Simulator", policy, trace=None) -> None:
        super().__init__(sim, policy, trace=trace)
        self.loop = _CountingLoop()
        self._link_factors: Dict[str, float] = \
            {n: 1.0 for n in self.topo.links}
        self._inflight: Dict[int, _Booking] = {}
        # paged-KV ledger: blocks in use per server, plus the FIFO of
        # routed requests waiting for their server's pool to free up
        self._kv_modeled = any(s.kv_blocks > 0 for s in self.specs)
        self.kv_used = [0] * len(self.specs)
        self.kv_wait: List[List[tuple]] = [[] for _ in self.specs]
        # single-use tokens: preemptor sid -> server whose drop_kv
        # preemption it issued; grants first claim on the freed blocks
        self._kv_express: Dict[int, int] = {}
        # shared-prefix ledger: per-server {prefix_id: _PrefixEntry} of
        # resident system-prompt pages, which dispatched request pins
        # which entry (sid -> (server, prefix_id)), and per-sid prefill
        # tokens the pending dispatch skips (consumed by `dispatch`)
        self._prefix: List[Dict[int, _PrefixEntry]] = \
            [{} for _ in self.specs]
        self._prefix_pin: Dict[int, tuple] = {}
        self._prefix_saved: Dict[int, int] = {}
        n = len(self.specs)
        self._n = n
        self._tiered = any(s.n_tiers > 1 for s in self.specs)
        # link topology index: which servers each link serves, and the
        # dedicated-link fast path (a single private link lets bookings
        # update the path-free vector without a path walk)
        topo = self.topo
        self._link_servers: Dict[str, List[int]] = \
            {name: [] for name in topo.links}
        for j in range(n):
            for name in topo.paths[j]:
                self._link_servers[name].append(j)
        self._single_link: List[Optional[str]] = []
        for j in range(n):
            path = topo.paths[j]
            if len(path) == 1 and self._link_servers[path[0]] == [j]:
                self._single_link.append(path[0])
            else:
                self._single_link.append(None)
        # incrementally maintained ledger vectors (the reference core
        # recomputes both per view)
        self._uplink_vec: List[float] = [0.0] * n
        # arrival cursor state (`seed_arrivals`)
        self._services: Optional[List[ServiceRequest]] = None
        if any(link.fluctuating for link in self.topo.links.values()):
            self._resample_factors(0.0)
        self._refresh_bandwidth_caches()

    # ---------------- bandwidth as an event stream -----------------------
    def _refresh_bandwidth_caches(self) -> None:
        """Recompute the per-server factor vector and the observed
        per-link bandwidth map. Only bandwidth events change either, so
        this runs per `BandwidthChange` instead of per arrival — same
        floats as the reference core's per-view recomputation."""
        factors, scale = self._link_factors, self.link_scale
        topo = self.topo
        self._factor_vec = [self.server_factor(j, factors)
                            for j in range(self._n)]
        self._link_bw_cache = {
            name: topo.links[name].capacity * factors.get(name, 1.0)
            * scale[name] for name in topo.links}

    def _resample_factors(self, t: float) -> None:
        k = int(round(t / self.sim.bw_interval))
        self._link_factors = self.topo.factors(k)
        self.loop.push(BandwidthChange(t + self.sim.bw_interval,
                                       resample=True))

    def on_bandwidth_change(self, ev: BandwidthChange) -> None:
        super().on_bandwidth_change(ev)
        if ev.resample:
            self._resample_factors(ev.time)
        self._refresh_bandwidth_caches()

    def _factor(self, j: int) -> float:
        return self._factor_vec[j]

    def on_reject(self, ev: Reject) -> None:
        """A previously preempted request shed on requeue must not leak
        the pages preserved for its resume."""
        req = ev.request
        if req.kv_server >= 0 and req.kv_blocks > 0:
            blocks, j = req.kv_blocks, req.kv_server
            req.kv_server, req.kv_blocks = -1, 0
            self._prefix_unpin(req, ev.time)
            self._kv_free(j, blocks, ev.time)
        super().on_reject(ev)

    # ---------------- link ledger ----------------------------------------
    def _book_links(self, path, end: float) -> None:
        """Advance every link on `path` to `end` and refresh the
        path-free-at vector of each server those links serve."""
        link_free = self.link_free
        for name in path:
            link_free[name] = end
        vec = self._uplink_vec
        paths = self.topo.paths
        done = set()
        for name in path:
            for j in self._link_servers[name]:
                if j not in done:
                    done.add(j)
                    vec[j] = max(link_free[lk] for lk in paths[j])

    # ---------------- the Runtime contract -------------------------------
    def slot_index(self, t: float) -> int:
        return int(t / self.sim.bw_interval)

    def _fill_running(self) -> List[List[RunningTask]]:
        per: List[List[RunningTask]] = [[] for _ in range(self._n)]
        for sid, b in self._inflight.items():
            req = b.request
            per[b.j].append(RunningTask(
                sid=sid, server=b.j, class_id=req.class_id,
                deadline_at=req.arrival + req.deadline,
                begin=b.begin, finish_est=b.finish,
                tier=b.alloc.freq_tier))
        return per

    def _fill_tier_load(self, t: float) -> List[List[float]]:
        # per-server tier state: committed in-flight lane-seconds per
        # DVFS tier (the within-batch commits stack on via the view's
        # own `commit`)
        tier_load = [[0.0] * s.n_tiers for s in self.specs]
        for b in self._inflight.values():
            k = b.alloc.freq_tier
            if k < 0:
                k = self.specs[b.j].nominal_tier
            tier_load[b.j][k] += max(b.finish - max(b.begin, t), 0.0)
        return tier_load

    def _fill_link_queue(self, t: float) -> Dict[str, float]:
        return {name: max(f - t, 0.0)
                for name, f in self.link_free.items()}

    def build_view(self, t: float) -> ClusterView:
        n = self._n
        kv_kwargs = {}
        if self._kv_modeled:
            # idle prefix entries are reclaimable page cache, so the view
            # reports them as free (mirroring PagedKVCache.free_blocks);
            # resident *ready* prefixes are surfaced so policies can rank
            # servers by expected prefix hit
            idle = [sum(e.blocks for e in self._prefix[j].values()
                        if e.refs <= 0) for j in range(n)]
            kv_kwargs = dict(
                kv_free_blocks=[self.specs[j].kv_blocks - self.kv_used[j]
                                + idle[j] for j in range(n)],
                kv_total_blocks=[self.specs[j].kv_blocks
                                 for j in range(n)],
                kv_prefix_tokens=[
                    {pid: e.tokens for pid, e in self._prefix[j].items()
                     if e.ready <= t} for j in range(n)])
        # direct construction (no dataclass __init__/kwarg machinery):
        # ClusterView is a plain dataclass with no __post_init__, so
        # assigning its instance dict wholesale is equivalent — this runs
        # once per arrival and the savings are real at 10^6 arrivals
        view = ClusterView.__new__(ClusterView)
        view.__dict__ = {
            "t": t,
            "specs": self.specs,
            "bw_factor": self._factor_vec.copy(),
            "uplink_free_at": self._uplink_vec.copy(),
            "lane_free": list(map(list.copy, self.lane_free)),
            "link_bw": _LazyViewDict(self._link_bw_cache.copy),
            "link_queue": _LazyViewDict(lambda: self._fill_link_queue(t)),
            "paths": self.topo.paths,
            "running": _LazyViewList(self._fill_running),
            "kv_free_blocks": kv_kwargs.get("kv_free_blocks"),
            "kv_total_blocks": kv_kwargs.get("kv_total_blocks"),
            "kv_prefix_tokens": kv_kwargs.get("kv_prefix_tokens"),
            "tier_load": (_LazyViewList(lambda: self._fill_tier_load(t))
                          if self._tiered else None),
        }
        return view

    # ---------------- shared-prefix ledger -------------------------------
    def _prefix_blocks(self, req: ServiceRequest, j: int) -> int:
        """Full KV blocks of `req`'s shared prefix on server j's block
        geometry (capped so at least one suffix token always remains —
        the same cap `PagedKVCache.match_prefix` applies)."""
        if req.prefix_id < 0 or req.prefix_tokens <= 0:
            return 0
        span = min(req.prefix_tokens, req.prompt_tokens - 1)
        return max(span, 0) // self.specs[j].kv_block_tokens

    def _kv_need(self, req: ServiceRequest, j: int, t: float) -> int:
        """Blocks `req` would claim on j right now: full need minus any
        *ready* resident prefix blocks it can share. Pure — admission and
        the kv-wait drain peek both call it at the same instant, so they
        always agree on whether a dispatch is a prefix hit."""
        need = self.specs[j].kv_blocks_needed(req.prompt_tokens,
                                              req.output_tokens)
        entry = self._prefix[j].get(req.prefix_id) \
            if req.prefix_id >= 0 else None
        if entry is not None and entry.ready <= t:
            need -= min(entry.blocks, self._prefix_blocks(req, j))
        return need

    def _prefix_attach(self, t: float, req: ServiceRequest, j: int) -> int:
        """Pin (or create) the prefix entry `req` uses on j; returns the
        prefill tokens this dispatch skips.

        First of its pool: the request becomes the entry's *creator* — the
        entry takes ownership of the prefix blocks out of the creator's
        just-claimed full allocation (`kv_used` already covers them) and
        `dispatch` stamps `ready` once the creator's prefill window is
        known. Later dispatches pin the entry and, when it is ready, skip
        `entry.tokens` of prefill while charging only their suffix."""
        p_blocks = self._prefix_blocks(req, j)
        if p_blocks <= 0:
            return 0
        bt = self.specs[j].kv_block_tokens
        entry = self._prefix[j].get(req.prefix_id)
        if entry is None:
            self._prefix[j][req.prefix_id] = _PrefixEntry(
                blocks=p_blocks, tokens=p_blocks * bt, refs=1,
                ready=float("inf"), stamp=t)
            req.kv_blocks -= p_blocks
            self._prefix_pin[req.sid] = (j, req.prefix_id)
            return 0
        if entry.ready > t:
            return 0         # still prefilling: this dispatch pays in full
        entry.refs += 1
        entry.stamp = t
        self._prefix_pin[req.sid] = (j, req.prefix_id)
        return min(entry.blocks, p_blocks) * bt

    def _prefix_unpin(self, req: ServiceRequest, t: float) -> None:
        """Drop `req`'s pin on its prefix entry. An entry whose prefill
        never completed (creator evicted mid-prefill) is removed outright
        — its pages hold garbage; ready entries linger unpinned as
        reclaimable page cache."""
        pin = self._prefix_pin.pop(req.sid, None)
        if pin is None:
            return
        j, pid = pin
        entry = self._prefix[j].get(pid)
        if entry is None:
            return
        entry.refs -= 1
        entry.stamp = t
        if entry.refs <= 0 and entry.ready > t:
            self.kv_used[j] -= entry.blocks
            del self._prefix[j][pid]

    def _prefix_reclaim(self, j: int, need: int, keep: int = -1) -> None:
        """LRU-evict idle (unpinned) prefix entries on j until `need`
        blocks fit — never the entry `keep`, which the requester is about
        to share."""
        table = self._prefix[j]
        cap = self.specs[j].kv_blocks
        while self.kv_used[j] + need > cap:
            idle = [(e.stamp, pid) for pid, e in table.items()
                    if e.refs <= 0 and pid != keep]
            if not idle:
                return
            _, pid = min(idle)
            self.kv_used[j] -= table.pop(pid).blocks

    # ---------------- paged-KV ledger ------------------------------------
    def _kv_admit(self, t: float, req: ServiceRequest,
                  decision: Decision, from_wait: bool = False) -> bool:
        """Claim KV blocks for `req` on its target server.

        True = blocks held (dispatch may proceed); False = the request
        joined the server's KV-wait queue (re-dispatched by `_kv_free`
        when blocks return). The queue is strictly FIFO with head-of-line
        blocking — a newcomer enqueues behind existing waiters even when
        its own allocation would fit, matching the paged
        `ServingEngine._admit` semantics (`from_wait` marks the drain
        path's own re-dispatches, which must not re-enqueue behind the
        waiters they precede). A requeued request whose preserved pages
        live on the *target* server resumes on its existing blocks; pages
        preserved on any *other* server migrate or are abandoned in
        `dispatch`, before admission runs. A request whose pool already
        holds its shared prefix (ready `_PrefixEntry`) claims only its
        unique suffix blocks and skips that much prefill."""
        j = decision.server
        spec = self.specs[j]
        if req.kv_server == j and req.kv_blocks > 0:
            return True                      # resume on the held pages
        full = spec.kv_blocks_needed(req.prompt_tokens, req.output_tokens)
        if full > spec.kv_blocks:
            # physically unfittable on this server (even an empty pool is
            # too small): a KV-blind policy routed it here, so the runtime
            # sheds it — crashing the run or queueing forever would lose
            # the request silently
            self.handle(Reject(t, request=req, decision=decision))
            return False
        need = self._kv_need(req, j, t)
        express = self._kv_express.pop(req.sid, -1) == j
        if self.kv_used[j] + need > spec.kv_blocks:
            # idle resident prefixes are just page cache — evict LRU ones
            # before making the request wait
            self._prefix_reclaim(j, need, keep=req.prefix_id)
        if self.kv_used[j] + need > spec.kv_blocks \
                or (self.kv_wait[j] and not (from_wait or express)):
            self.kv_wait[j].append((req, decision))
            if self.trace is not None:
                self._kv_wait_since.setdefault(req.sid, t)
            return False
        self.kv_used[j] += need
        req.kv_server, req.kv_blocks = j, need
        saved = self._prefix_attach(t, req, j)
        if saved:
            self._prefix_saved[req.sid] = saved
        return True

    def _kv_free(self, j: int, n_blocks: int, t: float) -> None:
        """Return blocks to server j's pool and re-dispatch every KV-wait
        request that now fits (FIFO, head-of-line blocking)."""
        self.kv_used[j] -= n_blocks
        assert self.kv_used[j] >= 0, (j, self.kv_used[j])
        while self.kv_wait[j]:
            req, decision = self.kv_wait[j][0]
            need = self._kv_need(req, j, t)
            if self.kv_used[j] + need > self.specs[j].kv_blocks:
                self._prefix_reclaim(j, need, keep=req.prefix_id)
                if self.kv_used[j] + need > self.specs[j].kv_blocks:
                    break
            self.kv_wait[j].pop(0)
            self.dispatch(t, req, decision, _from_kv_wait=True)

    def dispatch(self, t: float, req: ServiceRequest,
                 decision: Decision, _from_kv_wait: bool = False) -> None:
        j = decision.server
        spec = self.specs[j]
        st = self.states[j]
        if req.kv_server >= 0 and req.kv_server != j:
            if self._kv_migrate(t, req, decision):
                return       # pages in flight: KvMigrate re-dispatches
            # pages preserved on another server that can't (or weren't
            # asked to) migrate are abandoned: freed on their home server
            # — even when the *target* doesn't model KV, or the old pool
            # leaks those blocks forever — counted, and the request pays
            # full re-prefill wherever it lands
            self.n_kv_orphaned += 1
            self._prefix_unpin(req, t)
            self._kv_free(req.kv_server, req.kv_blocks, t)
            req.kv_server, req.kv_blocks = -1, 0
        kv_resumed = False
        prefix_saved = 0
        if spec.kv_blocks > 0:
            kv_resumed = req.kv_server == j and req.kv_blocks > 0
            if not self._kv_admit(t, req, decision,
                                  from_wait=_from_kv_wait):
                return                       # waiting on KV blocks
            prefix_saved = self._prefix_saved.pop(req.sid, 0)
        if self.trace is not None and (kv_resumed or self._kv_wait_since):
            self._trace_dispatch_kv(t, req, j, kv_resumed)
        alloc = decision.alloc
        free = self._uplink_vec[j]
        tx_start = t if t > free else free
        # a sub-unit bandwidth share stretches the transfer by 1/share and
        # occupies the path for the whole stretched window (exclusive-
        # window semantics: shares can never oversubscribe a link)
        share = self._factor_vec[j] * alloc.bw_share
        tx_dur = req.payload_bytes * 8.0 \
            / (spec.bandwidth * (share if share > 1e-9 else 1e-9))
        end = tx_start + tx_dur
        # a transfer occupies its whole path
        name = self._single_link[j]
        if name is not None:
            self.link_free[name] = end
            self._uplink_vec[j] = end
        else:
            self._book_links(self.topo.paths[j], end)
        st.uplink_free_at = end
        ready = end
        # the lane is booked at dispatch — the routed request is committed
        # capacity, visible to every later arrival's fresh view — while the
        # events below mark when its phases actually happen
        lanes = self.lane_free[j]
        li = 0
        lane_prev = lanes[0]
        for k in range(1, len(lanes)):
            v = lanes[k]
            if v < lane_prev:
                li = k
                lane_prev = v
        begin = ready if ready > lane_prev else lane_prev
        t_inf = self.sim._draw_infer(req, j, kv_resumed, alloc, prefix_saved)
        finish = begin + t_inf
        lanes[li] = finish
        pin = self._prefix_pin.get(req.sid)
        if pin is not None:
            # first dispatch of this pool's creator: the shared pages
            # materialize once its own prefill window has run
            entry = self._prefix[pin[0]].get(pin[1])
            if entry is not None and entry.ready == float("inf"):
                entry.ready = begin + spec.prefill_time(entry.tokens)
        ctx = _Booking(request=req, j=j, li=li, lane_prev=lane_prev,
                       tx_dur=tx_dur,
                       charge_from=t if req.preemptions else req.arrival,
                       ready=ready, begin=begin, t_inf=t_inf, finish=finish,
                       kv_resumed=kv_resumed, prefix_saved=prefix_saved,
                       alloc=alloc)
        self._inflight[req.sid] = ctx
        # flat hot events: the booking itself is the payload; priorities 3
        # (TxDone) and 1 (InferDone) are unique among pushed events, so
        # `drain` routes on them without per-event dataclass churn
        loop = self.loop
        heap = loop._heap
        heapq.heappush(heap, (ready, 3, loop._seq, ctx))
        heapq.heappush(heap, (finish, 1, loop._seq + 1, ctx))
        loop._seq += 2
        loop.n_work += 2

    def _kv_migrate(self, t: float, req: ServiceRequest,
                    decision: Decision) -> bool:
        """Ship `req`'s preserved pages from their home server to
        `decision.server` over the link topology, if asked and affordable.

        The transfer occupies every link on the union of both servers'
        paths (pages travel down one side of the tree and up the other)
        at the path's bottleneck bandwidth, charged against the same
        per-link ledgers payload transfers use — migration and uplink
        traffic genuinely contend. The destination's blocks are claimed
        up front so its pool can't oversubscribe while the pages are in
        flight; when they land (`KvMigrate`) the source frees and the
        request re-dispatches as a zero-re-prefill resume. False = the
        caller falls back to abandoning the pages (full re-prefill)."""
        j = decision.server
        src = req.kv_server
        spec = self.specs[j]
        if not decision.migrate_kv or spec.kv_blocks <= 0:
            return False
        need = spec.kv_blocks_needed(req.prompt_tokens, req.output_tokens)
        if need > spec.kv_blocks or self.kv_wait[j]:
            return False     # destination can't host the pages right now
        if self.kv_used[j] + need > spec.kv_blocks:
            self._prefix_reclaim(j, need, keep=req.prefix_id)
            if self.kv_used[j] + need > spec.kv_blocks:
                return False
        src_spec = self.specs[src]
        n_bytes = req.kv_blocks * src_spec.kv_block_tokens \
            * src_spec.kv_bytes_per_token()
        if n_bytes <= 0.0:
            return False     # nothing to ship (e.g. attention-free arch)
        path = self.topo.migration_path(src, j)
        bw = self.topo.migration_bandwidth(src, j, self._link_factors,
                                           self.link_scale)
        if not path or bw <= 0.0:
            return False
        self.kv_used[j] += need
        start = max(t, max(self.link_free[name] for name in path))
        end = start + n_bytes * 8.0 / bw
        self._book_links(path, end)
        st = self.states[src]
        # the source's radio pushes the pages; like payload transfers,
        # energy accrues over the whole window including the queue wait
        st.e_tx += (end - t) * src_spec.tx_power
        st.tx_busy_time += end - start
        self.n_kv_migrations += 1
        self.kv_migrated_bytes += n_bytes
        if self.trace is not None:
            self.trace.append(KIND_MIGRATE, req.sid, t, end, j,
                              req.class_id, 0,
                              (end - t) * src_spec.tx_power, n_bytes,
                              self.trace.intern(f"{src}->{j}"))
        self.loop.push(KvMigrate(end, request=req, decision=decision,
                                 context=(src, req.kv_blocks, j, need)))
        return True

    def on_kv_migrate(self, ev: KvMigrate) -> None:
        """Migrated pages landed: free them at the source, hand them to
        the request on the destination, and re-dispatch — the dispatch
        sees `kv_server == server`, so it books a decode-only resume with
        zero re-prefill (the destination's blocks were already claimed
        when the transfer started)."""
        req = ev.request
        src, src_blocks, j, need = ev.context
        self._prefix_unpin(req, ev.time)
        self._kv_free(src, src_blocks, ev.time)
        req.kv_server, req.kv_blocks = j, need
        self.dispatch(ev.time, req, ev.decision)

    def _tx_done(self, b: _Booking) -> None:
        st = self.states[b.j]
        # transmission energy accrues over the whole transfer window,
        # including the congestion queue (paper §2.3); for a preempted
        # continuation the window starts at the requeue instant — the
        # pre-preemption window was billed by the first TxDone. During the
        # transfer itself the radio draws tx_power × bw_share (a granted
        # slice lights up a slice of the link), so a sub-unit share's
        # *transfer* energy is share-invariant and only its queue window
        # still charges full power.
        st.e_tx += (b.ready - b.charge_from) * self.specs[b.j].tx_power \
            - (1.0 - b.alloc.bw_share) * b.tx_dur * self.specs[b.j].tx_power
        st.tx_busy_time += b.tx_dur

    def on_tx_done(self, ev: TxDone) -> None:
        self._tx_done(ev.context)

    def on_preempt(self, ev: Preempt) -> None:
        """Return the victim's lane and requeue its remaining work.

        Runs synchronously inside the preemptor's `place`, so the freed
        lane is visible before the preemptor's dispatch books it. The
        victim's booking rolls back only if it is still the last booking
        on its lane; partial decode already burned is charged as wasted
        inference energy, and the victim re-enters as a fresh Arrival
        carrying its remaining decode tokens.

        On a KV-modeled server the victim's pages survive the eviction by
        default (`ev.drop_kv` False): they stay allocated, and if the
        requeue lands back on this server the continuation skips prefill
        entirely. `drop_kv` frees them on the spot instead — preemption
        as *memory* relief — at the price of a full re-prefill wherever
        the victim resumes. Servers without a block pool keep the legacy
        semantics: KV is dropped with the lane and preemption is never
        free."""
        b = self._inflight.get(ev.victim)
        if b is None:
            return       # victim already finished (or never dispatched)
        t = ev.time
        if t < b.ready:
            # victim still in transit: its payload occupies the path links
            # and its TxDone will bill the transfer — aborting here would
            # leave ghost link occupancy and double-charge tx energy, so
            # only lane-resident (transfer-complete) victims are preempted
            return
        lanes = self.lane_free[b.j]
        if lanes[b.li] != b.finish:
            # a later booking already stacked onto the victim's lane:
            # cancelling would free no capacity (the stacked booking's
            # start was computed from the victim's finish), so refuse —
            # killing the victim here would be pure wasted work
            return
        del self._inflight[ev.victim]
        b.cancelled = True
        req = b.request
        spec = self.specs[b.j]
        st = self.states[b.j]
        lanes[b.li] = b.lane_prev if t <= b.begin else t
        e_waste = 0.0
        if t > b.begin:
            # wasted partial decode: the server burned real energy on it,
            # at the victim's allocated tier/share
            done = min(t, b.finish) - b.begin
            e_waste = spec.infer_energy(done, tier=b.alloc.freq_tier,
                                        lane_share=b.alloc.lane_share)
            st.e_infer += e_waste
            st.busy_time += done / spec.max_concurrency
            frac_left = max(b.finish - t, 0.0) / b.t_inf
            remaining = max(1, int(math.ceil(req.output_tokens * frac_left)))
        else:
            remaining = req.output_tokens
        if spec.kv_blocks > 0 and req.kv_blocks > 0:
            started = t > b.begin
            # a booking that never began holds prefilled pages only if it
            # was itself a resume (its KV survives from the earlier run)
            prefilled = started or b.kv_resumed
            if ev.drop_kv and ev.request is not None:
                # memory-pressure eviction: the blocks return *undrained*
                # and the preemptor (dispatched synchronously next, inside
                # the same `place`) gets first claim on them — that is the
                # whole point of the drop. Leftovers reach the kv_wait
                # FIFO at the next free event on this server.
                self.kv_used[b.j] -= req.kv_blocks
                req.kv_server, req.kv_blocks = -1, 0
                self._prefix_unpin(req, t)
                self._kv_express[ev.request.sid] = b.j
            elif ev.drop_kv or not prefilled:
                self._prefix_unpin(req, t)
                self._kv_free(b.j, req.kv_blocks, t)
                req.kv_server, req.kv_blocks = -1, 0
            if started:
                self.n_kv_evictions += 1
        req.output_tokens = remaining
        req.preemptions += 1
        self.n_preempted += 1
        if self.trace is not None:
            # span covers the wasted decode window (a point at t when the
            # victim had not yet begun); value = tokens left to requeue
            self.trace.append(KIND_PREEMPT, req.sid,
                              b.begin if t > b.begin else t, t, b.j,
                              req.class_id, b.alloc.freq_tier, e_waste,
                              float(remaining), b.li)
        self.loop.push(Arrival(t, requests=(req,)))

    def _infer_done(self, b: _Booking, finish: float) -> None:
        if b.cancelled:
            return                       # preempted: the requeue completes
        req = b.request
        self._inflight.pop(req.sid, None)
        spec = self.specs[b.j]
        st = self.states[b.j]
        st.busy_time += b.t_inf / spec.max_concurrency
        e_inf = spec.infer_energy(b.t_inf, tier=b.alloc.freq_tier,
                                  lane_share=b.alloc.lane_share)
        st.e_infer += e_inf
        st.tokens_out += req.output_tokens
        st.served += 1
        if spec.kv_blocks > 0 and req.kv_blocks > 0:
            blocks, req.kv_server, req.kv_blocks = req.kv_blocks, -1, 0
            self._prefix_unpin(req, finish)
            self._kv_free(b.j, blocks, finish)
        if b.kv_resumed:
            # credited at completion, not dispatch: a resume preempted
            # again before it ran must not bank phantom savings
            self.kv_prefill_tokens_saved += req.prompt_tokens
        elif b.prefix_saved:
            # same late-credit rule for shared-prefix hits
            self.kv_prefill_tokens_saved += b.prefix_saved
            self.n_prefix_hits += 1
        req.finish = finish
        req.server = b.j
        proc = finish - req.arrival
        out = Outcome(
            server=b.j, tx_time=(b.ready - req.arrival),
            queue_time=max(b.begin - b.ready, 0.0), infer_time=b.t_inf,
            finish=finish, processing_time=proc,
            success=proc <= req.deadline,
            energy=b.tx_dur * spec.tx_power * b.alloc.bw_share + e_inf)
        self.outcomes.append(out)
        if self.trace is not None:
            self._trace_complete(req, b.j, b.li, b.alloc.freq_tier,
                                 b.ready, b.begin, finish,
                                 b.tx_dur * spec.tx_power
                                 * b.alloc.bw_share, e_inf, out.success)
        self.policy.feedback(req, out)

    def on_infer_done(self, ev: InferDone) -> None:
        self._infer_done(ev.context, ev.time)

    # ---------------- arrival cursor & fast drain ------------------------
    def seed_arrivals(self, services: List[ServiceRequest]) -> None:
        """Walk `services` (sorted by arrival) with a cursor instead of
        pre-pushing one Arrival event each. Virtual sequence numbers
        0..N-1 are reserved for the cursor so every equal-time tie-break
        (seeded vs requeued arrivals, scenario events) orders exactly as
        the seeded-heap reference core."""
        self._services = services
        self.loop._seq = len(services)

    def _cursor_arrival(self, t: float, req: ServiceRequest) -> None:
        """Inlined single-request `on_arrival` (same semantics as
        `Runtime.on_arrival` + `drive_slot` for a 1-tuple; `drain` has
        already advanced the clock)."""
        view = self.build_view(t)
        d = self.policy.assign(req, view)
        if d.admit:
            view.apply(req, d)
            if d.preempt_victim is None and d.defer_until <= t:
                if self.trace is not None and req.preemptions:
                    self._trace_decision(t, req, d)
                self.dispatch(t, req, d)
                return
        self.place(t, req, d)

    def drain(self, max_events: int = 10_000_000) -> None:
        """Merge the arrival cursor with the event heap; stop when only
        housekeeping (BandwidthChange) events remain."""
        services = self._services if self._services is not None else []
        n = len(services)
        i = 0
        clock = self.clock
        loop = self.loop
        heap = loop._heap
        pop = heapq.heappop
        cursor_arrival = self._cursor_arrival
        tx_done = self._tx_done
        infer_done = self._infer_done
        handled = 0
        while handled < max_events:
            handled += 1
            if i < n:
                r = services[i]
                ta = r.arrival
                if heap:
                    h0 = heap[0]
                    t0 = h0[0]
                    take_heap = t0 < ta or (
                        t0 == ta and (h0[1] < 5 or (h0[1] == 5
                                                    and h0[2] < i)))
                else:
                    take_heap = False
                if not take_heap:
                    i += 1
                    if ta > clock:
                        clock = ta
                        self.clock = ta
                    cursor_arrival(ta, r)
                    continue
            elif not heap or loop.n_work == 0:
                return
            item = pop(heap)
            ev = item[3]
            t = item[0]
            if t > clock:
                clock = t
                self.clock = t
            cls = ev.__class__
            if cls is _Booking:
                loop.n_work -= 1
                if item[1] == 3:
                    tx_done(ev)
                else:
                    infer_done(ev, t)
            elif cls is BandwidthChange:
                self.on_bandwidth_change(ev)
            else:
                loop.n_work -= 1
                self.handle(ev)
        raise RuntimeError(f"runtime did not drain in {max_events} events")


# ---------------------------------------------------------------------------
# Simulator — seeds the event streams and aggregates results
# ---------------------------------------------------------------------------


class Simulator:
    """Event-driven edge-cloud simulator. `bw_interval` is the
    fluctuating bandwidth model's resample cadence (and the pseudo-slot
    length of `Runtime.slot_index`).

    `slot` is retired: the simulator always runs event-driven. The
    parameter is kept so legacy call sites fail with a clear message —
    any numeric value raises, `slot=None` is accepted and ignored.

    `topology` is the network (`repro.cluster.network.LinkTopology`);
    `None` builds the degenerate one-link-per-server topology around
    `bandwidth`, which reproduces the legacy per-server model bit-exactly
    (the frozen golden tests pin this)."""

    def __init__(self, specs: Sequence[ServerSpec],
                 bandwidth: Optional[BandwidthModel] = None,
                 slot: None = None, seed: int = 0,
                 bw_interval: float = 0.5,
                 topology: Optional[LinkTopology] = None,
                 core: str = "array"):
        if slot is not None:
            raise ValueError(
                f"slotted mode was removed: Simulator always runs "
                f"event-driven now, so slot={slot!r} has no "
                f"implementation. Drop the slot= argument (slot=None is "
                f"accepted for compatibility); quantized-slot goldens "
                f"were migrated to event-mode goldens.")
        if core not in ("array", "reference"):
            raise ValueError(f"core must be 'array' or 'reference', "
                             f"got {core!r}")
        self.core = core
        self.specs = list(specs)
        self.bandwidth = bandwidth or BandwidthModel()
        self.topology = topology \
            or LinkTopology.degenerate(self.specs, self.bandwidth)
        if self.topology.n_servers != len(self.specs):
            raise ValueError(
                f"topology routes {self.topology.n_servers} servers but the "
                f"testbed has {len(self.specs)}")
        self.slot = slot
        self.bw_interval = bw_interval
        rng = np.random.default_rng(seed)
        # hidden per-(service-class, server) efficiency (unknown to
        # schedulers): the paper's "diversity of task requirements" — e.g.
        # long-context classes stress small-RAM edges, chatty classes hit
        # cloud batching pathologies. Only per-class learners can adapt.
        from repro.cluster.workload import N_CLASSES
        self.efficiency = rng.uniform(0.7, 1.0, (N_CLASSES, len(specs)))
        self.noise_rng = np.random.default_rng(seed + 1)
        self._noise_buf: List[float] = []
        self._noise_i = 0

    def run(self, services: List[ServiceRequest], scheduler,
            scenario: Union[Scenario, str, None] = None,
            trace=None) -> SimResult:
        """Simulate `services` under `scheduler` (a `SchedulingPolicy`).
        `scenario` (instance or registered name) may inject extra
        bandwidth events; arrival shaping happens in the workload
        generator. `trace` (a `repro.obs.TraceRecorder`) records every
        request's lifecycle spans; the default None keeps the hot path
        untouched, and a traced run is result-bit-identical to an
        untraced one (golden-tested)."""
        policy = ensure_policy(scheduler)
        if isinstance(scenario, str):
            scenario = make_scenario(scenario)

        services = sorted(services, key=lambda r: r.arrival)
        for r in services:
            r.class_id = classify(r)
            r.finish = -1.0
            r.server = -1
            r.preemptions = 0
            # repro-check: orphan(kv_used) — pre-run reset of the claim
            # record; no pages are charged before the first dispatch
            r.kv_server = -1
            r.kv_blocks = 0
        if not services:
            return SimResult.empty(policy.name, len(self.specs))

        if self.core == "reference":
            from repro.cluster.reference_sim import _ReferenceEventRuntime
            rt: _SimRuntimeBase = _ReferenceEventRuntime(self, policy,
                                                         trace=trace)
            for r in services:
                rt.loop.push(Arrival(r.arrival, requests=(r,)))
        else:
            rt = _EventSimRuntime(self, policy, trace=trace)
            rt.seed_arrivals(services)
        if scenario is not None:
            horizon = services[-1].arrival
            for ev in scenario.bandwidth_events(horizon, len(self.specs)):
                rt.loop.push(ev)
        rt.drain()
        return self._aggregate(policy.name, services, rt)

    def _aggregate(self, name: str, services: List[ServiceRequest],
                   rt: _SimRuntimeBase) -> SimResult:
        outcomes, states = rt.outcomes, rt.states
        completed = [o for o in outcomes if not o.rejected]
        if not completed:
            res = SimResult.empty(name, len(self.specs))
            res.n_services = len(services)
            res.n_rejected = rt.n_rejected
            res.n_preempted = rt.n_preempted
            res.n_kv_evictions = rt.n_kv_evictions
            res.kv_prefill_tokens_saved = rt.kv_prefill_tokens_saved
            res.n_prefix_hits = rt.n_prefix_hits
            res.n_kv_orphaned = rt.n_kv_orphaned
            res.n_kv_migrations = rt.n_kv_migrations
            res.kv_migrated_bytes = rt.kv_migrated_bytes
            res.metrics = self._finalize_metrics(res, rt, [])
            return res
        makespan = max(o.finish for o in completed)
        for st in states:
            st.finalize_idle(makespan)

        # success counts every service (a rejection is an SLO miss);
        # processing-time stats describe the admitted ones
        times = np.array([o.processing_time for o in completed])
        succ = np.array([o.success for o in outcomes])
        adm_succ = np.array([o.success for o in completed])
        tokens = sum(r.prompt_tokens + r.output_tokens for r in services
                     if r.finish >= 0)
        res = SimResult(
            name=name,
            n_services=len(services),
            success_rate=float(np.mean(succ)),
            avg_processing_time=float(np.mean(times)),
            p95_processing_time=float(np.percentile(times, 95)),
            throughput_tokens_per_s=tokens / makespan,
            makespan=float(makespan),
            e_tx=sum(st.e_tx for st in states),
            e_infer=sum(st.e_infer for st in states),
            e_idle=sum(st.e_idle for st in states),
            per_server_served=[st.served for st in states],
            n_rejected=rt.n_rejected,
            n_preempted=rt.n_preempted,
            admitted_success_rate=float(np.mean(adm_succ)),
            n_kv_evictions=rt.n_kv_evictions,
            kv_prefill_tokens_saved=rt.kv_prefill_tokens_saved,
            n_prefix_hits=rt.n_prefix_hits,
            n_kv_orphaned=rt.n_kv_orphaned,
            n_kv_migrations=rt.n_kv_migrations,
            kv_migrated_bytes=rt.kv_migrated_bytes,
            served_tokens=tokens,
        )
        res.metrics = self._finalize_metrics(res, rt, times)
        return res

    @staticmethod
    def _finalize_metrics(res: SimResult, rt: _SimRuntimeBase, times):
        """Fold the run-level aggregates into the runtime's live
        registry (the hot-path counters are already in it via
        `counter_attr`), producing the registry `SimResult.metrics`
        exposes. Labeled per-server counters and the processing-time
        histogram are derived here, once per run, off the hot path."""
        m = rt.metrics
        m.put_scalar("n_served", sum(res.per_server_served))
        m.put_scalar("served_tokens", res.served_tokens)
        for j, served in enumerate(res.per_server_served):
            m.inc("per_server_served", served, server=j)
        m.set_gauge("success_rate", res.success_rate)
        m.set_gauge("admitted_success_rate", res.admitted_success_rate)
        m.set_gauge("avg_processing_time", res.avg_processing_time)
        m.set_gauge("p95_processing_time", res.p95_processing_time)
        m.set_gauge("throughput_tokens_per_s",
                    res.throughput_tokens_per_s)
        m.set_gauge("makespan", res.makespan)
        m.set_gauge("e_tx", res.e_tx)
        m.set_gauge("e_infer", res.e_infer)
        m.set_gauge("e_idle", res.e_idle)
        m.register_histogram("processing_time_s",
                             (0.5, 1.0, 2.0, 4.0, 8.0, 16.0))
        if len(times):
            m.observe_many("processing_time_s", times)
        return m

    # ------------------------------------------------------------------
    # Shared physics: both cores realize requests with exactly these
    # draws/formulas, so array-vs-reference comparisons measure the
    # *scheduling* semantics, never drifting cost models.
    # ------------------------------------------------------------------
    def _draw_infer(self, req: ServiceRequest, j: int,
                    resume: bool = False,
                    alloc: Optional[Allocation] = None,
                    prefix_tokens: int = 0) -> float:
        """Realized inference time: nominal / hidden efficiency × noise.
        Consumes one noise draw — call once per realized request.
        `resume` drops the prefill term: the request's KV pages survived
        its eviction on this server, so only the remaining decode runs.
        `prefix_tokens` drops just that many prompt tokens from the
        prefill term — the server already holds their KV as a shared
        prefix. `alloc` stretches the window by 1/(freq × lane_share) —
        the DVFS tier slows the clock, a sub-unit lane share slices the
        lane."""
        # draws are buffered: one bulk `lognormal(size=4096)` consumes the
        # same RNG stream as 4096 sequential scalar draws (verified
        # bit-identical, including across refills), at a fraction of the
        # per-call overhead. The buffer lives on the Simulator, so draw
        # sequences across multiple `run` calls also match the scalar path.
        i = self._noise_i
        buf = self._noise_buf
        if i >= len(buf):
            buf = self._noise_buf = \
                self.noise_rng.lognormal(0.0, 0.08, 4096).tolist()
            i = 0
        noise = buf[i]
        self._noise_i = i + 1
        nominal = (self.specs[j].decode_time(req.output_tokens) if resume
                   else self.specs[j].service_time(
                       req.prompt_tokens - prefix_tokens,
                       req.output_tokens))
        t_inf = (nominal / self.efficiency[req.class_id, j]) * noise
        if alloc is not None:
            t_inf /= alloc.freq(self.specs[j]) * alloc.lane_share
        return t_inf
