"""Discrete-event simulator for edge-cloud LLM serving.

Faithful to the paper's evaluation protocol (§4): services arrive in real
time, are scheduled to a server, upload over that server's (shared, possibly
fluctuating) uplink, then occupy a batch lane for prefill+decode. Processing
time = transmission + queue + inference; energy = transmission + inference +
idle (idle accrues over the run's makespan).

Both execution modes run on the shared event-driven `Runtime` / `EventLoop`
from `repro.core.runtime`:

* **Slotted-compat mode** (default, `slot=0.5`): arrivals are quantized —
  each non-empty slot becomes one batched `Arrival` event at the slot
  boundary, scheduled against a slot-start `ClusterView` and realized
  synchronously (feedback at decision time). This reproduces the PR 1
  slotted simulator bit-for-bit (see the golden tests).
* **Event-driven mode** (`slot=None`): every service is its own `Arrival`
  at its true timestamp, observed against a *fresh* view of live uplink/
  lane state; transmission and completion unfold as `TxDone`/`InferDone`
  events and the policy's `feedback` fires at the request's actual
  completion time. Bandwidth fluctuation is a periodic `BandwidthChange`
  resample stream.

Scenario hooks (`repro.core.runtime.Scenario`) inject extra event streams —
bursty/diurnal/trace arrivals shape the workload (see
`workload.generate_workload`), and mid-run bandwidth drops arrive as
`BandwidthChange` scale overlays honored by both modes.

Servers have *hidden* efficiency factors and per-request noise — schedulers
only observe realized outcomes, which is what makes the bandit formulation
meaningful (and is how the real testbed behaves).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.cluster.network import BandwidthModel
from repro.cluster.server import ServerSpec, ServerState
from repro.cluster.workload import ServiceRequest, classify
from repro.core.api import (
    ClusterView, Decision, SchedulerBase, as_policy, drive_slot,
)
from repro.core.runtime import (
    Arrival, BandwidthChange, InferDone, Runtime, Scenario, TxDone,
    make_scenario,
)

# Deprecated alias: the per-slot observation object is now the shared
# `ClusterView` (also built by the live `PerLLMServer`).
SlotView = ClusterView


@dataclasses.dataclass
class Outcome:
    server: int
    tx_time: float
    queue_time: float
    infer_time: float
    finish: float
    processing_time: float
    success: bool
    energy: float               # incremental (tx + active-infer) energy


@dataclasses.dataclass
class SimResult:
    name: str
    n_services: int
    success_rate: float
    avg_processing_time: float
    p95_processing_time: float
    throughput_tokens_per_s: float
    makespan: float
    e_tx: float
    e_infer: float
    e_idle: float
    per_server_served: List[int]

    @property
    def total_energy(self) -> float:
        return self.e_tx + self.e_infer + self.e_idle

    @classmethod
    def empty(cls, name: str, n_servers: int) -> "SimResult":
        """Zeroed result for a run that produced no outcomes."""
        return cls(name=name, n_services=0, success_rate=0.0,
                   avg_processing_time=0.0, p95_processing_time=0.0,
                   throughput_tokens_per_s=0.0, makespan=0.0,
                   e_tx=0.0, e_infer=0.0, e_idle=0.0,
                   per_server_served=[0] * n_servers)

    def row(self) -> str:
        return (f"{self.name:22s} succ={self.success_rate*100:5.1f}% "
                f"time={self.avg_processing_time:6.2f}s "
                f"thpt={self.throughput_tokens_per_s:8.1f} tok/s "
                f"energy={self.total_energy/1e3:8.1f} kJ "
                f"(tx={self.e_tx/1e3:.1f} inf={self.e_infer/1e3:.1f} "
                f"idle={self.e_idle/1e3:.1f})")


# ---------------------------------------------------------------------------
# Runtimes — simulator physics behind the shared event loop
# ---------------------------------------------------------------------------


class _SimRuntimeBase(Runtime):
    """Shared state for both simulator modes: server bookkeeping, the lane
    ledger, the bandwidth model plus scenario scale overlay."""

    def __init__(self, sim: "Simulator", policy) -> None:
        super().__init__(policy)
        self.sim = sim
        self.specs = sim.specs
        self.states = [ServerState(spec=s) for s in self.specs]
        self.lane_free = [[0.0] * s.max_concurrency for s in self.specs]
        self.bw_scale = [1.0] * len(self.specs)
        self.outcomes: List[Outcome] = []

    def on_bandwidth_change(self, ev: BandwidthChange) -> None:
        if ev.scale:
            for j, s in ev.scale.items():
                self.bw_scale[j] = s


class _SlottedSimRuntime(_SimRuntimeBase):
    """Legacy quantized-slot semantics as events.

    Each non-empty slot is one batched Arrival at the slot boundary; the
    whole slot is assigned against the slot-start view and realized
    synchronously, so feedback reaches the learner at decision time —
    exactly the PR 1 slotted loop, bit-for-bit when no scenario overlay is
    active.
    """

    def on_arrival(self, ev: Arrival) -> None:
        ts = ev.slot_index
        sim = self.sim
        factors = [sim.bandwidth.factor(ts, j) * self.bw_scale[j]
                   for j in range(len(self.specs))]
        view = ClusterView(
            t=ev.time, specs=self.specs, bw_factor=list(factors),
            uplink_free_at=[st.uplink_free_at for st in self.states],
            lane_free=[list(lf) for lf in self.lane_free],
        )
        decisions = drive_slot(self.policy, ev.requests, view, ts)
        for req, d in zip(ev.requests, decisions):
            out = sim._realize(req, d, self.states, self.lane_free, factors)
            self.outcomes.append(out)
            self.policy.feedback(req, out)


class _EventSimRuntime(_SimRuntimeBase):
    """Pure event-driven semantics.

    Every arrival observes a fresh view of the cluster at its actual
    timestamp; physics are resolved at dispatch (uplink and lane booked
    immediately, so later arrivals see the consumed capacity) while the
    timeline unfolds as TxDone → InferStart → InferDone events, with energy
    accounting and policy feedback at the times things actually happen.
    """

    def __init__(self, sim: "Simulator", policy) -> None:
        super().__init__(sim, policy)
        self._model_factors = [1.0] * len(self.specs)
        if sim.bandwidth.fluctuating:
            self._resample_factors(0.0)

    # ---------------- bandwidth as an event stream -----------------------
    def _resample_factors(self, t: float) -> None:
        k = int(round(t / self.sim.bw_interval))
        self._model_factors = self.sim.bandwidth.factors(k, len(self.specs))
        self.loop.push(BandwidthChange(t + self.sim.bw_interval,
                                       resample=True))

    def on_bandwidth_change(self, ev: BandwidthChange) -> None:
        super().on_bandwidth_change(ev)
        if ev.resample:
            self._resample_factors(ev.time)

    def _factor(self, j: int) -> float:
        return self._model_factors[j] * self.bw_scale[j]

    # ---------------- the Runtime contract -------------------------------
    def slot_index(self, t: float) -> int:
        return int(t / self.sim.bw_interval)

    def build_view(self, t: float) -> ClusterView:
        return ClusterView(
            t=t, specs=self.specs,
            bw_factor=[self._factor(j) for j in range(len(self.specs))],
            uplink_free_at=[st.uplink_free_at for st in self.states],
            lane_free=[list(lf) for lf in self.lane_free],
        )

    def dispatch(self, t: float, req: ServiceRequest,
                 decision: Decision) -> None:
        j = decision.server
        spec = self.specs[j]
        st = self.states[j]
        tx_start = max(t, st.uplink_free_at)
        tx_dur = spec.tx_time(req.payload_bytes, self._factor(j))
        st.uplink_free_at = tx_start + tx_dur
        ready = tx_start + tx_dur
        # the lane is booked at dispatch — the routed request is committed
        # capacity, visible to every later arrival's fresh view — while the
        # events below mark when its phases actually happen
        lanes = self.lane_free[j]
        li = int(np.argmin(lanes))
        begin = max(ready, lanes[li])
        t_inf = self.sim._draw_infer(req, j)
        finish = begin + t_inf
        lanes[li] = finish
        ctx = (j, tx_dur, ready, begin, t_inf)
        self.loop.push(TxDone(ready, request=req, decision=decision,
                              context=ctx))
        self.loop.push(InferDone(finish, request=req, context=ctx))

    def on_tx_done(self, ev: TxDone) -> None:
        j, tx_dur, ready, _begin, _t_inf = ev.context
        st = self.states[j]
        # transmission energy accrues over the whole transfer window,
        # including the congestion queue (paper §2.3)
        st.e_tx += (ready - ev.request.arrival) * self.specs[j].tx_power
        st.tx_busy_time += tx_dur

    def on_infer_done(self, ev: InferDone) -> None:
        j, tx_dur, ready, begin, t_inf = ev.context
        req = ev.request
        spec = self.specs[j]
        st = self.states[j]
        finish = ev.time
        st.busy_time += t_inf / spec.max_concurrency
        st.e_infer += spec.infer_energy(t_inf)
        st.tokens_out += req.output_tokens
        st.served += 1
        req.finish = finish
        req.server = j
        proc = finish - req.arrival
        out = Outcome(
            server=j, tx_time=(ready - req.arrival),
            queue_time=max(begin - ready, 0.0), infer_time=t_inf,
            finish=finish, processing_time=proc,
            success=proc <= req.deadline,
            energy=tx_dur * spec.tx_power + spec.infer_energy(t_inf))
        self.outcomes.append(out)
        self.policy.feedback(req, out)


# ---------------------------------------------------------------------------
# Simulator — seeds the event streams and aggregates results
# ---------------------------------------------------------------------------


class Simulator:
    """`slot=0.5` (default) runs the slotted-compat mode; `slot=None` runs
    pure event-driven scheduling. `bw_interval` is the fluctuating
    bandwidth model's resample cadence in event mode (and the pseudo-slot
    length reported to legacy batch schedulers)."""

    def __init__(self, specs: Sequence[ServerSpec],
                 bandwidth: Optional[BandwidthModel] = None,
                 slot: Optional[float] = 0.5, seed: int = 0,
                 bw_interval: float = 0.5):
        self.specs = list(specs)
        self.bandwidth = bandwidth or BandwidthModel()
        self.slot = slot
        self.bw_interval = bw_interval
        rng = np.random.default_rng(seed)
        # hidden per-(service-class, server) efficiency (unknown to
        # schedulers): the paper's "diversity of task requirements" — e.g.
        # long-context classes stress small-RAM edges, chatty classes hit
        # cloud batching pathologies. Only per-class learners can adapt.
        from repro.cluster.workload import N_CLASSES
        self.efficiency = rng.uniform(0.7, 1.0, (N_CLASSES, len(specs)))
        self.noise_rng = np.random.default_rng(seed + 1)

    def run(self, services: List[ServiceRequest], scheduler,
            scenario: Union[Scenario, str, None] = None) -> SimResult:
        """Simulate `services` under `scheduler` (a `SchedulingPolicy`, or a
        legacy `SchedulerBase` — coerced through the deprecation shim).
        `scenario` (instance or registered name) may inject extra
        bandwidth events; arrival shaping happens in the workload
        generator."""
        policy = as_policy(scheduler)
        if isinstance(scenario, str):
            scenario = make_scenario(scenario)

        services = sorted(services, key=lambda r: r.arrival)
        for r in services:
            r.class_id = classify(r)
            r.finish = -1.0
            r.server = -1
        if not services:
            return SimResult.empty(policy.name, len(self.specs))

        if self.slot is not None:
            rt: _SimRuntimeBase = _SlottedSimRuntime(self, policy)
            self._seed_slotted(rt, services)
        else:
            rt = _EventSimRuntime(self, policy)
            for r in services:
                rt.loop.push(Arrival(r.arrival, requests=(r,)))
        if scenario is not None:
            horizon = services[-1].arrival
            for ev in scenario.bandwidth_events(horizon, len(self.specs)):
                rt.loop.push(ev)
        rt.drain()
        return self._aggregate(policy.name, services, rt)

    def _seed_slotted(self, rt: _SimRuntimeBase,
                      services: List[ServiceRequest]) -> None:
        """Quantized arrivals: one batched Arrival event per non-empty
        slot, grouped by the same boundary scan as the PR 1 slot loop (so
        float-boundary membership is bit-identical)."""
        idx = 0
        ts = 0
        while idx < len(services):
            t0 = ts * self.slot
            t1 = t0 + self.slot
            batch = []
            while idx < len(services) and services[idx].arrival < t1:
                batch.append(services[idx])
                idx += 1
            if batch:
                rt.loop.push(Arrival(t0, requests=tuple(batch),
                                     slot_index=ts))
            ts += 1

    def _aggregate(self, name: str, services: List[ServiceRequest],
                   rt: _SimRuntimeBase) -> SimResult:
        outcomes, states = rt.outcomes, rt.states
        if not outcomes:
            return SimResult.empty(name, len(self.specs))
        makespan = max(o.finish for o in outcomes)
        for st in states:
            st.finalize_idle(makespan)

        times = np.array([o.processing_time for o in outcomes])
        succ = np.array([o.success for o in outcomes])
        tokens = sum(r.prompt_tokens + r.output_tokens for r in services)
        return SimResult(
            name=name,
            n_services=len(services),
            success_rate=float(np.mean(succ)),
            avg_processing_time=float(np.mean(times)),
            p95_processing_time=float(np.percentile(times, 95)),
            throughput_tokens_per_s=tokens / makespan,
            makespan=float(makespan),
            e_tx=sum(st.e_tx for st in states),
            e_infer=sum(st.e_infer for st in states),
            e_idle=sum(st.e_idle for st in states),
            per_server_served=[st.served for st in states],
        )

    # ------------------------------------------------------------------
    # Shared physics: both execution modes realize requests with exactly
    # these draws/formulas, so slot-vs-event comparisons measure the
    # *scheduling* semantics, never drifting cost models.
    # ------------------------------------------------------------------
    def _draw_infer(self, req: ServiceRequest, j: int) -> float:
        """Realized inference time: nominal / hidden efficiency × noise.
        Consumes one noise draw — call once per realized request."""
        noise = float(self.noise_rng.lognormal(0.0, 0.08))
        return (self.specs[j].service_time(req.prompt_tokens,
                                           req.output_tokens)
                / self.efficiency[req.class_id, j]) * noise

    def _realize(self, req: ServiceRequest, decision: Decision,
                 states: List[ServerState], lane_free: List[List[float]],
                 factors: List[float]) -> Outcome:
        j = decision.server
        spec = self.specs[j]
        st = states[j]
        # upload over the shared FIFO uplink; the runtime applies the
        # Decision's dispatch deferral (e.g. FineInfer's batching windows)
        dispatch = max(req.arrival, decision.defer_until)
        tx_start = max(dispatch, st.uplink_free_at)
        tx_dur = spec.tx_time(req.payload_bytes, factors[j])
        st.uplink_free_at = tx_start + tx_dur
        ready = tx_start + tx_dur
        # transmission energy accrues over the whole transfer window,
        # including the congestion queue — "network congestion causes cloud
        # servers to incur unnecessary energy costs" (paper §2.3)
        st.e_tx += (ready - req.arrival) * spec.tx_power
        st.tx_busy_time += tx_dur

        # batch lane with hidden efficiency + noise
        lanes = lane_free[j]
        li = int(np.argmin(lanes))
        begin = max(ready, lanes[li])
        t_inf = self._draw_infer(req, j)
        finish = begin + t_inf
        lanes[li] = finish
        st.busy_time += t_inf / spec.max_concurrency
        st.e_infer += spec.infer_energy(t_inf)
        st.tokens_out += req.output_tokens
        st.served += 1

        req.finish = finish
        req.server = j
        proc = finish - req.arrival
        return Outcome(
            server=j, tx_time=(ready - req.arrival), queue_time=max(
                begin - ready, 0.0), infer_time=t_inf, finish=finish,
            processing_time=proc, success=proc <= req.deadline,
            energy=tx_dur * spec.tx_power + spec.infer_energy(t_inf))
