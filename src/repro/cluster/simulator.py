"""Time-slotted discrete-event simulator for edge-cloud LLM serving.

Faithful to the paper's evaluation protocol (§4): services arrive in real
time, are scheduled to a server, upload over that server's (shared, possibly
fluctuating) uplink, then occupy a batch lane for prefill+decode. Processing
time = transmission + queue + inference; energy = transmission + inference +
idle (idle accrues over the run's makespan).

Scheduling goes through the unified `SchedulingPolicy` API
(`repro.core.api`): per slot the simulator builds a `ClusterView` from real
uplink/lane/bandwidth state, `drive_slot` collects one `Decision` per
arrival (committing residuals between requests), and realized `Outcome`s
feed back to the policy. Legacy `SchedulerBase` subclasses still run via
the `as_policy` shim.

Servers have *hidden* efficiency factors and per-request noise — schedulers
only observe realized outcomes, which is what makes the bandit formulation
meaningful (and is how the real testbed behaves).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from repro.cluster.network import BandwidthModel
from repro.cluster.server import ServerSpec, ServerState
from repro.cluster.workload import ServiceRequest, classify
from repro.core.api import (
    ClusterView, Decision, SchedulerBase, as_policy, drive_slot,
)

# Deprecated alias: the per-slot observation object is now the shared
# `ClusterView` (also built by the live `PerLLMServer`).
SlotView = ClusterView


@dataclasses.dataclass
class Outcome:
    server: int
    tx_time: float
    queue_time: float
    infer_time: float
    finish: float
    processing_time: float
    success: bool
    energy: float               # incremental (tx + active-infer) energy


@dataclasses.dataclass
class SimResult:
    name: str
    n_services: int
    success_rate: float
    avg_processing_time: float
    p95_processing_time: float
    throughput_tokens_per_s: float
    makespan: float
    e_tx: float
    e_infer: float
    e_idle: float
    per_server_served: List[int]

    @property
    def total_energy(self) -> float:
        return self.e_tx + self.e_infer + self.e_idle

    @classmethod
    def empty(cls, name: str, n_servers: int) -> "SimResult":
        """Zeroed result for a run that produced no outcomes."""
        return cls(name=name, n_services=0, success_rate=0.0,
                   avg_processing_time=0.0, p95_processing_time=0.0,
                   throughput_tokens_per_s=0.0, makespan=0.0,
                   e_tx=0.0, e_infer=0.0, e_idle=0.0,
                   per_server_served=[0] * n_servers)

    def row(self) -> str:
        return (f"{self.name:22s} succ={self.success_rate*100:5.1f}% "
                f"time={self.avg_processing_time:6.2f}s "
                f"thpt={self.throughput_tokens_per_s:8.1f} tok/s "
                f"energy={self.total_energy/1e3:8.1f} kJ "
                f"(tx={self.e_tx/1e3:.1f} inf={self.e_infer/1e3:.1f} "
                f"idle={self.e_idle/1e3:.1f})")


class Simulator:
    def __init__(self, specs: Sequence[ServerSpec],
                 bandwidth: Optional[BandwidthModel] = None,
                 slot: float = 0.5, seed: int = 0):
        self.specs = list(specs)
        self.bandwidth = bandwidth or BandwidthModel()
        self.slot = slot
        rng = np.random.default_rng(seed)
        # hidden per-(service-class, server) efficiency (unknown to
        # schedulers): the paper's "diversity of task requirements" — e.g.
        # long-context classes stress small-RAM edges, chatty classes hit
        # cloud batching pathologies. Only per-class learners can adapt.
        from repro.cluster.workload import N_CLASSES
        self.efficiency = rng.uniform(0.7, 1.0, (N_CLASSES, len(specs)))
        self.noise_rng = np.random.default_rng(seed + 1)

    def run(self, services: List[ServiceRequest], scheduler) -> SimResult:
        """Simulate `services` under `scheduler` (a `SchedulingPolicy`, or a
        legacy `SchedulerBase` — coerced through the deprecation shim)."""
        policy = as_policy(scheduler)
        specs = self.specs
        states = [ServerState(spec=s) for s in specs]
        lane_free = [[0.0] * s.max_concurrency for s in specs]
        outcomes: List[Outcome] = []

        services = sorted(services, key=lambda r: r.arrival)
        for r in services:
            r.class_id = classify(r)
            r.finish = -1.0
            r.server = -1
        if not services:
            return SimResult.empty(policy.name, len(specs))
        horizon_slots = int(math.ceil(services[-1].arrival / self.slot)) + 1

        idx = 0
        for ts in range(horizon_slots):
            t0 = ts * self.slot
            t1 = t0 + self.slot
            arrivals = []
            while idx < len(services) and services[idx].arrival < t1:
                arrivals.append(services[idx])
                idx += 1
            if not arrivals:
                continue
            factors = [self.bandwidth.factor(ts, j)
                       for j in range(len(specs))]
            view = ClusterView(
                t=t0, specs=specs, bw_factor=list(factors),
                uplink_free_at=[st.uplink_free_at for st in states],
                lane_free=[list(lf) for lf in lane_free],
            )
            decisions = drive_slot(policy, arrivals, view, ts)
            for req, d in zip(arrivals, decisions):
                out = self._realize(req, d, states, lane_free, factors)
                outcomes.append(out)
                policy.feedback(req, out)

        if not outcomes:
            return SimResult.empty(policy.name, len(specs))
        makespan = max(o.finish for o in outcomes)
        for st in states:
            st.finalize_idle(makespan)

        times = np.array([o.processing_time for o in outcomes])
        succ = np.array([o.success for o in outcomes])
        tokens = sum(r.prompt_tokens + r.output_tokens for r in services)
        return SimResult(
            name=policy.name,
            n_services=len(services),
            success_rate=float(np.mean(succ)),
            avg_processing_time=float(np.mean(times)),
            p95_processing_time=float(np.percentile(times, 95)),
            throughput_tokens_per_s=tokens / makespan,
            makespan=float(makespan),
            e_tx=sum(st.e_tx for st in states),
            e_infer=sum(st.e_infer for st in states),
            e_idle=sum(st.e_idle for st in states),
            per_server_served=[st.served for st in states],
        )

    # ------------------------------------------------------------------
    def _realize(self, req: ServiceRequest, decision: Decision,
                 states: List[ServerState], lane_free: List[List[float]],
                 factors: List[float]) -> Outcome:
        j = decision.server
        spec = self.specs[j]
        st = states[j]
        # upload over the shared FIFO uplink; the runtime applies the
        # Decision's dispatch deferral (e.g. FineInfer's batching windows)
        dispatch = max(req.arrival, decision.defer_until)
        tx_start = max(dispatch, st.uplink_free_at)
        tx_dur = req.payload_bytes * 8.0 / (spec.bandwidth * factors[j])
        st.uplink_free_at = tx_start + tx_dur
        ready = tx_start + tx_dur
        # transmission energy accrues over the whole transfer window,
        # including the congestion queue — "network congestion causes cloud
        # servers to incur unnecessary energy costs" (paper §2.3)
        st.e_tx += (ready - req.arrival) * spec.tx_power
        st.tx_busy_time += tx_dur

        # batch lane with hidden efficiency + noise
        lanes = lane_free[j]
        li = int(np.argmin(lanes))
        begin = max(ready, lanes[li])
        noise = float(self.noise_rng.lognormal(0.0, 0.08))
        t_inf = (spec.service_time(req.prompt_tokens, req.output_tokens)
                 / self.efficiency[req.class_id, j]) * noise
        finish = begin + t_inf
        lanes[li] = finish
        st.busy_time += t_inf / spec.max_concurrency
        st.e_infer += ((spec.power_active - spec.power_idle)
                       / spec.max_concurrency) * t_inf
        st.tokens_out += req.output_tokens
        st.served += 1

        req.finish = finish
        req.server = j
        proc = finish - req.arrival
        return Outcome(
            server=j, tx_time=(ready - req.arrival), queue_time=max(
                begin - ready, 0.0), infer_time=t_inf, finish=finish,
            processing_time=proc, success=proc <= req.deadline,
            energy=tx_dur * spec.tx_power
            + ((spec.power_active - spec.power_idle)
               / spec.max_concurrency) * t_inf)
