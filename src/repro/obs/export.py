"""Trace exporters: Chrome/Perfetto ``trace_event`` JSON and CSV.

Perfetto layout (load the JSON at https://ui.perfetto.dev):

* one *process* per server (``pid = server index``), with tracks
  (threads) ``uplink`` (TX / KV_WAIT spans), one per compute lane
  (INFER / QUEUE spans; the lane index rides in the ``aux`` column),
  and ``events`` for instant markers;
* one process per link label used by KV migrations
  (``pid = _LINK_PID_BASE + interned label id``);
* a ``csucb`` process for bandit arm pulls;
* one flow (``ph: s``/``f``, ``id = sid``) per request from its TX span
  to its INFER span, so Perfetto draws the arrival→inference arrow even
  when the phases land on different tracks.

Timestamps are microseconds (``ts``/``dur``), per the trace_event spec.
"""
from __future__ import annotations

import csv
import json
from typing import Dict, List

from .trace import (
    KIND_ARM, KIND_INFER, KIND_KV_WAIT, KIND_MIGRATE, KIND_NAMES,
    KIND_QUEUE, KIND_TX, SPAN_KINDS, TraceRecorder,
)

_LINK_PID_BASE = 10_000
_CSUCB_PID = 20_000
_TID_UPLINK = 1
_TID_EVENTS = 0
_TID_LANE_BASE = 2


def perfetto_events(rec: TraceRecorder) -> List[dict]:
    """Build the ``traceEvents`` list from a recorder."""
    cols = rec.to_arrays()
    n = len(cols["kind"])
    events: List[dict] = []

    # metadata: name every process we are about to emit into
    servers = sorted({int(s) for s in cols["server"] if s >= 0})
    for j in servers:
        events.append({"ph": "M", "name": "process_name", "pid": j,
                       "tid": 0, "ts": 0,
                       "args": {"name": f"server {j}"}})
    mig_labels = sorted({int(a) for k, a in zip(cols["kind"], cols["aux"])
                         if k == KIND_MIGRATE and a >= 0})
    for lid in mig_labels:
        events.append({"ph": "M", "name": "process_name",
                       "pid": _LINK_PID_BASE + lid, "tid": 0, "ts": 0,
                       "args": {"name":
                                f"link {rec.label(lid) or lid}"}})
    if (cols["kind"] == KIND_ARM).any():
        events.append({"ph": "M", "name": "process_name",
                       "pid": _CSUCB_PID, "tid": 0, "ts": 0,
                       "args": {"name": "csucb bandit"}})

    span_set = set(SPAN_KINDS)
    # per-request anchors for the flow arrows
    tx_anchor: Dict[int, tuple] = {}
    infer_anchor: Dict[int, tuple] = {}

    for i in range(n):
        kind = int(cols["kind"][i])
        sid = int(cols["sid"][i])
        t0 = float(cols["t0"][i])
        t1 = float(cols["t1"][i])
        server = int(cols["server"][i])
        aux = int(cols["aux"][i])
        args = {"sid": sid, "class": int(cols["class_id"][i]),
                "tier": int(cols["tier"][i]),
                "energy_j": float(cols["energy"][i]),
                "value": float(cols["value"][i])}
        name = KIND_NAMES[kind]
        ts = t0 * 1e6
        if kind == KIND_ARM:
            events.append({"ph": "i", "s": "t", "name": "arm_pull",
                           "cat": "bandit", "pid": _CSUCB_PID,
                           "tid": _TID_LANE_BASE + server, "ts": ts,
                           "args": args})
            continue
        if kind == KIND_MIGRATE:
            pid = _LINK_PID_BASE + aux if aux >= 0 else max(server, 0)
            events.append({"ph": "X", "name": name, "cat": "kv",
                           "pid": pid, "tid": _TID_EVENTS, "ts": ts,
                           "dur": max(t1 - t0, 0.0) * 1e6,
                           "args": args})
            continue
        pid = max(server, 0)
        if kind in span_set:
            if kind in (KIND_TX, KIND_KV_WAIT):
                tid = _TID_UPLINK
            else:  # QUEUE / INFER / PREEMPT ride the compute lane
                tid = _TID_LANE_BASE + aux if aux >= 0 else _TID_LANE_BASE
            events.append({"ph": "X", "name": name, "cat": "lifecycle",
                           "pid": pid, "tid": tid, "ts": ts,
                           "dur": max(t1 - t0, 0.0) * 1e6, "args": args})
            if kind == KIND_TX and sid not in tx_anchor:
                tx_anchor[sid] = (pid, _TID_UPLINK, ts)
            elif kind == KIND_INFER:
                infer_anchor[sid] = (pid, tid, ts)
        else:
            events.append({"ph": "i", "s": "t", "name": name,
                           "cat": "lifecycle", "pid": pid,
                           "tid": _TID_EVENTS, "ts": ts, "args": args})

    for sid, (pid, tid, ts) in tx_anchor.items():
        dst = infer_anchor.get(sid)
        if dst is None:
            continue
        events.append({"ph": "s", "id": sid, "name": "req",
                       "cat": "flow", "pid": pid, "tid": tid, "ts": ts})
        events.append({"ph": "f", "bp": "e", "id": sid, "name": "req",
                       "cat": "flow", "pid": dst[0], "tid": dst[1],
                       "ts": dst[2]})
    return events


def write_perfetto(rec: TraceRecorder, path: str) -> int:
    """Write Chrome/Perfetto trace JSON; returns the event count."""
    events = perfetto_events(rec)
    with open(path, "w") as fh:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, fh)
        fh.write("\n")
    return len(events)


def validate_perfetto(path: str) -> List[str]:
    """Schema check on a written trace; returns a list of problems
    (empty == valid). Checks the keys the trace_event spec requires:
    every event has ``ph``/``pid``/``ts``, duration events have
    ``dur``."""
    problems: List[str] = []
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable trace JSON: {e}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    for i, ev in enumerate(events):
        for key in ("ph", "pid", "ts"):
            if key not in ev:
                problems.append(f"event {i} missing {key!r}: {ev}")
                break
        if ev.get("ph") == "X" and "dur" not in ev:
            problems.append(f"complete event {i} missing 'dur'")
        if len(problems) >= 10:
            problems.append("... (truncated)")
            break
    return problems


def write_csv(rec: TraceRecorder, path: str) -> int:
    """Columnar CSV dump (one row per trace row); returns row count."""
    cols = rec.to_arrays()
    n = len(cols["kind"])
    with open(path, "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(["kind", "sid", "t0", "t1", "server", "class_id",
                    "tier", "energy", "value", "aux", "aux_label"])
        for i in range(n):
            aux = int(cols["aux"][i])
            w.writerow([
                KIND_NAMES[int(cols["kind"][i])], int(cols["sid"][i]),
                repr(float(cols["t0"][i])), repr(float(cols["t1"][i])),
                int(cols["server"][i]), int(cols["class_id"][i]),
                int(cols["tier"][i]), repr(float(cols["energy"][i])),
                repr(float(cols["value"][i])), aux,
                rec.label(aux) or "",
            ])
    return n
