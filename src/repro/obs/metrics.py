"""Metrics registry: counters, gauges, fixed-bucket histograms.

One canonical key namespace shared by the simulator (`SimResult`), the
live server (`PerLLMServer.stats`), and the serving engine
(`ServingEngine.stats()`). Keys are labeled by arbitrary string/int
dimensions (server, class, tier); an unlabeled key is the plain scalar
counter.

The sim runtimes keep their hot-path counters *in* the registry via
:func:`counter_attr` — a class-level property backed by a single
unlabeled registry slot, so existing ``self.n_rejected += 1`` call sites
work unchanged while `SimResult` / `stats()` read straight out of the
registry. The slot holds whatever Python number was assigned (int or
float), so floating-point accumulation order — and therefore
bit-identity with the pre-registry code — is preserved.

Deprecated key aliases: the pre-unification stats dictionaries used a
second naming convention (``served`` vs ``n_served``, ``prefix_hits`` vs
``n_prefix_hits``). :data:`DEPRECATED_ALIASES` maps old → canonical and
:func:`with_aliases` adds the old spellings back onto a canonical stats
dict for one release; new code should read only canonical keys.
"""
from __future__ import annotations

from bisect import bisect_right
from typing import Dict, Iterable, List, Tuple

import numpy as np

#: old key -> canonical key. The old spellings are served by
#: :func:`with_aliases` for one release and then removed.
DEPRECATED_ALIASES = {
    "served": "n_served",
    "rejected": "n_rejected",
    "preempted": "n_preempted",
    "kv_migrations": "n_kv_migrations",
    "prefix_hits": "n_prefix_hits",
    "prefix_tokens_reused": "kv_prefill_tokens_saved",
    "prefills": "n_prefills",
    "deadline_met": "admitted_success_rate",
    "mean_latency": "avg_processing_time",
    "per_server": "per_server_served",
}


def with_aliases(stats: Dict[str, object]) -> Dict[str, object]:
    """Return ``stats`` plus the deprecated old-name aliases for every
    canonical key present."""
    out = dict(stats)
    for old, new in DEPRECATED_ALIASES.items():
        if new in out and old not in out:
            out[old] = out[new]
    return out


def _label_key(labels: dict) -> Tuple[Tuple[str, object], ...]:
    return tuple(sorted(labels.items()))


class MetricsRegistry:
    """Counters / gauges / fixed-bucket histograms keyed by
    ``(name, sorted-label-tuple)``."""

    def __init__(self) -> None:
        self._counters: Dict[tuple, float] = {}
        self._gauges: Dict[tuple, float] = {}
        self._hist_edges: Dict[str, List[float]] = {}
        # (name, labels) -> [counts(list, len(edges)+1), sum, n]
        self._hists: Dict[tuple, list] = {}

    # -- counters ------------------------------------------------------
    def inc(self, name: str, amount=1, **labels) -> None:
        k = (name, _label_key(labels))
        self._counters[k] = self._counters.get(k, 0) + amount

    def put_scalar(self, name: str, value) -> None:
        """Set the unlabeled counter slot (used by :func:`counter_attr`)."""
        self._counters[(name, ())] = value

    def put(self, name: str, value, **labels) -> None:
        """Idempotently set a labeled counter (snapshot semantics — safe
        to call from a `stats` path that may run repeatedly)."""
        self._counters[(name, _label_key(labels))] = value

    def get_scalar(self, name: str, default=0):
        return self._counters.get((name, ()), default)

    def get(self, name: str, default=0, **labels):
        return self._counters.get((name, _label_key(labels)), default)

    def total(self, name: str):
        """Sum of a counter across all label sets."""
        return sum(v for (n, _), v in self._counters.items() if n == name)

    # -- gauges --------------------------------------------------------
    def set_gauge(self, name: str, value, **labels) -> None:
        self._gauges[(name, _label_key(labels))] = value

    def gauge(self, name: str, default=0.0, **labels):
        return self._gauges.get((name, _label_key(labels)), default)

    # -- histograms ----------------------------------------------------
    def register_histogram(self, name: str,
                           edges: Iterable[float]) -> None:
        edges = sorted(float(e) for e in edges)
        if not edges:
            raise ValueError("histogram needs at least one bucket edge")
        self._hist_edges[name] = edges

    def observe(self, name: str, value: float, **labels) -> None:
        edges = self._hist_edges.get(name)
        if edges is None:
            raise KeyError(f"histogram {name!r} not registered")
        k = (name, _label_key(labels))
        h = self._hists.get(k)
        if h is None:
            h = [[0] * (len(edges) + 1), 0.0, 0]
            self._hists[k] = h
        h[0][bisect_right(edges, value)] += 1
        h[1] += value
        h[2] += 1

    def observe_many(self, name: str, values, **labels) -> None:
        """Vectorized bulk observe (one np.histogram instead of N
        bisects — what keeps end-of-run aggregation cheap at 10^6
        outcomes)."""
        edges = self._hist_edges.get(name)
        if edges is None:
            raise KeyError(f"histogram {name!r} not registered")
        values = np.asarray(values, dtype=float)
        if values.size == 0:
            return
        k = (name, _label_key(labels))
        h = self._hists.get(k)
        if h is None:
            h = [[0] * (len(edges) + 1), 0.0, 0]
            self._hists[k] = h
        bins = np.concatenate(([-np.inf], edges, [np.inf]))
        counts, _ = np.histogram(values, bins=bins)
        for i, c in enumerate(counts):
            h[0][i] += int(c)
        h[1] += float(values.sum())
        h[2] += int(values.size)

    def histogram(self, name: str, **labels):
        """``(edges, counts, sum, n)`` for one label set, or None."""
        h = self._hists.get((name, _label_key(labels)))
        if h is None:
            return None
        return (list(self._hist_edges[name]), list(h[0]), h[1], h[2])

    # -- export --------------------------------------------------------
    @staticmethod
    def _fmt_labels(lk: tuple) -> str:
        return ",".join(f"{k}={v}" for k, v in lk)

    def as_dict(self) -> Dict[str, dict]:
        """Nested plain-dict snapshot (JSON-serializable modulo values)."""
        counters: Dict[str, dict] = {}
        for (name, lk), v in sorted(self._counters.items()):
            counters.setdefault(name, {})[self._fmt_labels(lk)] = v
        gauges: Dict[str, dict] = {}
        for (name, lk), v in sorted(self._gauges.items()):
            gauges.setdefault(name, {})[self._fmt_labels(lk)] = v
        hists: Dict[str, dict] = {}
        for (name, lk), h in sorted(self._hists.items()):
            hists.setdefault(name, {})[self._fmt_labels(lk)] = {
                "edges": list(self._hist_edges[name]),
                "counts": list(h[0]), "sum": h[1], "count": h[2],
            }
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}


def counter_attr(name: str) -> property:
    """Class-level property storing a scalar counter in
    ``self.metrics`` under the unlabeled key ``name``.

    Lets a runtime replace ``self.n_rejected = 0`` instance counters
    with registry-backed ones without touching any ``+= 1`` call site.
    """
    key = (name, ())

    def fget(self):
        return self.metrics._counters.get(key, 0)

    def fset(self, value):
        self.metrics._counters[key] = value

    return property(fget, fset, doc=f"registry-backed counter {name!r}")
