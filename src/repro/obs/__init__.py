"""Observability: request-lifecycle tracing, metrics registry,
exporters, and the SLO-attribution report CLI (docs/observability.md).

Everything here is opt-in: the runtimes take ``trace=None`` defaults
and a traced run is result-bit-identical to an untraced one.
"""
from repro.obs.export import (
    perfetto_events, validate_perfetto, write_csv, write_perfetto,
)
from repro.obs.metrics import (
    DEPRECATED_ALIASES, MetricsRegistry, counter_attr, with_aliases,
)
from repro.obs.trace import (
    KIND_ARM, KIND_ARRIVAL, KIND_DECISION, KIND_DONE, KIND_INFER,
    KIND_KV_WAIT, KIND_MIGRATE, KIND_NAMES, KIND_PREEMPT, KIND_QUEUE,
    KIND_REJECT, KIND_RESUME, KIND_TX, SPAN_KINDS, TraceRecorder,
)

__all__ = [
    "DEPRECATED_ALIASES", "KIND_ARM", "KIND_ARRIVAL", "KIND_DECISION",
    "KIND_DONE", "KIND_INFER", "KIND_KV_WAIT", "KIND_MIGRATE",
    "KIND_NAMES", "KIND_PREEMPT", "KIND_QUEUE", "KIND_REJECT",
    "KIND_RESUME", "KIND_TX", "MetricsRegistry", "SPAN_KINDS",
    "TraceRecorder", "counter_attr", "perfetto_events",
    "validate_perfetto", "with_aliases", "write_csv", "write_perfetto",
]
