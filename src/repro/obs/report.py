"""SLO-attribution report CLI.

Runs a seeded benchmark workload with tracing enabled and prints where
the time went — per-phase (tx / queue / kv-wait / infer) breakdowns for
all completions, for the p95 latency tail, and for SLO violations —
plus the CSUCB arm-pull / violation timeline from the bandit. Optionally
exports the trace as Perfetto JSON and/or CSV.

Usage::

    PYTHONPATH=src python -m repro.obs.report --n 2000 --seed 0 \
        --perfetto trace.json --check

``--check`` re-reads the written Perfetto JSON and validates the
required ``ph``/``ts``/``pid`` keys, exiting non-zero on failure (the CI
smoke step).
"""
from __future__ import annotations

import argparse
import sys
import tempfile
from collections import defaultdict

import numpy as np

from repro.cluster import (
    BandwidthModel, Simulator, generate_workload, paper_testbed,
)
from repro.core import make_policy

from .export import validate_perfetto, write_csv, write_perfetto
from .trace import (
    KIND_ARM, KIND_DONE, KIND_INFER, KIND_KV_WAIT, KIND_NAMES,
    KIND_QUEUE, KIND_REJECT, KIND_TX, TraceRecorder,
)

_PHASES = ((KIND_TX, "tx"), (KIND_QUEUE, "queue"),
           (KIND_KV_WAIT, "kv_wait"), (KIND_INFER, "infer"))


def run_traced(n: int, rate: float, seed: int, n_edge: int,
               policy_name: str, scenario, capacity: int):
    """One seeded simulator run with the recorder attached to both the
    runtime and (when the policy has one) the CSUCB bandit."""
    specs = paper_testbed(n_edge=n_edge)
    services = generate_workload(n, rate=rate, seed=seed)
    rec = TraceRecorder(capacity=capacity)
    policy = make_policy(policy_name, len(specs))
    bandit = getattr(policy, "bandit", None)
    if bandit is not None:
        bandit.trace = rec
    sim = Simulator(specs, BandwidthModel(fluctuating=False), seed=seed)
    res = sim.run(services, policy, scenario=scenario, trace=rec)
    return rec, res


def _per_request(cols):
    """sid -> {phase: duration}, plus DONE/slo flags."""
    phases = defaultdict(lambda: defaultdict(float))
    done = {}
    for i in range(len(cols["kind"])):
        kind = int(cols["kind"][i])
        sid = int(cols["sid"][i])
        if kind == KIND_DONE:
            done[sid] = bool(cols["value"][i])
            continue
        for pk, pname in _PHASES:
            if kind == pk:
                phases[sid][pname] += float(cols["t1"][i]
                                            - cols["t0"][i])
                break
    return phases, done


def _phase_table(title, sids, phases, out):
    names = [p for _, p in _PHASES]
    if not sids:
        out.append(f"{title}: (none)")
        return
    sums = {p: sum(phases[s].get(p, 0.0) for s in sids) for p in names}
    # kv_wait nests inside tx: exclude it from the share denominator
    total = sum(v for p, v in sums.items() if p != "kv_wait")
    out.append(f"{title} ({len(sids)} requests):")
    for p in names:
        mean = sums[p] / len(sids)
        share = (100.0 * sums[p] / total) if total > 0 else 0.0
        nested = "  (within tx)" if p == "kv_wait" else ""
        out.append(f"    {p:8s} mean {mean * 1e3:9.2f} ms"
                   f"  share {share:5.1f}%{nested}")


def _arm_report(cols, out, bins=8):
    mask = cols["kind"] == KIND_ARM
    if not mask.any():
        out.append("CSUCB arm pulls: (no bandit trace attached)")
        return
    t = cols["t0"][mask]
    srv = cols["server"][mask]
    cls = cols["class_id"][mask]
    viol = cols["value"][mask]
    pulls = defaultdict(int)
    viols = defaultdict(float)
    for c, j, v in zip(cls, srv, viol):
        pulls[(int(c), int(j))] += 1
        viols[(int(c), int(j))] += float(v)
    out.append(f"CSUCB arm pulls: {int(mask.sum())} updates, "
               f"{len(pulls)} distinct (class, server) arms")
    top = sorted(pulls, key=lambda k: -pulls[k])[:10]
    out.append("    arm (class, server)    pulls   sum(violation)")
    for key in top:
        out.append(f"    {str(key):20s} {pulls[key]:6d}   "
                   f"{viols[key]:10.3f}")
    lo, hi = float(t.min()), float(t.max())
    span = max(hi - lo, 1e-9)
    edges = lo + span * np.arange(bins + 1) / bins
    out.append(f"  timeline ({bins} bins over "
               f"[{lo:.1f}s, {hi:.1f}s]):")
    idx = np.minimum((bins * (t - lo) / span).astype(int), bins - 1)
    pull_bins = np.bincount(idx, minlength=bins)
    viol_bins = np.bincount(idx, weights=(viol > 0), minlength=bins)
    out.append("    pulls      " + " ".join(f"{int(v):6d}"
                                            for v in pull_bins))
    out.append("    violations " + " ".join(f"{int(v):6d}"
                                            for v in viol_bins))
    _ = edges  # edges shown implicitly via the range line


def render_report(rec: TraceRecorder, res) -> str:
    cols = rec.to_arrays()
    out = []
    n_rows = len(cols["kind"])
    out.append(f"trace: {n_rows} rows ({rec.dropped} dropped), kinds: "
               + ", ".join(
                   f"{KIND_NAMES[k]}={int((cols['kind'] == k).sum())}"
                   for k in sorted(set(int(x) for x in cols["kind"]))))
    out.append(f"run: success_rate={res.success_rate:.4f} "
               f"avg={res.avg_processing_time:.3f}s "
               f"p95={res.p95_processing_time:.3f}s "
               f"rejected={res.n_rejected} preempted={res.n_preempted} "
               f"energy/token={res.energy_per_token:.4f}")

    phases, done = _per_request(cols)
    completed = sorted(done)
    _phase_table("phase breakdown, all completions", completed, phases,
                 out)

    totals = {s: sum(v for p, v in phases[s].items() if p != "kv_wait")
              for s in completed}
    if completed:
        p95 = float(np.percentile(list(totals.values()), 95))
        tail = [s for s in completed if totals[s] >= p95]
        _phase_table(f"p95 tail (>= {p95 * 1e3:.1f} ms)", tail, phases,
                     out)
    missed = [s for s in completed if not done[s]]
    _phase_table("SLO violations (completed, deadline missed)", missed,
                 phases, out)
    n_rej = int((cols["kind"] == KIND_REJECT).sum())
    out.append(f"shed by admission control: {n_rej}")

    _arm_report(cols, out)
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Traced benchmark run + SLO-violation attribution "
                    "report (and Perfetto/CSV export).")
    ap.add_argument("--n", type=int, default=2000,
                    help="workload size (default 2000)")
    ap.add_argument("--rate", type=float, default=50.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-edge", type=int, default=4)
    ap.add_argument("--policy", default="perllm")
    ap.add_argument("--scenario", default=None)
    ap.add_argument("--capacity", type=int, default=1 << 18,
                    help="recorder ring capacity in rows")
    ap.add_argument("--perfetto", metavar="PATH", default=None,
                    help="write Chrome/Perfetto trace JSON")
    ap.add_argument("--csv", metavar="PATH", default=None,
                    help="write columnar CSV dump")
    ap.add_argument("--check", action="store_true",
                    help="validate the written Perfetto JSON schema "
                         "(writes a temp file if --perfetto not given)")
    args = ap.parse_args(argv)

    rec, res = run_traced(args.n, args.rate, args.seed, args.n_edge,
                          args.policy, args.scenario, args.capacity)
    print(render_report(rec, res))

    if args.csv:
        n = write_csv(rec, args.csv)
        print(f"wrote {args.csv} ({n} rows)")
    path = args.perfetto
    if args.check and path is None:
        path = tempfile.mktemp(suffix=".json", prefix="repro_trace_")
    if path:
        n = write_perfetto(rec, path)
        print(f"wrote {path} ({n} trace events)")
    if args.check:
        problems = validate_perfetto(path)
        if problems:
            for p in problems:
                print(f"perfetto schema: {p}", file=sys.stderr)
            return 1
        print("perfetto schema: valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
