"""Columnar, ring-buffered request-lifecycle trace recorder.

The recorder is the substrate for per-request timelines (docs/
observability.md): every lifecycle phase — arrival, scheduling decision,
uplink transmission, KV-admission wait, inference, completion — plus the
non-happy-path events (reject, preempt, migrate, resume) and CSUCB arm
pulls land here as fixed-width rows in preallocated numpy columns.

Design constraints (the "overhead contract"):

* **Nothing expensive on the hot path.** Writers push plain tuples
  onto ``deque(maxlen=...)`` staging — no numpy element conversion
  while the traced system runs. The dominant writer, one completion
  per request, uses :meth:`complete`: a single 13-scalar record (one
  tuple, one deque append) that materialization expands into the four
  TX/QUEUE/INFER/DONE schema rows vectorized. The PyObject→column
  conversion (the genuinely costly part, ~60 ns per stored scalar)
  happens exactly once, lazily, the first time a reader asks for
  :meth:`to_arrays` — off the window the CI traced-overhead gate times.
  Instrumenting the array event core therefore costs ~1 µs per arrival
  against its ~30 µs baseline, which is what keeps the gate under 10%.
* **No side effects on the traced system.** The recorder never draws
  RNG, never reads lazily-materialized views, and never mutates ledger
  state; traced runs are result-bit-identical to untraced runs (golden
  tested in ``tests/test_obs.py``).
* **Bounded memory.** Staging is two bounded tables — generic rows
  (at most ``capacity``) and completion records (at most
  ``capacity // 4`` records of four rows each) — so the surviving
  window never exceeds ~2·``capacity`` rows. Once a table fills, its
  oldest entries fall off the front and ``dropped`` counts what was
  lost. Readers receive the surviving window as numpy columns sorted
  by ``(t0, kind)`` — a deterministic chronological order shared by
  both sim cores.

Row schema (one value per column; unused fields hold the defaults):

========  =======  ====================================================
column    dtype    meaning
========  =======  ====================================================
kind      int8     one of the ``KIND_*`` constants below
sid       int64    service id (``ARM`` rows: the bandit's pull count)
t0        float64  span start (seconds, sim clock)
t1        float64  span end; ``t0 == t1`` for instant markers
server    int32    server index (``MIGRATE``: destination), -1 n/a
class_id  int16    request class, -1 n/a
tier      int16    DVFS tier of the granted allocation, 0 nominal
energy    float64  energy attributed to the span (J); ``ARM``: reward
value     float64  kind-specific payload (see ``KIND_VALUE_DOC``)
aux       int32    interned label id (links/lanes), -1 n/a
========  =======  ====================================================
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

import numpy as np

# lifecycle kinds ------------------------------------------------------
KIND_ARRIVAL = 0    # re-entry marker (requeue after preempt); a first
#                     arrival is implicit as its TX span's t0
KIND_DECISION = 1   # placement marker for sheds and re-placements;
#                     value = 1.0 admit / 0.0 shed. Happy-path decisions
#                     are implicit (server/tier ride on the TX/INFER
#                     spans, decision time == arrival)
KIND_TX = 2         # arrival -> uplink transfer complete ("ready")
KIND_QUEUE = 3      # ready -> inference begin (lane wait)
KIND_KV_WAIT = 4    # blocked in the KV admission queue (nested in TX)
KIND_INFER = 5      # inference begin -> finish; value = output tokens
KIND_DONE = 6       # completion marker; value = 1.0 SLO met / 0.0 missed
KIND_REJECT = 7     # admission control shed the request
KIND_PREEMPT = 8    # lane reclaimed; span covers the wasted decode
KIND_MIGRATE = 9    # cross-server KV page transfer; value = bytes
KIND_RESUME = 10    # dispatch resumed preserved KV pages (no re-prefill)
KIND_ARM = 11       # CSUCB arm pull; energy = reward, value = violation

KIND_NAMES = (
    "ARRIVAL", "DECISION", "TX", "QUEUE", "KV_WAIT", "INFER", "DONE",
    "REJECT", "PREEMPT", "MIGRATE", "RESUME", "ARM",
)

#: kinds rendered as duration slices (everything else is a marker)
SPAN_KINDS = (KIND_TX, KIND_QUEUE, KIND_KV_WAIT, KIND_INFER,
              KIND_PREEMPT, KIND_MIGRATE)

KIND_VALUE_DOC = {
    KIND_DECISION: "1.0 admitted / 0.0 shed",
    KIND_INFER: "output tokens decoded",
    KIND_DONE: "1.0 deadline met / 0.0 missed",
    KIND_MIGRATE: "KV bytes shipped",
    KIND_ARM: "violation severity fed to CSUCB",
}

_COLUMNS = (
    ("kind", np.int8), ("sid", np.int64), ("t0", np.float64),
    ("t1", np.float64), ("server", np.int32), ("class_id", np.int16),
    ("tier", np.int16), ("energy", np.float64), ("value", np.float64),
    ("aux", np.int32),
)


def _expand_completions(d: np.ndarray) -> np.ndarray:
    """Expand (m, 13) completion records into the (4m, 10) schema rows
    TX / QUEUE / INFER / DONE — all slice assignments, no Python loop
    over records."""
    m = d.shape[0]
    sid, arrival, ready, begin, finish = (d[:, i] for i in range(5))
    server, cls, tier, lane = (d[:, i] for i in range(5, 9))
    e_tx, e_inf, tokens, success = (d[:, i] for i in range(9, 13))
    out = np.empty((4 * m, 10), dtype=np.float64)
    rows = (
        (KIND_TX, arrival, ready, e_tx, 0.0, -1.0),
        (KIND_QUEUE, ready, begin, 0.0, 0.0, lane),
        (KIND_INFER, begin, finish, e_inf, tokens, lane),
        (KIND_DONE, finish, finish, 0.0, success, -1.0),
    )
    for off, (kind, t0, t1, energy, value, aux) in enumerate(rows):
        blk = out[off::4]
        blk[:, 0] = kind
        blk[:, 1] = sid
        blk[:, 2] = t0
        blk[:, 3] = t1
        blk[:, 4] = server
        blk[:, 5] = cls
        blk[:, 6] = tier
        blk[:, 7] = energy
        blk[:, 8] = value
        blk[:, 9] = aux
    return out


class TraceRecorder:
    """Ring-buffered columnar store for lifecycle rows.

    Pass one instance as ``trace=`` to ``Simulator.run`` /
    ``PerLLMServer`` (and optionally attach it to ``CSUCB.trace``); read
    it back with :meth:`to_arrays` or the exporters in
    :mod:`repro.obs.export`.
    """

    def __init__(self, capacity: int = 1 << 18) -> None:
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = int(capacity)
        self.n_total = 0
        self._buf: deque = deque(maxlen=self.capacity)
        self._done: deque = deque(maxlen=max(1, self.capacity // 4))
        self._labels: List[str] = []
        self._label_ids: Dict[str, int] = {}
        self._mat: Optional[Dict[str, np.ndarray]] = None
        self._mat_stamp = -1

    # -- write path ----------------------------------------------------
    def append(self, kind: int, sid: int, t0: float, t1: float,
               server: int = -1, class_id: int = -1, tier: int = 0,
               energy: float = 0.0, value: float = 0.0,
               aux: int = -1) -> None:
        """Record one row. Hot path: one tuple + one deque append."""
        self._buf.append((kind, sid, t0, t1, server, class_id, tier,
                          energy, value, aux))
        self.n_total += 1

    def append_rows(self, rows) -> None:
        """Batch append of pre-built 10-tuples (one call per lifecycle
        batch keeps the instrumented runtimes' per-arrival cost down)."""
        self._buf.extend(rows)
        self.n_total += len(rows)

    def complete(self, sid: int, arrival: float, ready: float,
                 begin: float, finish: float, server: int = -1,
                 class_id: int = -1, tier: int = 0, lane: int = -1,
                 e_tx: float = 0.0, e_inf: float = 0.0, tokens: int = 0,
                 success=False) -> None:
        """Record one completed request's whole TX/QUEUE/INFER/DONE
        lifecycle as a single 13-scalar record — the hottest write in
        every traced run (one per served request). Materialization
        expands it into the four schema rows, so readers never see the
        compressed form."""
        self._done.append((sid, arrival, ready, begin, finish, server,
                           class_id, tier, lane, e_tx, e_inf, tokens,
                           success))
        self.n_total += 4

    def intern(self, label: str) -> int:
        """Map a string label (link name, lane id) to a stable int for
        the ``aux`` column."""
        lid = self._label_ids.get(label)
        if lid is None:
            lid = len(self._labels)
            self._label_ids[label] = lid
            self._labels.append(label)
        return lid

    def flush(self) -> None:
        """Materialize the columnar view now (optional — readers do this
        lazily). Kept so callers can pay the conversion cost at a chosen
        point, e.g. after a timed region, instead of at first read."""
        self._materialize()

    def _materialize(self) -> Dict[str, np.ndarray]:
        """Convert the staging deques into numpy columns, cached until
        the next write. This is the only PyObject→array conversion and
        it never runs on the recording hot path. Rows come out sorted
        by ``(t0, kind)`` — deterministic regardless of which staging
        table a row lived in."""
        if self._mat is not None and self._mat_stamp == self.n_total:
            return self._mat
        parts = []
        if self._buf:
            parts.append(np.array(self._buf, dtype=np.float64))
        if self._done:
            parts.append(_expand_completions(
                np.array(self._done, dtype=np.float64)))
        if parts:
            raw = parts[0] if len(parts) == 1 else np.concatenate(parts)
            raw = raw[np.lexsort((raw[:, 0], raw[:, 2]))]
            self._mat = {name: raw[:, i].astype(dt, copy=False)
                         for i, (name, dt) in enumerate(_COLUMNS)}
        else:
            self._mat = {name: np.zeros(0, dtype=dt)
                         for name, dt in _COLUMNS}
        self._mat_stamp = self.n_total
        return self._mat

    # -- read path -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._buf) + 4 * len(self._done)

    @property
    def dropped(self) -> int:
        """Rows that fell off the front of the ring (0 unless capacity
        was exceeded)."""
        return self.n_total - len(self)

    @property
    def labels(self) -> List[str]:
        return list(self._labels)

    def label(self, aux: int) -> Optional[str]:
        if 0 <= aux < len(self._labels):
            return self._labels[aux]
        return None

    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Chronological copy of the surviving window, column-major."""
        return {name: col.copy()
                for name, col in self._materialize().items()}

    def timeline(self, sid: int) -> Dict[str, np.ndarray]:
        """All rows for one request, chronological."""
        cols = self._materialize()
        mask = (cols["sid"] == sid) & (cols["kind"] != KIND_ARM)
        return {name: col[mask] for name, col in cols.items()}
