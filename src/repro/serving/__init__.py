from repro.serving.engine import Request, ServingEngine
from repro.serving.perllm_server import PerLLMServer, ServedRequest
from repro.serving.sampling import sample_tokens

__all__ = ["PerLLMServer", "Request", "ServedRequest", "ServingEngine",
           "sample_tokens"]
