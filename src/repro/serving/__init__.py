from repro.serving.engine import Request, ServingEngine
from repro.serving.kvcache import (
    BlockAllocator, KVSnapshot, PagedKVCache, PageTable, blocks_needed,
)
from repro.serving.perllm_server import PerLLMServer, ServedRequest
from repro.serving.sampling import sample_tokens

__all__ = ["BlockAllocator", "KVSnapshot", "PagedKVCache", "PageTable",
           "PerLLMServer", "Request", "ServedRequest", "ServingEngine",
           "blocks_needed", "sample_tokens"]
