"""Continuous-batching serving engine.

A fixed pool of `max_batch` KV-cache slots; requests are admitted into free
slots (prefill) and all active slots decode together each step with
per-slot positions (the `update_cache_seq` vector-pos path). This is the
execution layer a PerLLM "server" runs — the scheduler decides *which*
server a request goes to, the engine decides *how* it runs there.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.parallel import ParallelContext, cpu_context
from repro.serving.sampling import sample_tokens


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: int = -1          # -1: never stop early
    # runtime
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: float = -1.0
    done_at: float = -1.0

    @property
    def done(self) -> bool:
        return self.done_at >= 0


def _batch_axis_tree(cfg: ModelConfig, max_seq: int):
    """Which axis of each cache leaf is the batch axis (found by probing)."""
    c1 = jax.eval_shape(lambda: M.init_cache(cfg, 1, max_seq))
    c2 = jax.eval_shape(lambda: M.init_cache(cfg, 2, max_seq))
    return jax.tree.map(
        lambda a, b: next(i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                          if x != y), c1, c2)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_seq: int = 1024, ctx: Optional[ParallelContext] = None,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or cpu_context()
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.cache = M.init_cache(cfg, max_batch, max_seq)
        self._axis = _batch_axis_tree(cfg, max_seq)
        self.positions = np.zeros(max_batch, np.int32)
        self.cur_tokens = np.zeros(max_batch, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self._rid = itertools.count()
        self._key = jax.random.key(seed)
        self.completed: List[Request] = []

        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, t, c, pos, cfg=cfg,
                                               ctx=self.ctx))

        # prompts are right-padded to power-of-2 buckets so prefill
        # compiles once per bucket, not once per prompt length; `last`
        # indexes the true final-token logits. Padded garbage keys occupy
        # slots >= plen but decode overwrites them sequentially before the
        # position mask can ever reach them.
        def _prefill_cache(p, batch, c, last):
            logits, new_cache, _ = M.forward(p, batch, cfg=cfg,
                                             ctx=self.ctx, mode="prefill",
                                             cache=c)
            return logits[:, last], new_cache
        self._prefill = jax.jit(_prefill_cache)

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 32,
               eos_id: int = -1) -> Request:
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      submitted_at=time.time())
        self.queue.append(req)
        return req

    @property
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _insert_slot(self, slot: int, single_cache):
        def ins(pool, one, ax):
            return jax.lax.dynamic_update_slice_in_dim(pool, one, slot, ax)
        self.cache = jax.tree.map(ins, self.cache, single_cache, self._axis)

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            plen = len(req.prompt)
            bucket = 1 << max(plen - 1, 1).bit_length()   # next pow2 >= plen
            bucket = min(bucket, self.max_seq)
            padded = req.prompt + [0] * (bucket - plen)
            prompt = jnp.asarray(padded, jnp.int32)[None, :]
            one_cache = M.init_cache(self.cfg, 1, self.max_seq)
            batch = {"tokens": prompt}
            if self.cfg.mrope:
                s = prompt.shape[1]
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(s, dtype=jnp.int32), (3, 1, s))
            last_logits, one_cache = self._prefill(
                self.params, batch, one_cache, jnp.int32(plen - 1))
            self._key, k = jax.random.split(self._key)
            tok = int(sample_tokens(k, last_logits, self.temperature)[0])
            self._insert_slot(slot, one_cache)
            req.slot = slot
            req.generated.append(tok)
            req.first_token_at = time.time()
            self.positions[slot] = len(req.prompt)
            self.cur_tokens[slot] = tok
            self.slot_req[slot] = req
            self._maybe_finish(slot)

    def evict(self, slot: int) -> Optional[Request]:
        """Preempt the request occupying `slot`, returning its lane.

        The request is detached un-finished (its partial generation is
        kept on the object, its KV cache is dropped — stale cache rows are
        harmless, the next admission overwrites them); the caller decides
        whether to resubmit the remaining tokens here or elsewhere."""
        req = self.slot_req[slot]
        if req is None:
            return None
        self.slot_req[slot] = None
        req.slot = -1
        return req

    def _maybe_finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is None:
            return
        last = req.generated[-1]
        if (len(req.generated) >= req.max_new_tokens
                or (req.eos_id >= 0 and last == req.eos_id)
                or self.positions[slot] >= self.max_seq - 1):
            req.done_at = time.time()
            self.completed.append(req)
            self.slot_req[slot] = None

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        self._admit()
        active = self.active_slots
        if not active:
            return 0
        tokens = jnp.asarray(self.cur_tokens, jnp.int32)[:, None]
        pos = jnp.asarray(self.positions, jnp.int32)
        logits, self.cache = self._decode(self.params, tokens, self.cache,
                                          pos)
        self._key, k = jax.random.split(self._key)
        next_tokens = np.asarray(sample_tokens(k, logits, self.temperature))
        for slot in active:
            req = self.slot_req[slot]
            req.generated.append(int(next_tokens[slot]))
            self.positions[slot] += 1
            self.cur_tokens[slot] = next_tokens[slot]
            self._maybe_finish(slot)
        return len(active)

    def run_until_idle(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if not self.queue and not self.active_slots:
                break
            self.step()
        return self.completed
