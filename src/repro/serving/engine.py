"""Continuous-batching serving engine.

A fixed pool of `max_batch` KV-cache slots; requests are admitted into free
slots (prefill) and all active slots decode together each step with
per-slot positions (the `update_cache_seq` vector-pos path). This is the
execution layer a PerLLM "server" runs — the scheduler decides *which*
server a request goes to, the engine decides *how* it runs there.

With `paged=True` the engine's KV capacity is a `PagedKVCache` block pool
instead of the implicit `max_batch × max_seq` dense reservation: admission
allocates `ceil((prompt+max_new)/block_tokens)` blocks up front and stalls
(FIFO) when the pool is exhausted — memory, not lane count, is what bounds
the batch. Eviction snapshots the slot's KV into the request's pages
(`evict` → `Request.kv`), so a preempted request `resubmit`-ted to the
same engine reattaches its page table and resumes decoding with **zero
re-prefill**; `release` drops a request's pages when the work moves
elsewhere. The per-slot compute view stays the dense jitted cache (pages
are scattered/gathered at evict/resume only), which keeps paged and dense
decoding bit-identical; `repro.kernels.paged_attention` is the kernel
that decodes straight from such a pool on TPU.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.obs.metrics import MetricsRegistry, counter_attr, with_aliases
from repro.models.parallel import ParallelContext, cpu_context
from repro.serving.kvcache import KVSnapshot, PagedKVCache, PageTable
from repro.serving.sampling import sample_tokens


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: int = -1          # -1: never stop early
    # runtime
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: float = -1.0
    done_at: float = -1.0
    # paged-KV runtime: the request's block-pool pages while it holds any,
    # and the resume snapshot written by `evict` (consumed by re-admission)
    pages: Optional[PageTable] = None
    kv: Optional[KVSnapshot] = None

    @property
    def done(self) -> bool:
        return self.done_at >= 0


def _batch_axis_tree(cfg: ModelConfig, max_seq: int):
    """Which axis of each cache leaf is the batch axis (found by probing)."""
    c1 = jax.eval_shape(lambda: M.init_cache(cfg, 1, max_seq))
    c2 = jax.eval_shape(lambda: M.init_cache(cfg, 2, max_seq))
    return jax.tree.map(
        lambda a, b: next(i for i, (x, y)
                          in enumerate(zip(a.shape, b.shape, strict=True))
                          if x != y), c1, c2)


class ServingEngine:
    # registry-backed compile counters — the runtime complement to the
    # R8 static rule: decode must stay at one compile per (batch, 1)
    # token shape, prefill at one per pow-2 seq bucket
    decode_compiles = counter_attr("engine.decode_compiles")
    prefill_compiles = counter_attr("engine.prefill_compiles")

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_seq: int = 1024, ctx: Optional[ParallelContext] = None,
                 temperature: float = 0.0, seed: int = 0,
                 paged: bool = False, kv_blocks: Optional[int] = None,
                 kv_block_tokens: int = 16, prefix_sharing: bool = True):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or cpu_context()
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.cache = M.init_cache(cfg, max_batch, max_seq)
        self._axis = _batch_axis_tree(cfg, max_seq)
        self.positions = np.zeros(max_batch, np.int32)
        self.cur_tokens = np.zeros(max_batch, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self._rid = itertools.count()
        self._key = jax.random.key(seed)
        self.completed: List[Request] = []
        self.metrics = MetricsRegistry()
        self._compiled_shapes: set = set()
        self.n_prefills = 0       # prompts actually prefilled (resumes skip)
        self.n_prefix_hits = 0        # admissions that reused a shared prefix
        self.prefix_tokens_reused = 0  # prompt tokens those hits skipped
        self.prefix_sharing = bool(prefix_sharing)
        # DVFS pacing hint: the relative clock frequency this engine's host
        # is currently running at. Compute (`step`) is frequency-blind —
        # the same tokens come out — but the runtime that clocks the engine
        # (PerLLMServer's per-engine tick cadence) stretches each decode
        # step by 1/freq_scale, mapping scheduler-chosen tiers onto real
        # decode-step pacing. Set via `set_freq_scale`.
        self.freq_scale = 1.0
        self.paged = paged
        self.kv: Optional[PagedKVCache] = None
        if paged:
            # default pool: the dense reservation's worth of blocks
            n_blocks = kv_blocks if kv_blocks is not None \
                else max_batch * (max_seq // kv_block_tokens)
            self.kv = PagedKVCache(cfg, n_blocks=n_blocks,
                                   block_tokens=kv_block_tokens,
                                   max_seq=max_seq)

        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, t, c, pos, cfg=cfg,
                                               ctx=self.ctx))

        # prompts are right-padded to power-of-2 buckets so prefill
        # compiles once per bucket, not once per prompt length; `last`
        # indexes the true final-token logits. Padded garbage keys occupy
        # slots >= plen but decode overwrites them sequentially before the
        # position mask can ever reach them.
        def _prefill_cache(p, batch, c, last):
            logits, new_cache, _ = M.forward(p, batch, cfg=cfg,
                                             ctx=self.ctx, mode="prefill",
                                             cache=c)
            return logits[:, last], new_cache
        self._prefill = jax.jit(_prefill_cache)

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 32,
               eos_id: int = -1) -> Request:
        if self.paged:
            need = self.kv.blocks_for(len(prompt) + max_new_tokens)
            if need > self.kv.n_blocks:
                raise ValueError(
                    f"request needs {need} KV blocks but the pool only has "
                    f"{self.kv.n_blocks}; it could never be admitted")
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      submitted_at=time.time())
        self.queue.append(req)
        return req

    def resubmit(self, req: Request) -> Request:
        """Re-enqueue a previously evicted request on this engine.

        Paged engines only: the request re-enters with its pages and
        `KVSnapshot` attached, so admission reattaches the page table and
        resumes decoding instead of re-running prefill. (Dense engines have
        nothing to reattach — submit the remainder as a new request.)"""
        assert self.paged, "resubmit needs a paged engine (KV survives)"
        assert req.slot < 0 and not req.done, req
        assert req.kv is not None and req.pages is not None, \
            "resubmit is for evicted requests holding a KV snapshot"
        self.queue.append(req)
        return req

    def release(self, req: Request) -> None:
        """Drop an evicted request's pages + snapshot (it is moving to a
        different server, or its work was abandoned)."""
        if self.paged and req.pages is not None:
            self.kv.free(req.pages)
        req.pages = None
        req.kv = None

    def set_freq_scale(self, freq: float) -> None:
        """Set the host's DVFS pacing (relative frequency, nominal 1.0)."""
        if freq <= 0.0:
            raise ValueError(f"freq_scale must be positive, got {freq}")
        self.freq_scale = float(freq)

    @property
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    @property
    def kv_free_blocks(self) -> Optional[int]:
        """Free KV blocks (None when the engine is dense)."""
        return self.kv.free_blocks if self.paged else None

    @property
    def _sharing(self) -> bool:
        """Prefix sharing live on this engine (paged + enabled + the
        pool's leaf layout supports a prefix index)."""
        return self.paged and self.prefix_sharing \
            and self.kv.supports_prefix

    def _note_compile(self, kind: str, shape) -> None:
        """Count first-seen operand shapes per jitted entry point. jit
        caches on shape, so a fresh (kind, shape) key is exactly one new
        XLA compile; the counters stay flat once the shape set is warm."""
        key = (kind, tuple(shape))
        if key not in self._compiled_shapes:
            self._compiled_shapes.add(key)
            self.metrics.inc(f"engine.{kind}_compiles")

    def _insert_slot(self, slot: int, single_cache):
        def ins(pool, one, ax):
            return jax.lax.dynamic_update_slice_in_dim(pool, one, slot, ax)
        self.cache = jax.tree.map(ins, self.cache, single_cache, self._axis)

    def _extract_slot(self, slot: int):
        def ext(pool, ax):
            return jax.lax.dynamic_slice_in_dim(pool, slot, 1, ax)
        return jax.tree.map(ext, self.cache, self._axis)

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None:
                continue
            if not self.queue:
                break
            req = self.queue[0]
            if self.paged and req.kv is not None:
                self.queue.pop(0)
                self._resume(slot, req)
                continue
            if self.paged:
                req.pages = self.kv.allocate(
                    len(req.prompt) + req.max_new_tokens,
                    prompt=req.prompt if self._sharing else None)
                if req.pages is None:
                    # KV pressure: admission stalls FIFO — but a resumable
                    # continuation further back already holds its pages
                    # (it allocates nothing) and must pass the stalled
                    # head, or its held blocks could deadlock the pool
                    ri = next((i for i, q in enumerate(self.queue)
                               if q.kv is not None), None)
                    if ri is None:
                        break
                    self._resume(slot, self.queue.pop(ri))
                    continue
            self.queue.pop(0)
            plen = len(req.prompt)
            skip = 0
            if self.paged and req.pages is not None \
                    and req.pages.shared_blocks > 0:
                skip = req.pages.shared_blocks * self.kv.block_tokens
            if skip > 0:
                # prefix hit: the table's read-shared head already holds
                # the prompt's first `skip` tokens of KV — gather the
                # pages and prefill only the suffix, one token at a time
                # through the decode step (its shape-polymorphic jit
                # serves batch 1; `allocate` guarantees skip < plen)
                one_cache = self.kv.load(req.pages, [])
                logits = None
                self._note_compile("decode", (1, 1))
                for i in range(skip, plen):
                    tok = jnp.asarray([[req.prompt[i]]], jnp.int32)
                    logits, one_cache = self._decode(
                        self.params, tok, one_cache,
                        jnp.asarray([i], jnp.int32))
                last_logits = logits
                self.n_prefix_hits += 1
                self.prefix_tokens_reused += skip
            else:
                bucket = 1 << max(plen - 1, 1).bit_length()  # next pow2 >= plen
                bucket = min(bucket, self.max_seq)
                padded = req.prompt + [0] * (bucket - plen)
                prompt = jnp.asarray(padded, jnp.int32)[None, :]
                one_cache = M.init_cache(self.cfg, 1, self.max_seq)
                batch = {"tokens": prompt}
                if self.cfg.mrope:
                    s = prompt.shape[1]
                    batch["positions"] = jnp.broadcast_to(
                        jnp.arange(s, dtype=jnp.int32), (3, 1, s))
                # deliberate shape polymorphism: the pow-2 bucketing above
                # caps this at log2(max_seq) distinct prefill shapes, and
                # `engine.prefill_compiles` counts them at runtime
                self._note_compile("prefill", prompt.shape)
                last_logits, one_cache = self._prefill(  # repro-check: disable=R8
                    self.params, batch, one_cache, jnp.int32(plen - 1))
            self.n_prefills += 1
            if self._sharing:
                # publish the prompt's full blocks while they really hold
                # its KV (pages are otherwise only written at eviction) so
                # later admissions can attach them copy-on-write
                self.kv.store_prefix(req.pages, one_cache, n_tokens=plen)
                self.kv.register_prefix(req.prompt, req.pages)
            self._key, k = jax.random.split(self._key)
            tok = int(sample_tokens(k, last_logits, self.temperature)[0])
            self._insert_slot(slot, one_cache)
            req.slot = slot
            req.generated.append(tok)
            req.first_token_at = time.time()
            self.positions[slot] = len(req.prompt)
            self.cur_tokens[slot] = tok
            self.slot_req[slot] = req
            self._maybe_finish(slot)

    def _resume(self, slot: int, req: Request) -> None:
        """Reattach an evicted request: gather its pages back into the
        slot's dense compute cache and continue decoding — no prefill."""
        snap = req.kv
        req.kv = None
        self._insert_slot(slot, self.kv.load(req.pages, snap.state))
        req.slot = slot
        self.positions[slot] = snap.position
        self.cur_tokens[slot] = snap.cur_token
        self.slot_req[slot] = req
        self._maybe_finish(slot)

    def evict(self, slot: int, keep_kv: bool = True) -> Optional[Request]:
        """Preempt the request occupying `slot`, returning its lane.

        The request is detached un-finished with its partial generation
        kept on the object. A paged engine snapshots the slot's KV into
        the request's pages first (`Request.kv`), so `resubmit` here skips
        re-prefill — unless `keep_kv=False` (a memory-pressure eviction:
        the pages go straight back to the pool, no snapshot scatter). A
        dense engine drops the KV either way (stale cache rows are
        harmless — the next admission overwrites them). The freed lane's
        `positions`/`cur_tokens` are zeroed so stale decode state can't
        leak into the next occupant's diagnostics. The caller decides
        whether the remaining tokens run here or elsewhere (and must
        `release` the pages if elsewhere)."""
        req = self.slot_req[slot]
        if req is None:
            return None
        if self.paged and not keep_kv:
            self.release(req)
        elif self.paged:
            state = self.kv.store(req.pages, self._extract_slot(slot))
            req.kv = KVSnapshot(state=state,
                                position=int(self.positions[slot]),
                                cur_token=int(self.cur_tokens[slot]))
        self.slot_req[slot] = None
        self.positions[slot] = 0
        self.cur_tokens[slot] = 0
        req.slot = -1
        return req

    def _maybe_finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is None:
            return
        last = req.generated[-1]
        if (len(req.generated) >= req.max_new_tokens
                or (req.eos_id >= 0 and last == req.eos_id)
                or self.positions[slot] >= self.max_seq - 1):
            req.done_at = time.time()
            self.completed.append(req)
            self.slot_req[slot] = None
            self.positions[slot] = 0
            self.cur_tokens[slot] = 0
            self.release(req)      # free-on-finish: pages return to the pool

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        self._admit()
        active = self.active_slots
        if not active:
            return 0
        tokens = jnp.asarray(self.cur_tokens, jnp.int32)[:, None]
        pos = jnp.asarray(self.positions, jnp.int32)
        self._note_compile("decode", tokens.shape)
        logits, self.cache = self._decode(self.params, tokens, self.cache,
                                          pos)
        self._key, k = jax.random.split(self._key)
        next_tokens = np.asarray(sample_tokens(k, logits, self.temperature))
        for slot in active:
            req = self.slot_req[slot]
            req.generated.append(int(next_tokens[slot]))
            self.positions[slot] += 1
            self.cur_tokens[slot] = next_tokens[slot]
            self._maybe_finish(slot)
        return len(active)

    def stats(self) -> dict:
        """Engine-local counters under the canonical key namespace shared
        with ``PerLLMServer.stats`` / ``SimResult.stats()`` (old spellings
        like ``prefills`` / ``prefix_tokens_reused`` ride along as
        deprecated aliases for one release)."""
        out = {
            "n_prefills": self.n_prefills,
            "n_prefix_hits": self.n_prefix_hits,
            "kv_prefill_tokens_saved": self.prefix_tokens_reused,
            "n_queued": len(self.queue),
            "n_active": len(self.active_slots),
            "n_served": len(self.completed),
        }
        if self.paged:
            out["kv_free_blocks"] = self.kv.free_blocks
            out["kv_total_blocks"] = self.kv.n_blocks
        return with_aliases(out)

    def run_until_idle(self, max_steps: int = 10_000) -> List[Request]:
        """Step until queue and slots drain. Raises if `max_steps` runs out
        with work still pending — silently returning would lose requests
        (and with paged KV a stall can also mean the queue head needs
        blocks held by evicted-but-never-released snapshots)."""
        for _ in range(max_steps):
            if not self.queue and not self.active_slots:
                return self.completed
            self.step()
        if self.queue or self.active_slots:
            raise RuntimeError(
                f"run_until_idle: {len(self.queue)} queued and "
                f"{len(self.active_slots)} active requests remain after "
                f"{max_steps} steps"
                + (f" ({self.kv.free_blocks}/{self.kv.n_blocks} KV blocks "
                   f"free)" if self.paged else ""))
        return self.completed
