"""Paged KV cache: a fixed block pool, per-request page tables, snapshots.

The dense engine pre-books one `(max_seq, ...)` cache lane per batch slot,
so a server's KV capacity is `max_batch` regardless of how long requests
actually are — and evicting a request throws its prefill away. This module
makes KV memory a real, countable resource instead:

* `BlockAllocator` — a reference-counted free list over `n_blocks`
  fixed-size blocks; every admitted request allocates
  `ceil(tokens / block_tokens)` blocks up front and the pool's
  `free_blocks` is what schedulers observe as `ClusterView.kv_free_blocks`.
  A block's refcount is the number of page tables (plus the prefix index)
  holding it; `free` only returns a block to the pool at refcount zero,
  which is what makes prefix sharing and copy-on-write forks safe.
* `PrefixIndex` — a radix tree over *full* blocks keyed by token content.
  Prefilled prompts publish their full blocks (`register`); later
  admissions whose prompt starts with the same tokens `match` those
  resident blocks and skip that much prefill. The index holds one
  allocator reference per indexed block and evicts least-recently-touched
  leaves under pool pressure, so sharing never shrinks usable capacity.
* `PageTable` — one request's physical block ids, in logical order. Padded
  to any length with block 0 it is exactly the `block_tables` row the
  `paged_attention` kernel gathers through. Its first `shared_blocks`
  blocks are copy-on-write prefix pages: read-shared with other tables,
  never written back by `store`.
* `PagedKVCache` — the pool's storage side: for every cache-tree leaf with
  a sequence axis it keeps a `(n_blocks, block_tokens, ...)` pool and can
  scatter a slot's dense per-request cache into that request's pages
  (`store`, at eviction) and gather it back into a dense slot cache
  (`load`, at resume) — which is what lets a preempted request re-enter
  *without re-running prefill*. Leaves with no sequence axis (SSM/conv
  states, rolling windows smaller than `max_seq`) are snapshotted wholesale
  in the returned state list; they are per-request O(1)-sized state, not
  paged memory.

Layout note: pool leaves keep each cache leaf's own layout with the
sequence axis split as `(block, block_tokens)` and moved to the front, so
`store`/`load` are pure reshapes plus one indexed scatter/gather — the
attention kernels never read these pools directly (the engine's compute
view stays the dense jitted cache); `repro.kernels.paged_attention` is the
kernel that *does* read a `(n_pool, Hkv, page, D)` pool through a page
table, for the TPU deployment where the pool is the only cache.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


class BlockAllocator:
    """Reference-counted free-list allocator over a fixed pool of KV
    blocks. `allocate` hands out blocks at refcount 1; `ref` adds a
    holder (a sharing page table or the prefix index); `free` drops one
    holder and only returns the block to the pool when nobody holds it."""

    def __init__(self, n_blocks: int):
        if n_blocks <= 0:
            raise ValueError(f"need a positive block pool, got {n_blocks}")
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._ref = [0] * n_blocks

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def refcount(self, block: int) -> int:
        return self._ref[block]

    def allocate(self, n: int) -> Optional[List[int]]:
        """`n` block ids, or None if the pool can't satisfy the request
        (callers treat that as admission back-pressure, not an error)."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._ref[i] = 1
        return ids

    def ref(self, ids: List[int]) -> None:
        """Add a holder to already-live blocks (prefix sharing / COW)."""
        for i in ids:
            if self._ref[i] <= 0:
                raise ValueError(f"ref of free KV block {i}")
            self._ref[i] += 1

    def free(self, ids: List[int]) -> None:
        for i in ids:
            if self._ref[i] <= 0:
                raise ValueError(f"double free of KV block {i}")
            self._ref[i] -= 1
            if self._ref[i] == 0:
                self._free.append(i)


class _PrefixNode:
    """One full block of a registered prompt: `key` is the block's token
    content, `block` the physical block id the index holds a ref on."""

    __slots__ = ("key", "block", "children", "parent", "stamp")

    def __init__(self, key, block, parent):
        self.key = key
        self.block = block
        self.parent = parent
        self.children = {}
        self.stamp = 0


class PrefixIndex:
    """Radix tree over full KV blocks, keyed by token content.

    Each node owns one allocator reference on its block, so indexed
    prefixes survive the registering request's release — that is what
    turns a finished request's prefill into reusable capacity. `match`
    walks the longest indexed chain of full blocks that is a strict
    prefix of `tokens` (at least one suffix token always remains, so a
    hit still produces next-token logits). Under pool pressure `reclaim`
    evicts least-recently-touched leaves; evicting a leaf whose block is
    still held by a live table merely drops the index's share."""

    def __init__(self, allocator: BlockAllocator, block_tokens: int):
        self.allocator = allocator
        self.block_tokens = block_tokens
        self._root = _PrefixNode(key=None, block=-1, parent=None)
        self._clock = 0
        self.n_nodes = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _nodes(self):
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            yield node

    @property
    def reclaimable_blocks(self) -> int:
        """Blocks the index could return to the pool right now: indexed
        blocks no live table shares (refcount 1 — the index's own)."""
        return sum(1 for n in self._nodes()
                   if self.allocator.refcount(n.block) == 1)

    def match(self, tokens: List[int]) -> List[int]:
        """Block ids of the longest indexed full-block strict prefix of
        `tokens`, freshening their LRU stamps."""
        bt = self.block_tokens
        limit = max(0, (len(tokens) - 1) // bt)
        blocks: List[int] = []
        node = self._root
        stamp = self._tick()
        for k in range(limit):
            child = node.children.get(tuple(tokens[k * bt:(k + 1) * bt]))
            if child is None:
                break
            child.stamp = stamp
            blocks.append(child.block)
            node = child
        return blocks

    def register(self, tokens: List[int], blocks: List[int]) -> None:
        """Index the full blocks of a just-prefilled prompt. Existing
        nodes win (content-addressed: same tokens, interchangeable
        blocks); each newly inserted node takes one allocator ref."""
        bt = self.block_tokens
        node = self._root
        stamp = self._tick()
        for k in range(min(len(tokens) // bt, len(blocks))):
            key = tuple(tokens[k * bt:(k + 1) * bt])
            child = node.children.get(key)
            if child is None:
                child = _PrefixNode(key, blocks[k], node)
                self.allocator.ref([blocks[k]])
                node.children[key] = child
                self.n_nodes += 1
            child.stamp = stamp
            node = child

    def _evict(self, node: _PrefixNode) -> None:
        del node.parent.children[node.key]
        self.n_nodes -= 1
        self.allocator.free([node.block])

    def reclaim(self, n_free_target: int) -> bool:
        """Evict LRU leaves until the allocator has `n_free_target` free
        blocks (or no useful eviction remains). Returns success."""
        while self.allocator.free_blocks < n_free_target:
            leaves = [n for n in self._nodes() if not n.children]
            if not leaves:
                return False
            owned = [n for n in leaves
                     if self.allocator.refcount(n.block) == 1]
            if not owned and self.reclaimable_blocks == 0:
                # every remaining indexed block is shared with a live
                # table: evicting gains nothing now or transitively
                return False
            pool = owned or leaves
            self._evict(min(pool, key=lambda n: n.stamp))
        return True

    def clear(self) -> None:
        def drop(node):
            for child in list(node.children.values()):
                drop(child)
            self._evict(node)
        for child in list(self._root.children.values()):
            drop(child)


@dataclasses.dataclass
class PageTable:
    """One request's pages: physical block ids in logical order.

    The first `shared_blocks` blocks are copy-on-write prefix pages,
    read-shared with the prefix index (and possibly other tables): their
    content is immutable, `store` never writes them back."""

    blocks: List[int]
    block_tokens: int
    shared_blocks: int = 0

    @property
    def capacity_tokens(self) -> int:
        return len(self.blocks) * self.block_tokens

    def padded(self, n_pages: int) -> List[int]:
        """Block-table row for the paged kernel: padded with page 0 (the
        kernel masks padded pages via valid_len)."""
        assert n_pages >= len(self.blocks), (n_pages, len(self.blocks))
        return self.blocks + [0] * (n_pages - len(self.blocks))


@dataclasses.dataclass
class KVSnapshot:
    """What an evicted request keeps besides its pages: the unpaged state
    leaves and the decode cursor, enough to resume without re-prefill."""

    state: List[Any]          # non-sequence cache leaves, flat order
    position: int             # next cache write position
    cur_token: int            # last sampled token (next decode input)


def blocks_needed(n_tokens: int, block_tokens: int) -> int:
    """Blocks covering `n_tokens` of KV (minimum one — even an empty
    request owns its first page)."""
    return max(1, math.ceil(n_tokens / block_tokens))


class PagedKVCache:
    """Block-pool storage for one engine's KV cache."""

    def __init__(self, cfg: ModelConfig, n_blocks: int, block_tokens: int,
                 max_seq: int, dtype=None):
        if max_seq % block_tokens:
            raise ValueError(
                f"block_tokens={block_tokens} must divide max_seq={max_seq}")
        self.cfg = cfg
        self.block_tokens = block_tokens
        self.max_seq = max_seq
        self.allocator = BlockAllocator(n_blocks)
        # Probe which leaves carry the sequence axis: leaves whose shape
        # changes with max_seq are paged; the rest (recurrent states, conv
        # buffers, rolling windows < max_seq) are snapshot-wholesale state.
        shape_a = jax.eval_shape(
            lambda: M.init_cache(cfg, 1, max_seq, dtype=dtype))
        shape_b = jax.eval_shape(
            lambda: M.init_cache(cfg, 1, max_seq // 2, dtype=dtype))
        flat_a, self._treedef = jax.tree.flatten(shape_a)
        flat_b, _ = jax.tree.flatten(shape_b)
        self._seq_axis: List[Optional[int]] = []
        self._pools: List[Optional[jnp.ndarray]] = []
        for a, b in zip(flat_a, flat_b, strict=True):
            axis = next((i for i, (x, y)
                         in enumerate(zip(a.shape, b.shape, strict=True))
                         if x != y), None)
            if axis is not None and a.shape[axis] != max_seq:
                axis = None       # seq-dependent but not max_seq-sized
            self._seq_axis.append(axis)
            if axis is None:
                self._pools.append(None)
                continue
            rest = a.shape[:axis] + a.shape[axis + 1:]
            self._pools.append(jnp.zeros(
                (n_blocks, block_tokens) + rest, a.dtype))
        # prefix sharing needs the pages to BE the whole per-request
        # state: any non-sequence leaf (SSM states, rolling windows)
        # carries history the pages can't reproduce for a different
        # request, so such models keep the index off
        self.supports_prefix = bool(self._seq_axis) \
            and all(a is not None for a in self._seq_axis)
        self.prefix: Optional[PrefixIndex] = \
            PrefixIndex(self.allocator, block_tokens) \
            if self.supports_prefix else None

    # ------------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return self.allocator.n_blocks

    @property
    def free_blocks(self) -> int:
        """Allocatable blocks: truly free plus what the prefix index
        would surrender under pressure (indexed blocks no table shares).
        This is the number admission control may count on."""
        free = self.allocator.free_blocks
        if self.prefix is not None:
            free += self.prefix.reclaimable_blocks
        return free

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_needed(min(n_tokens, self.max_seq), self.block_tokens)

    def _allocate_fresh(self, n: int) -> Optional[List[int]]:
        ids = self.allocator.allocate(n)
        if ids is None and self.prefix is not None \
                and self.prefix.reclaim(n):
            ids = self.allocator.allocate(n)
        return ids

    def allocate(self, n_tokens: int,
                 prompt: Optional[List[int]] = None) -> Optional[PageTable]:
        """A page table covering `n_tokens`. With `prompt` given and a
        prefix index live, resident full blocks matching the prompt's
        head are attached read-shared (`shared_blocks`) instead of being
        allocated — the caller skips that much prefill."""
        shared: List[int] = []
        if prompt is not None and self.prefix is not None:
            shared = self.match_prefix(prompt)
        if shared:
            # pin before allocating: the pressure reclaim below must not
            # evict-and-recycle the very blocks we are about to share
            self.allocator.ref(shared)
        ids = self._allocate_fresh(self.blocks_for(n_tokens) - len(shared))
        if ids is None:
            if shared:
                self.allocator.free(shared)
            return None
        return PageTable(blocks=shared + ids,
                         block_tokens=self.block_tokens,
                         shared_blocks=len(shared))

    def match_prefix(self, prompt: List[int]) -> List[int]:
        """Resident full-block ids covering `prompt`'s head ([] without
        an index). Always a strict prefix: >= 1 suffix token remains."""
        if self.prefix is None:
            return []
        return self.prefix.match(prompt)

    def fork(self, table: PageTable) -> Optional[PageTable]:
        """Copy-on-write duplicate of a live table: all but the last
        block are reference-shared; the last (still-written) block is
        copied into a fresh one. None under pool exhaustion."""
        shared = table.blocks[:-1]
        if shared:
            self.allocator.ref(shared)
        tail = self._allocate_fresh(1)
        if tail is None:
            if shared:
                self.allocator.free(shared)
            return None
        src = table.blocks[-1]
        for i, pool in enumerate(self._pools):
            if pool is not None:
                self._pools[i] = pool.at[tail[0]].set(pool[src])
        return PageTable(blocks=shared + tail,
                         block_tokens=self.block_tokens,
                         shared_blocks=len(shared))

    def free(self, table: PageTable) -> None:
        self.allocator.free(table.blocks)
        table.blocks = []
        table.shared_blocks = 0

    # ------------------------------------------------------------------
    def store(self, table: PageTable, slot_cache) -> List[Any]:
        """Scatter a dense single-slot cache into `table`'s pages.

        Only the table's `capacity_tokens` prefix of each sequence leaf is
        persisted (the request can never have written beyond it), and the
        table's leading `shared_blocks` copy-on-write pages are skipped —
        they are read-shared and already hold exactly this content.
        Returns the non-sequence state leaves for the caller's
        `KVSnapshot`."""
        flat = self._flatten(slot_cache)
        skip = table.shared_blocks
        write = table.blocks[skip:]
        ids = jnp.asarray(write, jnp.int32)
        offset = skip * self.block_tokens
        span = table.capacity_tokens
        state: List[Any] = []
        for i, leaf in enumerate(flat):
            axis = self._seq_axis[i]
            if axis is None:
                state.append(leaf)
                continue
            if not write:
                continue
            lead = jnp.moveaxis(leaf, axis, 0)[offset:span]
            pages = lead.reshape((len(write), self.block_tokens)
                                 + lead.shape[1:])
            self._pools[i] = self._pools[i].at[ids].set(pages)
        return state

    def store_prefix(self, table: PageTable, slot_cache,
                     n_tokens: int) -> None:
        """Persist a live slot's *full* blocks (the first
        `n_tokens // block_tokens` pages, minus the read-shared head)
        into the pool — called right after prefill so `register_prefix`
        publishes pages that actually hold the prompt's KV (ordinarily
        pages are only written at eviction)."""
        bt = self.block_tokens
        n_full = min(len(table.blocks), n_tokens // bt)
        skip = table.shared_blocks
        if n_full <= skip:
            return
        ids = jnp.asarray(table.blocks[skip:n_full], jnp.int32)
        for i, leaf in enumerate(self._flatten(slot_cache)):
            axis = self._seq_axis[i]
            if axis is None:
                continue
            lead = jnp.moveaxis(leaf, axis, 0)[skip * bt:n_full * bt]
            pages = lead.reshape((n_full - skip, bt) + lead.shape[1:])
            self._pools[i] = self._pools[i].at[ids].set(pages)

    def register_prefix(self, prompt: List[int],
                        table: PageTable) -> None:
        """Publish a prefilled prompt's full blocks to the prefix index
        (no-op without one). Call after `store_prefix`."""
        if self.prefix is not None:
            self.prefix.register(prompt, table.blocks)

    # ------------------------------------------------------------------
    def export(self, table: PageTable) -> List[Optional[Any]]:
        """The table's page contents, one `(n_blocks, block_tokens,
        *rest)` array per sequence leaf (None for non-sequence leaves) —
        the wire format of a KV migration."""
        ids = jnp.asarray(table.blocks, jnp.int32)
        return [None if pool is None else pool[ids]
                for pool in self._pools]

    def import_pages(self, pages: List[Optional[Any]],
                     n_blocks: int) -> Optional[PageTable]:
        """Adopt migrated pages into this pool: allocate `n_blocks`
        fresh blocks and scatter each exported leaf in. None under pool
        exhaustion (the caller falls back to re-prefill)."""
        ids = self._allocate_fresh(n_blocks)
        if ids is None:
            return None
        arr = jnp.asarray(ids, jnp.int32)
        for i, leaf in enumerate(pages):
            if leaf is None:
                continue
            self._pools[i] = self._pools[i].at[arr].set(leaf)
        return PageTable(blocks=ids, block_tokens=self.block_tokens)

    def load(self, table: PageTable, state: List[Any]):
        """Gather `table`'s pages back into a dense single-slot cache.

        Sequence positions past the table's span are zeros; decode masks
        them by position exactly as it masks never-written tail slots."""
        ids = jnp.asarray(table.blocks, jnp.int32)
        flat: List[Any] = []
        state_it = iter(state)
        for i, axis in enumerate(self._seq_axis):
            if axis is None:
                flat.append(next(state_it))
                continue
            pool = self._pools[i]
            pages = pool[ids]                       # (nb, bt, *rest)
            lead = pages.reshape((-1,) + pages.shape[2:])
            rest = pool.shape[2:]
            full = jnp.zeros((self.max_seq,) + rest, pool.dtype)
            full = full.at[: lead.shape[0]].set(lead)
            flat.append(jnp.moveaxis(full, 0, axis))
        return jax.tree.unflatten(self._treedef, flat)

    def _flatten(self, slot_cache) -> List[Any]:
        flat, treedef = jax.tree.flatten(slot_cache)
        if treedef != self._treedef:
            raise ValueError(
                f"slot cache tree {treedef} does not match the pool's "
                f"{self._treedef}")
        return flat
