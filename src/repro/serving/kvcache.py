"""Paged KV cache: a fixed block pool, per-request page tables, snapshots.

The dense engine pre-books one `(max_seq, ...)` cache lane per batch slot,
so a server's KV capacity is `max_batch` regardless of how long requests
actually are — and evicting a request throws its prefill away. This module
makes KV memory a real, countable resource instead:

* `BlockAllocator` — a free list over `n_blocks` fixed-size blocks; every
  admitted request allocates `ceil(tokens / block_tokens)` blocks up front
  and the pool's `free_blocks` is what schedulers observe as
  `ClusterView.kv_free_blocks`.
* `PageTable` — one request's physical block ids, in logical order. Padded
  to any length with block 0 it is exactly the `block_tables` row the
  `paged_attention` kernel gathers through.
* `PagedKVCache` — the pool's storage side: for every cache-tree leaf with
  a sequence axis it keeps a `(n_blocks, block_tokens, ...)` pool and can
  scatter a slot's dense per-request cache into that request's pages
  (`store`, at eviction) and gather it back into a dense slot cache
  (`load`, at resume) — which is what lets a preempted request re-enter
  *without re-running prefill*. Leaves with no sequence axis (SSM/conv
  states, rolling windows smaller than `max_seq`) are snapshotted wholesale
  in the returned state list; they are per-request O(1)-sized state, not
  paged memory.

Layout note: pool leaves keep each cache leaf's own layout with the
sequence axis split as `(block, block_tokens)` and moved to the front, so
`store`/`load` are pure reshapes plus one indexed scatter/gather — the
attention kernels never read these pools directly (the engine's compute
view stays the dense jitted cache); `repro.kernels.paged_attention` is the
kernel that *does* read a `(n_pool, Hkv, page, D)` pool through a page
table, for the TPU deployment where the pool is the only cache.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


class BlockAllocator:
    """Free-list allocator over a fixed pool of KV blocks."""

    def __init__(self, n_blocks: int):
        if n_blocks <= 0:
            raise ValueError(f"need a positive block pool, got {n_blocks}")
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._held = [False] * n_blocks

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def allocate(self, n: int) -> Optional[List[int]]:
        """`n` block ids, or None if the pool can't satisfy the request
        (callers treat that as admission back-pressure, not an error)."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._held[i] = True
        return ids

    def free(self, ids: List[int]) -> None:
        for i in ids:
            if not self._held[i]:
                raise ValueError(f"double free of KV block {i}")
            self._held[i] = False
            self._free.append(i)


@dataclasses.dataclass
class PageTable:
    """One request's pages: physical block ids in logical order."""

    blocks: List[int]
    block_tokens: int

    @property
    def capacity_tokens(self) -> int:
        return len(self.blocks) * self.block_tokens

    def padded(self, n_pages: int) -> List[int]:
        """Block-table row for the paged kernel: padded with page 0 (the
        kernel masks padded pages via valid_len)."""
        assert n_pages >= len(self.blocks), (n_pages, len(self.blocks))
        return self.blocks + [0] * (n_pages - len(self.blocks))


@dataclasses.dataclass
class KVSnapshot:
    """What an evicted request keeps besides its pages: the unpaged state
    leaves and the decode cursor, enough to resume without re-prefill."""

    state: List[Any]          # non-sequence cache leaves, flat order
    position: int             # next cache write position
    cur_token: int            # last sampled token (next decode input)


def blocks_needed(n_tokens: int, block_tokens: int) -> int:
    """Blocks covering `n_tokens` of KV (minimum one — even an empty
    request owns its first page)."""
    return max(1, math.ceil(n_tokens / block_tokens))


class PagedKVCache:
    """Block-pool storage for one engine's KV cache."""

    def __init__(self, cfg: ModelConfig, n_blocks: int, block_tokens: int,
                 max_seq: int, dtype=None):
        if max_seq % block_tokens:
            raise ValueError(
                f"block_tokens={block_tokens} must divide max_seq={max_seq}")
        self.cfg = cfg
        self.block_tokens = block_tokens
        self.max_seq = max_seq
        self.allocator = BlockAllocator(n_blocks)
        # Probe which leaves carry the sequence axis: leaves whose shape
        # changes with max_seq are paged; the rest (recurrent states, conv
        # buffers, rolling windows < max_seq) are snapshot-wholesale state.
        shape_a = jax.eval_shape(
            lambda: M.init_cache(cfg, 1, max_seq, dtype=dtype))
        shape_b = jax.eval_shape(
            lambda: M.init_cache(cfg, 1, max_seq // 2, dtype=dtype))
        flat_a, self._treedef = jax.tree.flatten(shape_a)
        flat_b, _ = jax.tree.flatten(shape_b)
        self._seq_axis: List[Optional[int]] = []
        self._pools: List[Optional[jnp.ndarray]] = []
        for a, b in zip(flat_a, flat_b):
            axis = next((i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                         if x != y), None)
            if axis is not None and a.shape[axis] != max_seq:
                axis = None       # seq-dependent but not max_seq-sized
            self._seq_axis.append(axis)
            if axis is None:
                self._pools.append(None)
                continue
            rest = a.shape[:axis] + a.shape[axis + 1:]
            self._pools.append(jnp.zeros(
                (n_blocks, block_tokens) + rest, a.dtype))

    # ------------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return self.allocator.n_blocks

    @property
    def free_blocks(self) -> int:
        return self.allocator.free_blocks

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_needed(min(n_tokens, self.max_seq), self.block_tokens)

    def allocate(self, n_tokens: int) -> Optional[PageTable]:
        ids = self.allocator.allocate(self.blocks_for(n_tokens))
        if ids is None:
            return None
        return PageTable(blocks=ids, block_tokens=self.block_tokens)

    def free(self, table: PageTable) -> None:
        self.allocator.free(table.blocks)
        table.blocks = []

    # ------------------------------------------------------------------
    def store(self, table: PageTable, slot_cache) -> List[Any]:
        """Scatter a dense single-slot cache into `table`'s pages.

        Only the table's `capacity_tokens` prefix of each sequence leaf is
        persisted (the request can never have written beyond it). Returns
        the non-sequence state leaves for the caller's `KVSnapshot`."""
        flat = self._flatten(slot_cache)
        ids = jnp.asarray(table.blocks, jnp.int32)
        span = table.capacity_tokens
        state: List[Any] = []
        for i, leaf in enumerate(flat):
            axis = self._seq_axis[i]
            if axis is None:
                state.append(leaf)
                continue
            lead = jnp.moveaxis(leaf, axis, 0)[:span]
            pages = lead.reshape((len(table.blocks), self.block_tokens)
                                 + lead.shape[1:])
            self._pools[i] = self._pools[i].at[ids].set(pages)
        return state

    def load(self, table: PageTable, state: List[Any]):
        """Gather `table`'s pages back into a dense single-slot cache.

        Sequence positions past the table's span are zeros; decode masks
        them by position exactly as it masks never-written tail slots."""
        ids = jnp.asarray(table.blocks, jnp.int32)
        flat: List[Any] = []
        state_it = iter(state)
        for i, axis in enumerate(self._seq_axis):
            if axis is None:
                flat.append(next(state_it))
                continue
            pool = self._pools[i]
            pages = pool[ids]                       # (nb, bt, *rest)
            lead = pages.reshape((-1,) + pages.shape[2:])
            rest = pool.shape[2:]
            full = jnp.zeros((self.max_seq,) + rest, pool.dtype)
            full = full.at[: lead.shape[0]].set(lead)
            flat.append(jnp.moveaxis(full, 0, axis))
        return jax.tree.unflatten(self._treedef, flat)

    def _flatten(self, slot_cache) -> List[Any]:
        flat, treedef = jax.tree.flatten(slot_cache)
        if treedef != self._treedef:
            raise ValueError(
                f"slot cache tree {treedef} does not match the pool's "
                f"{self._treedef}")
        return flat
