"""PerLLMServer: the paper's system as a deployable service object.

Owns N `ServingEngine`s (the edge/cloud fleet), a scheduling policy and a
cluster spec; callers `submit()` requests with deadlines and `step()` the
service. Scheduling decisions route requests to a concrete engine, real
prefill/decode runs there, and realized latencies feed the learner — the
full loop of Fig. 3 in one class.

Scheduling goes through the same `SchedulingPolicy` API as the simulator:
each `step()` builds a `ClusterView` from *real* fleet state — persistent
per-server uplink occupancy, the link bandwidth model's current factor, and
engine batch-lane occupancy — and `drive_slot` applies every `Decision`'s
residual accounting. The learner therefore sees the same observation
surface in the live server as in the simulator (previously the live view
was degenerate: unit bandwidth factors and no uplink state).

Time handling: the server runs on a logical clock advanced by `step()`;
each engine-step costs its server's analytic per-step latency, so the
learner sees the same cost surface the cluster simulator models while the
tokens themselves are produced by the real models.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.network import BandwidthModel
from repro.cluster.server import ServerSpec
from repro.cluster.simulator import Outcome
from repro.cluster.workload import ServiceRequest, classify
from repro.core.api import ClusterView, Decision, as_policy, drive_slot
from repro.core.scheduler import PerLLMScheduler
from repro.serving.engine import Request, ServingEngine


@dataclasses.dataclass
class ServedRequest:
    service: ServiceRequest
    engine_req: Optional[Request] = None
    server: int = -1
    submitted_clock: float = 0.0
    done_clock: float = -1.0
    decision: Optional[Decision] = None
    tx_time: float = 0.0          # uplink occupancy charged at routing time

    @property
    def done(self) -> bool:
        return self.done_clock >= 0

    @property
    def latency(self) -> float:
        return self.done_clock - self.submitted_clock if self.done else -1.0

    @property
    def met_deadline(self) -> bool:
        return self.done and self.latency <= self.service.deadline


class PerLLMServer:
    def __init__(self, specs: Sequence[ServerSpec],
                 engines: Sequence[ServingEngine],
                 scheduler=None, slot: float = 0.5,
                 bandwidth: Optional[BandwidthModel] = None):
        assert len(specs) == len(engines)
        self.specs = list(specs)
        self.engines = list(engines)
        self.scheduler = scheduler or PerLLMScheduler(len(specs))
        self.policy = as_policy(self.scheduler)
        self.bandwidth = bandwidth or BandwidthModel()
        self.slot = slot
        self.clock = 0.0
        # real uplink occupancy: advanced by each committed Decision,
        # shared across steps (the fleet's links are stateful)
        self.uplink_free_at = [0.0] * len(specs)
        self._sid = itertools.count()
        self._pending: List[ServedRequest] = []
        # routed but held back by Decision.defer_until (deferred batching):
        # the runtime — not the policy — applies the deferral
        self._deferred: List[ServedRequest] = []
        self.active: Dict[int, ServedRequest] = {}
        self.completed: List[ServedRequest] = []

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               deadline: float = 4.0,
               payload_bytes: float = 1e6) -> ServedRequest:
        svc = ServiceRequest(
            sid=next(self._sid), arrival=self.clock,
            prompt_tokens=len(prompt), output_tokens=max_new_tokens,
            deadline=deadline, payload_bytes=payload_bytes)
        svc.class_id = classify(svc)
        sr = ServedRequest(service=svc, submitted_clock=self.clock)
        sr._prompt = list(prompt)
        self._pending.append(sr)
        return sr

    def _view(self) -> ClusterView:
        """Snapshot real fleet state for the policy: live uplink residuals,
        the bandwidth model's current per-link factor, and engine batch-lane
        occupancy."""
        t_slot = int(self.clock / self.slot)
        lane_free = []
        for j, eng in enumerate(self.engines):
            spec = self.specs[j]
            busy = len(eng.active_slots) + len(eng.queue)
            lanes = [0.0] * spec.max_concurrency
            step_t = spec.decode_step_time()
            for i in range(min(busy, spec.max_concurrency)):
                lanes[i] = self.clock + 8 * step_t  # coarse occupancy
            lane_free.append(lanes)
        return ClusterView(
            t=self.clock, specs=self.specs,
            bw_factor=[self.bandwidth.factor(t_slot, j)
                       for j in range(len(self.specs))],
            uplink_free_at=list(self.uplink_free_at),
            lane_free=lane_free)

    # ------------------------------------------------------------------
    def _dispatch(self, sr: ServedRequest) -> None:
        sr.engine_req = self.engines[sr.server].submit(
            sr._prompt, max_new_tokens=sr.service.output_tokens)
        self.active[sr.service.sid] = sr

    def step(self) -> int:
        """Route pending requests, advance every engine one decode step."""
        # dispatch deferred requests whose batching window has arrived
        held = []
        for sr in self._deferred:
            if sr.decision.defer_until <= self.clock:
                self._dispatch(sr)
            else:
                held.append(sr)
        self._deferred = held

        if self._pending:
            view = self._view()
            batch = self._pending
            self._pending = []
            decisions = drive_slot(
                self.policy, [sr.service for sr in batch], view,
                int(self.clock / self.slot))
            # persist the committed uplink residuals: the fleet's links
            # stay occupied across steps
            self.uplink_free_at = list(view.uplink_free_at)
            for sr, d in zip(batch, decisions):
                j = d.server
                sr.server = j
                sr.decision = d
                spec = self.specs[j]
                sr.tx_time = sr.service.payload_bytes * 8.0 \
                    / (spec.bandwidth * view.bw_factor[j])
                if d.defer_until > self.clock:
                    self._deferred.append(sr)
                else:
                    self._dispatch(sr)

        n_active = 0
        for j, eng in enumerate(self.engines):
            before = {r.rid for r in eng.completed}
            n_active += eng.step()
            for r in eng.completed:
                if r.rid in before:
                    continue
                for sr in list(self.active.values()):
                    if sr.engine_req is r:
                        self._finish(sr)
        # logical time: the slowest engine's decode step dominates the tick
        self.clock += max(self.specs[j].decode_step_time()
                          for j in range(len(self.specs)))
        return n_active

    def _finish(self, sr: ServedRequest) -> None:
        sr.done_clock = self.clock
        spec = self.specs[sr.server]
        t_inf = spec.service_time(sr.service.prompt_tokens,
                                  sr.service.output_tokens)
        energy = (((spec.power_active - spec.power_idle)
                   / spec.max_concurrency) * t_inf
                  + spec.tx_power * sr.tx_time)
        out = Outcome(server=sr.server, tx_time=sr.tx_time, queue_time=0.0,
                      infer_time=t_inf, finish=sr.done_clock,
                      processing_time=sr.latency,
                      success=sr.met_deadline, energy=energy)
        self.policy.feedback(sr.service, out)
        self.completed.append(sr)
        del self.active[sr.service.sid]

    def run_until_idle(self, max_steps: int = 10_000) -> List[ServedRequest]:
        for _ in range(max_steps):
            if not self._pending and not self._deferred and not self.active:
                break
            self.step()
        return self.completed

    # ------------------------------------------------------------------
    @property
    def stats(self) -> dict:
        done = self.completed
        if not done:
            return {"served": 0}
        lat = np.array([sr.latency for sr in done])
        return {
            "served": len(done),
            "deadline_met": float(np.mean([sr.met_deadline for sr in done])),
            "mean_latency": float(lat.mean()),
            "per_server": np.bincount(
                [sr.server for sr in done],
                minlength=len(self.specs)).tolist(),
        }
