"""PerLLMServer: the paper's system as a deployable service object.

Owns N `ServingEngine`s (the edge/cloud fleet), a scheduling policy and a
cluster spec; callers `submit()` requests with deadlines and `step()` the
service. Scheduling decisions route requests to a concrete engine, real
prefill/decode runs there, and realized latencies feed the learner — the
full loop of Fig. 3 in one class.

The server is a `repro.core.runtime.Runtime`: the same event loop that
drives the simulator drives the fleet. Each submission becomes an `Arrival`
event; routing builds a *fresh* `ClusterView` at the arrival's timestamp
from real state — persistent uplink occupancy, the link bandwidth model's
current factor, and per-engine batch-lane occupancy derived from each
active request's **actual remaining decode tokens** (plus nominal bookings
for queued/in-flight work). Transmission completes as a `TxDone` event that
hands the request to the engine; each engine advances on its own
`InferStart` tick cadence (one real `ServingEngine.step` per tick, costing
that server's analytic per-step latency) instead of a fleet-wide lock-step
clock, so a fast edge is never held hostage to the cloud's step time.
Realized completions report the true transmission/queue/inference split and
energy from the realized inference window, so the live learner's feedback
matches the simulator's semantics.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.network import BandwidthModel, LinkStateMixin, LinkTopology
from repro.cluster.server import ServerSpec
from repro.cluster.simulator import Outcome, rejected_outcome
from repro.cluster.workload import ServiceRequest, classify
from repro.core.api import NOMINAL, ClusterView, Decision, RunningTask
from repro.core.runtime import (
    Arrival, BandwidthChange, InferStart, KvMigrate, Preempt, Reject,
    Runtime, TxDone,
)
from repro.core.scheduler import PerLLMScheduler
from repro.obs.metrics import MetricsRegistry, counter_attr, with_aliases
from repro.obs.trace import (
    KIND_ARRIVAL, KIND_DECISION, KIND_MIGRATE, KIND_PREEMPT,
    KIND_REJECT, KIND_RESUME,
)
from repro.serving.engine import Request, ServingEngine


@dataclasses.dataclass
class ServedRequest:
    service: ServiceRequest
    engine_req: Optional[Request] = None
    server: int = -1
    submitted_clock: float = 0.0
    done_clock: float = -1.0
    decision: Optional[Decision] = None
    tx_time: float = 0.0          # arrival -> uplink transfer complete
    tx_dur: float = 0.0           # pure transfer duration (energy basis)
    dispatch_clock: float = -1.0  # entered the engine (TxDone)
    admit_clock: float = -1.0     # admitted to a batch lane (prefill start)
    # KV-preserving preemption: (server, evicted engine Request) whose
    # pages + snapshot survive on that engine until rerouting resolves
    evicted: Optional[tuple] = None

    @property
    def done(self) -> bool:
        return self.done_clock >= 0

    @property
    def latency(self) -> float:
        return self.done_clock - self.submitted_clock if self.done else -1.0

    @property
    def met_deadline(self) -> bool:
        return self.done and self.latency <= self.service.deadline


class PerLLMServer(Runtime, LinkStateMixin):
    # fleet counters live in the metrics registry (one canonical key
    # namespace with SimResult.stats()); `+= 1` call sites are unchanged
    n_preempted = counter_attr("n_preempted")
    n_kv_migrations = counter_attr("n_kv_migrations")
    kv_migrated_bytes = counter_attr("kv_migrated_bytes")

    def __init__(self, specs: Sequence[ServerSpec],
                 engines: Sequence[ServingEngine],
                 scheduler=None, slot: float = 0.5,
                 bandwidth: Optional[BandwidthModel] = None,
                 topology: Optional[LinkTopology] = None,
                 trace=None):
        assert len(specs) == len(engines)
        self.scheduler = scheduler or PerLLMScheduler(len(specs))
        super().__init__(self.scheduler, trace=trace)
        self.metrics = MetricsRegistry()
        if trace is not None \
                and getattr(self.scheduler, "bandit", None) is not None:
            # the bandit stamps ARM rows into the same recorder
            self.scheduler.bandit.trace = trace
        self.specs = list(specs)
        self.engines = list(engines)
        self.bandwidth = bandwidth or BandwidthModel()
        # the fleet's network: named links + per-server paths (defaults to
        # the degenerate one-private-link-per-server legacy model); link
        # occupancy is advanced by each dispatched request and shared
        # across steps (the fleet's links are stateful), `uplink_free_at`
        # mirrors each server's path for observers
        self.init_link_state(topology
                             or LinkTopology.degenerate(self.specs,
                                                        self.bandwidth))
        assert self.topology.n_servers == len(self.specs)
        # `slot` survives only as the bandwidth model's sampling cadence;
        # execution itself is event-driven
        self.slot = slot
        # per-slot factor cache: the factor the policy observed in a view
        # is the factor dispatch realizes (a fluctuating model's RNG
        # advances per draw, so repeated draws would diverge)
        self._factor_cache = (-1, {n: 1.0 for n in self.topology.links})
        self.uplink_free_at = [0.0] * len(specs)
        # per-engine logical clocks: each engine ticks at its own analytic
        # decode-step cadence, driven by InferStart events
        self.engine_clock = [0.0] * len(specs)
        # server-level DVFS state: the tier each host currently runs at.
        # A Decision's `alloc.freq_tier` retunes the target host at
        # dispatch; ticks then cost decode_step_time(tier) — scheduler-
        # chosen tiers mapped onto real decode-step pacing.
        self.engine_tier = [s.nominal_tier for s in self.specs]
        self._tick_scheduled = [False] * len(specs)
        # completion cursor per engine: eng.completed is append-only, so
        # each tick only inspects the new tail
        self._completed_seen = [0] * len(specs)
        self._idle_tick = min(s.decode_step_time() for s in self.specs)
        self._sid = itertools.count()
        self._by_sid: Dict[int, ServedRequest] = {}
        self._pending: List[ServedRequest] = []
        # routed but held back by Decision.defer_until (deferred batching):
        # the runtime — not the policy — applies the deferral
        self._deferred: List[ServedRequest] = []
        self.active: Dict[int, ServedRequest] = {}
        self.completed: List[ServedRequest] = []
        self.rejected: List[ServedRequest] = []
        self.n_preempted = 0
        self.n_kv_migrations = 0
        self.kv_migrated_bytes = 0.0

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               deadline: float = 4.0,
               payload_bytes: float = 1e6) -> ServedRequest:
        svc = ServiceRequest(
            sid=next(self._sid), arrival=self.clock,
            prompt_tokens=len(prompt), output_tokens=max_new_tokens,
            deadline=deadline, payload_bytes=payload_bytes)
        svc.class_id = classify(svc)
        sr = ServedRequest(service=svc, submitted_clock=self.clock)
        sr._prompt = list(prompt)
        self._by_sid[svc.sid] = sr
        self._pending.append(sr)
        self.loop.push(Arrival(self.clock, requests=(svc,)))
        return sr

    # ------------------------------------------------------------------
    # Runtime contract: fresh views from real fleet state
    # ------------------------------------------------------------------
    def _link_factors(self, t: float) -> Dict[str, float]:
        k = int(t / self.slot)
        if self._factor_cache[0] != k:
            self._factor_cache = (k, self.topology.factors(k))
        return self._factor_cache[1]

    def _bw_factor(self, t: float, j: int) -> float:
        return self.topology.server_factor(
            j, self.specs[j].bandwidth, self._link_factors(t),
            self.link_scale)

    def on_bandwidth_change(self, ev: BandwidthChange) -> None:
        self.apply_bandwidth_scales(ev)

    def build_view(self, t: float) -> ClusterView:
        """Snapshot real fleet state: live link residuals, the topology's
        current per-link factor, and batch-lane occupancy from each active
        request's actual remaining decode tokens (queued and in-transit
        requests stack on as nominal bookings). Engine-resident requests
        are exposed as `running` tasks so preemption-capable policies can
        name a `preempt_victim`."""
        factors = self._link_factors(t)
        lane_free = []
        running: List[List[RunningTask]] = []
        by_engine_req = {id(sr.engine_req): sr for sr in self.active.values()
                         if sr.engine_req is not None}
        for j, eng in enumerate(self.engines):
            spec = self.specs[j]
            tier = self.engine_tier[j]
            step_t = spec.decode_step_time(tier=tier)
            base = max(self.engine_clock[j], t)
            lanes = [t] * spec.max_concurrency
            tasks: List[RunningTask] = []
            for slot in eng.active_slots:
                r = eng.slot_req[slot]
                remaining = max(r.max_new_tokens - len(r.generated), 0)
                li = int(np.argmin(lanes))
                lanes[li] = base + remaining * step_t
                sr = by_engine_req.get(id(r))
                if sr is not None:
                    svc = sr.service
                    tasks.append(RunningTask(
                        sid=svc.sid, server=j, class_id=svc.class_id,
                        deadline_at=svc.arrival + svc.deadline,
                        begin=sr.admit_clock if sr.admit_clock >= 0 else t,
                        finish_est=lanes[li], tier=tier))
            for r in eng.queue:
                li = int(np.argmin(lanes))
                lanes[li] = max(lanes[li], base) + spec.service_time(
                    len(r.prompt), r.max_new_tokens, tier=tier)
            for sr in self.active.values():
                if sr.server == j and sr.engine_req is None:
                    li = int(np.argmin(lanes))
                    lanes[li] = max(lanes[li], sr.dispatch_clock) \
                        + spec.service_time(len(sr._prompt),
                                            sr.service.output_tokens,
                                            tier=tier)
            lane_free.append(lanes)
            running.append(tasks)
        topo = self.topology
        tier_kwargs = {}
        if any(s.n_tiers > 1 for s in self.specs):
            # per-server tier state: the committed lane-seconds above,
            # attributed to each host's current DVFS tier
            tier_load = [[0.0] * s.n_tiers for s in self.specs]
            for j, lanes in enumerate(lane_free):
                tier_load[j][self.engine_tier[j]] = \
                    sum(max(lf - t, 0.0) for lf in lanes)
            tier_kwargs = dict(tier_load=tier_load)
        kv_kwargs = {}
        if any(eng.paged for eng in self.engines):
            # paged engines expose their allocator's live free count; a
            # dense engine's 0-total entry marks KV as unmodeled there
            kv_kwargs = dict(
                kv_free_blocks=[eng.kv.free_blocks if eng.paged else 0
                                for eng in self.engines],
                kv_total_blocks=[eng.kv.n_blocks if eng.paged else 0
                                 for eng in self.engines])
        return ClusterView(
            t=t, specs=self.specs,
            bw_factor=[self._bw_factor(t, j)
                       for j in range(len(self.specs))],
            uplink_free_at=[topo.path_free_at(j, self.link_free)
                            for j in range(len(self.specs))],
            lane_free=lane_free,
            running=running,
            **tier_kwargs,
            **kv_kwargs,
            **self.link_view_kwargs(t, factors))

    def _view(self) -> ClusterView:
        """Deprecated alias: the view at the current clock."""
        return self.build_view(self.clock)

    def slot_index(self, t: float) -> int:
        return int(t / self.slot)

    # ------------------------------------------------------------------
    # Event handlers: route -> transmit -> engine ticks -> finish
    # ------------------------------------------------------------------
    def place(self, t: float, svc: ServiceRequest,
              decision: Decision) -> None:
        sr = self._by_sid[svc.sid]
        sr.server = decision.server
        sr.decision = decision
        self._pending.remove(sr)
        if self.trace is not None and (svc.preemptions
                                       or not decision.admit):
            # markers only for the non-implicit placements (requeues and
            # sheds) — mirrors the sim cores' _trace_decision semantics
            alloc = decision.alloc
            tier = alloc.freq_tier if alloc is not None else 0
            self.trace.append_rows((
                (KIND_ARRIVAL, svc.sid, t, t, -1, svc.class_id, 0, 0.0,
                 svc.preemptions, -1),
                (KIND_DECISION, svc.sid, t, t, decision.server,
                 svc.class_id, tier, 0.0, decision.admit, -1),
            ))
        super().place(t, svc, decision)

    def defer(self, t: float, when: float, svc: ServiceRequest,
              decision: Decision) -> None:
        self._deferred.append(self._by_sid[svc.sid])
        super().defer(t, when, svc, decision)

    def dispatch(self, t: float, svc: ServiceRequest,
                 decision: Decision) -> None:
        """Start the uplink transfer; the engine takes over at TxDone.
        The transfer serializes on every link of the server's path (a
        sub-unit `alloc.bw_share` stretches it by 1/share), and the
        Decision's DVFS tier retunes the target host's decode pacing."""
        sr = self._by_sid[svc.sid]
        if sr in self._deferred:
            self._deferred.remove(sr)
        j = decision.server
        spec = self.specs[j]
        alloc = decision.alloc
        tier = alloc.freq_tier if alloc.freq_tier >= 0 else spec.nominal_tier
        self.engine_tier[j] = tier
        self.engines[j].set_freq_scale(spec.tier_freq(tier))
        path = self.topology.paths[j]
        tx_start = max(t, self.topology.path_free_at(j, self.link_free))
        tx_dur = spec.tx_time(svc.payload_bytes,
                              self._bw_factor(t, j) * alloc.bw_share)
        for name in path:
            self.link_free[name] = tx_start + tx_dur
        self.uplink_free_at[j] = tx_start + tx_dur
        ready = tx_start + tx_dur
        sr.tx_dur = tx_dur
        sr.tx_time = ready - svc.arrival
        sr.dispatch_clock = ready
        self.active[svc.sid] = sr
        self.loop.push(TxDone(ready, request=svc, decision=decision))

    def on_reject(self, ev: Reject) -> None:
        """Admission control shed the submission: emit the rejected
        Outcome (SLO-violation cost, zero fleet energy) and retire it."""
        svc = ev.request
        sr = self._by_sid.pop(svc.sid)
        # a runtime-forced shed (e.g. pool-oversized at TxDone) may arrive
        # after dispatch already put the request in `active`
        self.active.pop(svc.sid, None)
        if sr.evicted is not None:
            # a previously evicted request shed on requeue: its preserved
            # pages would otherwise leak on the old engine
            old_j, old_req = sr.evicted
            sr.evicted = None
            svc.kv_server, svc.kv_blocks = -1, 0
            self.engines[old_j].release(old_req)
        sr.server = -1
        sr.decision = ev.decision
        if self.trace is not None:
            self.trace.append(
                KIND_REJECT, svc.sid, ev.time, ev.time,
                ev.decision.server if ev.decision is not None else -1,
                svc.class_id)
        self.policy.feedback(svc, rejected_outcome(svc, ev.decision,
                                                   ev.time))
        self.rejected.append(sr)

    def on_preempt(self, ev: Preempt) -> None:
        """Evict the victim from its engine and requeue its remaining
        decode tokens as a fresh Arrival.

        On a paged engine `ServingEngine.evict` snapshots the victim's KV
        into its pages; the evicted engine Request is kept on the
        `ServedRequest` so that, if the requeue routes back to the same
        server, `on_tx_done` resubmits it and decode resumes with zero
        re-prefill. `ev.drop_kv` (or rerouting elsewhere) releases the
        pages instead. Dense engines keep the legacy semantics: the KV
        dies with the slot and prefill is redone wherever the victim
        lands."""
        sr = self.active.get(ev.victim)
        if sr is None or sr.engine_req is None:
            return            # finished, rejected, or still in transit
        eng = self.engines[sr.server]
        r = sr.engine_req
        evicted_from_slot = r.slot >= 0
        if evicted_from_slot:
            # drop_kv skips the snapshot scatter — the pages are being
            # freed for memory, not preserved for a resume
            eng.evict(r.slot, keep_kv=not ev.drop_kv)
            remaining = max(r.max_new_tokens - len(r.generated), 1)
        elif r in eng.queue:
            eng.queue.remove(r)
            if eng.paged:
                eng.release(r)   # queued: pages (if allocated) go back
            # a queued victim may itself be a resubmitted continuation
            # with tokens already generated — only the remainder requeues
            remaining = max(r.max_new_tokens - len(r.generated), 1)
        else:
            return            # completing this very tick — too late
        svc = sr.service
        if eng.paged and evicted_from_slot and not ev.drop_kv:
            sr.evicted = (sr.server, r)
            svc.kv_server = sr.server
            svc.kv_blocks = len(r.pages.blocks)
        svc.output_tokens = remaining
        svc.preemptions += 1
        if self.trace is not None:
            # span covers the in-batch window burned so far (a point at
            # ev.time if the victim never reached a lane); value = tokens
            # left to requeue
            t0 = sr.admit_clock if sr.admit_clock >= 0 else ev.time
            self.trace.append(KIND_PREEMPT, svc.sid, t0, ev.time,
                              sr.server, svc.class_id,
                              self.engine_tier[sr.server], 0.0,
                              float(remaining))
        sr.engine_req = None
        sr.server = -1
        sr.decision = None
        sr.dispatch_clock = -1.0
        sr.admit_clock = -1.0
        del self.active[svc.sid]
        self._pending.append(sr)
        self.n_preempted += 1
        self.loop.push(Arrival(ev.time, requests=(svc,)))

    def _resolve_eviction(self, sr: ServedRequest, j: int):
        """Decide what a rerouted, previously evicted request keeps: its
        engine Request (same paged server — resume in place) or nothing
        (different server — release the stranded pages there)."""
        if sr.evicted is None:
            return None
        old_j, old_req = sr.evicted
        sr.evicted = None
        sr.service.kv_server = -1
        sr.service.kv_blocks = 0
        if old_j == j and self.engines[j].paged and old_req.kv is not None:
            return old_req
        self.engines[old_j].release(old_req)
        return None

    def _kv_compatible(self, src: int, dst: int) -> bool:
        """Can pages move between these engines byte-for-byte? Same model
        config and page geometry on two paged engines."""
        a, b = self.engines[src], self.engines[dst]
        return (a.paged and b.paged and a.cfg == b.cfg
                and a.kv.block_tokens == b.kv.block_tokens
                and a.max_seq == b.max_seq)

    def _start_migration(self, sr: ServedRequest, j: int,
                         t: float) -> bool:
        """Begin shipping `sr`'s preserved pages from their home engine to
        server `j`, if the Decision asked for it and the move is possible
        (compatible engines, destination pool has room). The transfer
        occupies the union of both servers' link paths at the bottleneck
        bandwidth — exactly the simulator's charging rule — and the engine
        handoff resumes at `KvMigrate`. False = fall through to the normal
        release-and-re-prefill path."""
        if sr.evicted is None or sr.decision is None \
                or not sr.decision.migrate_kv:
            return False
        old_j, old_req = sr.evicted
        if old_j == j or not self._kv_compatible(old_j, j):
            return False
        dst = self.engines[j]
        n_blocks = len(old_req.pages.blocks)
        if dst.kv.free_blocks < n_blocks:
            return False
        n_bytes = n_blocks * self.engines[old_j].kv.block_tokens \
            * float(self.engines[old_j].cfg.kv_bytes_per_token())
        path = self.topology.migration_path(old_j, j)
        bw = self.topology.migration_bandwidth(
            old_j, j, self._link_factors(t), self.link_scale)
        if not path or bw <= 0.0 or n_bytes <= 0.0:
            return False
        start = max(t, max(self.link_free[name] for name in path))
        end = start + n_bytes * 8.0 / bw
        for name in path:
            self.link_free[name] = end
        self.n_kv_migrations += 1
        self.kv_migrated_bytes += n_bytes
        if self.trace is not None:
            self.trace.append(KIND_MIGRATE, sr.service.sid, t, end, j,
                              sr.service.class_id, 0,
                              (end - t) * self.specs[old_j].tx_power,
                              n_bytes, self.trace.intern(f"{old_j}->{j}"))
        self.loop.push(KvMigrate(end, request=sr.service,
                                 decision=sr.decision,
                                 context=(old_j, j, old_req)))
        return True

    def on_kv_migrate(self, ev: KvMigrate) -> None:
        """Migrated pages landed on the destination engine: export them
        from the source pool, adopt them into the destination's, and
        resubmit the continuation there — decode resumes with zero
        re-prefill. If the destination pool filled while the pages were
        in flight, fall back to a fresh submit (full re-prefill)."""
        svc = ev.request
        old_j, j, old_req = ev.context
        src, dst = self.engines[old_j], self.engines[j]
        sr = self.active.get(svc.sid)
        if sr is None:
            src.release(old_req)     # retired while the pages were in flight
            return
        pages = src.kv.export(old_req.pages)
        table = dst.kv.import_pages(pages, len(old_req.pages.blocks))
        sr.evicted = None
        svc.kv_server, svc.kv_blocks = -1, 0
        if table is None:
            src.release(old_req)
            sr.engine_req = dst.submit(
                sr._prompt, max_new_tokens=svc.output_tokens)
        else:
            new_req = Request(rid=next(dst._rid),
                              prompt=list(old_req.prompt),
                              max_new_tokens=old_req.max_new_tokens,
                              eos_id=old_req.eos_id,
                              generated=list(old_req.generated),
                              pages=table, kv=old_req.kv)
            old_req.kv = None        # the snapshot moved with the pages
            src.release(old_req)
            sr.engine_req = dst.resubmit(new_req)
            svc.kv_server, svc.kv_blocks = j, len(table.blocks)
            if self.trace is not None:
                self.trace.append(KIND_RESUME, svc.sid, ev.time, ev.time,
                                  j, svc.class_id)
        self._ensure_tick(j, ev.time)

    def on_tx_done(self, ev: TxDone) -> None:
        sr = self.active[ev.request.sid]
        j = sr.server
        eng = self.engines[j]
        if self._start_migration(sr, j, ev.time):
            return    # pages in flight: KvMigrate finishes the handoff
        resumable = self._resolve_eviction(sr, j)
        if resumable is not None:
            # KV-preserving requeue: reattach the evicted Request — its
            # page table and snapshot skip the prefill entirely
            sr.engine_req = eng.resubmit(resumable)
            if self.trace is not None:
                self.trace.append(KIND_RESUME, sr.service.sid, ev.time,
                                  ev.time, j, sr.service.class_id)
        elif eng.paged and eng.kv.blocks_for(
                len(sr._prompt) + sr.service.output_tokens) \
                > eng.kv.n_blocks:
            # the engine's whole pool can't hold this request — a KV-blind
            # policy routed it; shed it instead of crashing the loop
            self.handle(Reject(ev.time, request=ev.request,
                               decision=sr.decision))
            return
        else:
            sr.engine_req = eng.submit(
                sr._prompt, max_new_tokens=sr.service.output_tokens)
        self._ensure_tick(j, ev.time)

    def _ensure_tick(self, j: int, t: float) -> None:
        if not self._tick_scheduled[j]:
            self._tick_scheduled[j] = True
            self.loop.push(InferStart(max(t, self.engine_clock[j]),
                                      server=j))

    def on_infer_start(self, ev: InferStart) -> None:
        """One engine tick: admit + one real decode step on engine j,
        costing that server's analytic per-step latency at the host's
        current DVFS tier (a slow tier stretches each tick by 1/f)."""
        j = ev.server
        eng = self.engines[j]
        self._tick_scheduled[j] = False
        eng.step()
        t_end = ev.time + self.specs[j].decode_step_time(
            tier=self.engine_tier[j])
        self.engine_clock[j] = t_end
        self.clock = max(self.clock, t_end)
        for sr in self.active.values():
            if (sr.server == j and sr.engine_req is not None
                    and sr.admit_clock < 0 and sr.engine_req.slot >= 0):
                sr.admit_clock = ev.time
        new_done = eng.completed[self._completed_seen[j]:]
        self._completed_seen[j] = len(eng.completed)
        for r in new_done:
            for sr in list(self.active.values()):
                if sr.engine_req is r:
                    self._finish(sr, t_end)
        if eng.queue or eng.active_slots:
            self._ensure_tick(j, t_end)

    def _finish(self, sr: ServedRequest, t: float) -> None:
        sr.done_clock = t
        spec = self.specs[sr.server]
        # realized split: transmission (uplink wait + transfer), lane wait
        # (engine queue until prefill admission), inference window.
        # DVFS is host-level (last dispatch retunes the host), so the
        # inference energy is billed at the tier the host is actually
        # running — the frequency that paced the realized window — not at
        # the request's own decision tier, which a later dispatch may have
        # overridden mid-flight; shares stay per-request.
        alloc = sr.decision.alloc if sr.decision is not None else NOMINAL
        admit = sr.admit_clock if sr.admit_clock >= 0 else sr.dispatch_clock
        queue_time = max(admit - sr.dispatch_clock, 0.0)
        infer_time = max(sr.done_clock - admit, 0.0)
        tier = self.engine_tier[sr.server]
        e_inf = spec.infer_energy(infer_time, tier=tier,
                                  lane_share=alloc.lane_share)
        e_tx = spec.tx_power * sr.tx_dur * alloc.bw_share
        out = Outcome(server=sr.server, tx_time=sr.tx_time,
                      queue_time=queue_time, infer_time=infer_time,
                      finish=sr.done_clock, processing_time=sr.latency,
                      success=sr.met_deadline, energy=e_inf + e_tx)
        if self.trace is not None:
            svc, trace = sr.service, self.trace
            trace.complete(svc.sid, svc.arrival, sr.dispatch_clock,
                           admit, sr.done_clock, sr.server,
                           svc.class_id, tier, -1, e_tx, e_inf,
                           svc.output_tokens, sr.met_deadline)
        self.policy.feedback(sr.service, out)
        self.completed.append(sr)
        del self.active[sr.service.sid]
        del self._by_sid[sr.service.sid]

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Process the next event on the runtime loop (an arrival batch, a
        dispatch window, an uplink completion, or one engine's decode
        tick). With nothing scheduled the clock idles forward one minimal
        engine tick."""
        if not self.loop:
            self.clock += self._idle_tick
            return 0
        self.handle(self.loop.pop())
        return sum(len(e.active_slots) for e in self.engines)

    def run_until_idle(self,
                       max_steps: int = 1_000_000) -> List[ServedRequest]:
        """Drain the service. `max_steps` counts *events* (finer-grained
        than the old fleet-wide steps: each engine tick, transfer
        completion and routing is one step), so the default budget is a
        runaway backstop, not a workload bound."""
        for _ in range(max_steps):
            if not self._pending and not self._deferred and not self.active:
                break
            self.step()
        return self.completed

    # ------------------------------------------------------------------
    @property
    def stats(self) -> dict:
        """Canonical fleet stats (one key namespace with
        ``SimResult.stats()``), plus the deprecated pre-unification
        spellings via :func:`repro.obs.metrics.with_aliases` — old
        readers of e.g. ``served`` / ``deadline_met`` keep working for
        one release. The same values land in ``self.metrics``."""
        done = self.completed
        m = self.metrics
        m.put_scalar("n_served", len(done))
        m.put_scalar("n_rejected", len(self.rejected))
        if not done:
            return with_aliases({"n_served": 0,
                                 "n_rejected": len(self.rejected),
                                 "n_preempted": self.n_preempted})
        lat = np.array([sr.latency for sr in done])
        per_server = np.bincount([sr.server for sr in done],
                                 minlength=len(self.specs)).tolist()
        stats = {
            "n_served": len(done),
            "n_rejected": len(self.rejected),
            "n_preempted": self.n_preempted,
            "n_kv_migrations": self.n_kv_migrations,
            "kv_migrated_bytes": self.kv_migrated_bytes,
            "n_prefills": sum(e.n_prefills for e in self.engines),
            "n_prefix_hits": sum(e.n_prefix_hits for e in self.engines),
            "kv_prefill_tokens_saved": sum(e.prefix_tokens_reused
                                           for e in self.engines),
            "admitted_success_rate": float(np.mean([sr.met_deadline
                                                    for sr in done])),
            "avg_processing_time": float(lat.mean()),
            "per_server_served": per_server,
        }
        for key in ("n_prefills", "n_prefix_hits",
                    "kv_prefill_tokens_saved"):
            m.put_scalar(key, stats[key])
        for j, n in enumerate(per_server):
            m.put("per_server_served", n, server=j)
        m.set_gauge("admitted_success_rate",
                    stats["admitted_success_rate"])
        m.set_gauge("avg_processing_time", stats["avg_processing_time"])
        return with_aliases(stats)
