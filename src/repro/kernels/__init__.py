"""Pallas TPU kernels for the serving hot paths (+ pure-jnp oracles).

flash_attention — prefill/train attention, online softmax, BlockSpec-tiled.
decode_attention — flash-decode against long KV caches.
paged_attention — flash-decode over non-contiguous KV pages (page-table
    indirection via scalar prefetch; the paged serving engine's kernel).
ref — the jnp oracles every kernel is allclose-tested against.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
