"""Flash-decode: single-token attention against a long KV cache (Pallas TPU).

One query row per (batch, q-head); the KV sequence is the innermost grid
axis with online-softmax state carried in VMEM scratch. Because slot order
is irrelevant (keys are rotated before caching), the same kernel serves both
linear caches (`valid = slot < pos+1`) and rolling sliding-window caches
(`valid = slot < min(pos+1, W)`); the wrapper picks `valid_len`.

TPU notes: the query row is broadcast against (block_k, D) KV tiles — the
contraction is a (1×D)·(D×block_k) VPU/MXU matvec per tile; block_k=512
keeps ≥4 lanes of 128 busy. Per-(b, h) state is 2 scalars + a D-vector in
VMEM; HBM traffic is exactly one read of the valid cache prefix, which is
the roofline floor for decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(vl_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int, n_kv_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    slot = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    valid = slot < vl_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret"))
def decode_attention(q, k, v, valid_len, *, scale: float = 1.0,
                     block_k: int = 512, interpret: bool = False):
    """q: (B, Hq, D); k, v: (B, Hkv, S, D); valid_len: scalar int32.

    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    g = hq // hkv
    block_k = min(block_k, s)
    assert s % block_k == 0, (s, block_k)
    n_kv = s // block_k
    grid = (b, hq, n_kv)

    q4 = q[:, :, None, :]     # (B, Hq, 1, D) so blocks are 2D tiles
    vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (1,))

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               n_kv_blocks=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, d), lambda b_, h, ki: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, ki, g=g: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, ki, g=g: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b_, h, ki: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(vl, q4, k, v)
    return out[:, :, 0, :]
