"""SSD within-chunk (diagonal-block) kernel — the Mamba-2 compute hotspot.

The chunked SSD decomposition's quadratic-in-chunk term
    Y_diag[i] = Σ_{j ≤ i} (C_i·B_j) · exp(cum_i − cum_j) · dt_j · x_j
is the part the Mamba-2 paper hand-writes CUDA kernels for. TPU adaptation:
one grid cell per (batch, chunk, head) computes two MXU matmuls
(scores = C·Bᵀ, then the masked-decay-weighted (Q,Q)·(Q,P) product) with the
whole working set — (Q,N) + (Q,N) + (Q,P) + (Q,Q) ≈ 0.6 MB f32 at
Q=256, N=128, P=64 — resident in VMEM. The inter-chunk recurrence (linear,
sequential) stays in jnp (`repro.models.ssm`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_diag_kernel(a_log_ref, x_ref, dt_ref, b_ref, c_ref, o_ref, *,
                     chunk: int):
    h = pl.program_id(2)
    dt = dt_ref[0, :, 0].astype(jnp.float32)            # (Q,)
    a = dt * a_log_ref[h]                                # log-decay incr.
    cum = jnp.cumsum(a)
    x = x_ref[0, :, 0, :].astype(jnp.float32)            # (Q, P)
    bm = b_ref[0].astype(jnp.float32)                    # (Q, N)
    cm = c_ref[0].astype(jnp.float32)                    # (Q, N)

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    decay = cum[:, None] - cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(kj <= qi, jnp.exp(decay), 0.0)
    w = scores * lmat                                    # (Q, Q)
    dtx = dt[:, None] * x                                # (Q, P)
    y = jax.lax.dot_general(w, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0, :, 0, :] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_diag(x, dt, A, Bm, Cm, *, chunk: int = 256,
             interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H) post-softplus; A: (H,) negative;
    Bm/Cm: (B,S,N). Returns the diagonal-block output (B,S,H,P) f32."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (b, nc, h)

    kernel = functools.partial(_ssd_diag_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # A (H,)
            pl.BlockSpec((1, chunk, 1, p),
                         lambda b_, c, h_: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda b_, c, h_: (b_, c, h_)),
            pl.BlockSpec((1, chunk, n), lambda b_, c, h_: (b_, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, c, h_: (b_, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda b_, c, h_: (b_, c, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), jnp.float32),
        interpret=interpret,
    )(A, x, dt, Bm, Cm)
