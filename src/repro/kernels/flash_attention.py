"""Flash attention (prefill/train) as a Pallas TPU kernel.

TPU adaptation of the FlashAttention online-softmax algorithm:
  * Block sizes default to (128, 128) so the QK^T and PV contractions are
    MXU-aligned (128-multiples) and the working set
    (q_blk + k_blk + v_blk + acc ≈ 4·128·D·4B) fits comfortably in the
    ~16 MiB VMEM budget for head_dim ≤ 256.
  * The KV dimension is the innermost ("arbitrary") grid axis; the running
    (m, l, acc) state lives in VMEM scratch and is carried across KV steps —
    HBM traffic is O(S·D) per Q block, never O(S²).
  * Causal/sliding-window masking is applied with block-level iota; fully
    out-of-horizon KV blocks still run (masked) — grid pruning for them is a
    recorded §Perf candidate, not needed for correctness.

GQA is expressed through the BlockSpec index_map: the KV block index maps the
query head h to kv head h // group — no KV replication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, q_offset: int,
                  block_q: int, block_k: int, n_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)             # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)             # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "scale", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, scale: float = 1.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    n_kv = sk // block_k
    grid = (b, hq, sq // block_q, n_kv)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_kv_blocks=n_kv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, g=g: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, g=g: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=_scratch(block_q, d),
        interpret=interpret,
    )(q, k, v)


def _scratch(block_q: int, d: int):
    """(m, l, acc) running-softmax state in VMEM."""
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32)]
