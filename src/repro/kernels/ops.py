"""jit'd public wrappers around the Pallas kernels.

`flash_attention` here accepts the model-layout tensors
(B, S, H, D) and handles transposition + CPU fallback:
on a CPU backend Pallas-TPU cannot lower, so kernels run in interpret mode
when `interpret=None` (auto) and the backend is CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _paged
from repro.kernels import ref as _ref


@functools.lru_cache(maxsize=None)
def _backend_is_cpu() -> bool:
    # the backend cannot change within a process; probing it resolves the
    # whole JAX platform stack, so pay that once, not per kernel call
    return jax.default_backend() == "cpu"


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return _backend_is_cpu()
    if not interpret and _backend_is_cpu():
        raise RuntimeError(
            "Pallas-TPU lowering is unavailable on the CPU backend but "
            "interpret=False was forced; pass interpret=None (auto) or "
            "interpret=True to run the kernel in interpret mode")
    return bool(interpret)


def flash_attention(q, k, v, *, mask=None, causal: bool = True,
                    window: int = 0, q_offset: int = 0, scale: float = 1.0,
                    interpret: Optional[bool] = None):
    """Model-layout flash attention. q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D).

    `mask` is accepted for API-compatibility with the jnp path but must be
    expressible as (causal, window, q_offset) — the kernel computes masking
    from block iota, it never materializes an (Sq, Sk) mask.
    """
    del mask
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention(
        qt, kt, vt, causal=causal, window=window, q_offset=q_offset,
        scale=scale, interpret=_auto_interpret(interpret))
    return jnp.swapaxes(out, 1, 2)


def decode_attention(q, k, v, valid_len, *, scale: float = 1.0,
                     interpret: Optional[bool] = None):
    """q: (B, 1, Hq, D) or (B, Hq, D); k/v: (B, S, Hkv, D)."""
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _dec.decode_attention(q, kt, vt, valid_len, scale=scale,
                                interpret=_auto_interpret(interpret))
    return out[:, None] if squeeze else out


def paged_attention(q, k_pages, v_pages, block_tables, valid_len, *,
                    scale: float = 1.0, interpret: Optional[bool] = None):
    """Decode attention over a paged KV pool.

    q: (B, 1, Hq, D) or (B, Hq, D); k_pages/v_pages in model layout
    (n_pool, page_size, Hkv, D); block_tables: (B, n_pages) page ids
    (pad with 0); valid_len: scalar or (B,) valid tokens per request.
    """
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    kt = jnp.swapaxes(k_pages, 1, 2)
    vt = jnp.swapaxes(v_pages, 1, 2)
    out = _paged.paged_attention(q, kt, vt, block_tables, valid_len,
                                 scale=scale,
                                 interpret=_auto_interpret(interpret))
    return out[:, None] if squeeze else out


# re-export oracles for tests/benchmarks
flash_attention_ref = _ref.flash_attention_ref
decode_attention_ref = _ref.decode_attention_ref
paged_attention_ref = _ref.paged_attention_ref
