"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references the kernel tests `assert_allclose`
against, and the path the model code uses on CPU (where Pallas-TPU cannot
lower). Signatures mirror `repro.kernels.ops`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, scale: float = 1.0):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). GQA via head grouping.

    Returns (B, Hq, Sq, D) in q.dtype; softmax in f32.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (ki <= qi)
    if window > 0:
        mask = mask & (ki > qi - window)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def ssd_diag_ref(x, dt, A, Bm, Cm, *, chunk: int = 256):
    """Oracle for the SSD diagonal-block kernel (pure jnp, per chunk)."""
    b, s, h, p = x.shape
    chunk = min(chunk, s)
    nc = s // chunk
    xr = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtr = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Br = Bm.reshape(b, nc, chunk, -1).astype(jnp.float32)
    Cr = Cm.reshape(b, nc, chunk, -1).astype(jnp.float32)
    a = dtr * A
    cum = jnp.cumsum(a, axis=2)
    dtx = dtr[..., None] * xr
    scores = jnp.einsum("bcqn,bckn->bcqk", Cr, Br)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    mask = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]
    lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    y = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", scores, lmat, dtx)
    return y.reshape(b, s, h, p)


def paged_attention_ref(q, k_pages, v_pages, block_tables, valid_len, *,
                        scale: float = 1.0):
    """Oracle for the paged decode kernel: gather each request's pages into
    a dense contiguous cache, then run plain decode attention.

    q: (B, Hq, D); k_pages, v_pages: (n_pool, Hkv, page_size, D);
    block_tables: (B, n_pages) physical page ids; valid_len: scalar or (B,).
    """
    b = q.shape[0]
    hkv, page_size, d = k_pages.shape[1:]
    # (B, n_pages, Hkv, page, D) -> (B, Hkv, n_pages*page, D)
    k = jnp.swapaxes(k_pages[block_tables], 1, 2).reshape(b, hkv, -1, d)
    v = jnp.swapaxes(v_pages[block_tables], 1, 2).reshape(b, hkv, -1, d)
    return decode_attention_ref(q, k, v, valid_len, scale=scale)


def decode_attention_ref(q, k, v, valid_len, *, scale: float = 1.0):
    """Single-step decode attention against a (possibly rolling) KV cache.

    q: (B, Hq, D); k, v: (B, Hkv, S, D); valid_len: scalar or (B,) — number
    of valid cache slots (slot order is irrelevant: keys are pre-rotated).
    """
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg, k.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, :] < jnp.asarray(valid_len).reshape(-1, 1)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)
