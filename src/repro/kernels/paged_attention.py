"""Paged flash-decode: single-token attention over non-contiguous KV pages.

Same online-softmax structure as `decode_attention`, with one extra level of
indirection: the KV cache lives in a shared block pool of fixed-size pages
(`k_pages`/`v_pages`: (n_pool, Hkv, page_size, D)), and each request's
logical cache is the sequence of physical pages named by its row of
`block_tables`. The page table and per-request valid lengths ride in as
scalar-prefetch operands (`pltpu.PrefetchScalarGridSpec`), so the BlockSpec
index map — not the kernel body — resolves logical block `ki` of batch row
`b` to physical page `block_tables[b, ki]`; Mosaic can then issue the page
DMA as early as any contiguous block fetch.

TPU notes: per (b, q-head) the query row is broadcast against one
(page_size, D) page tile at a time, identical math to the contiguous
kernel, so arithmetic intensity is unchanged; the only cost of paging is
potentially non-coalesced HBM pages, which is the deal paged serving
makes everywhere. Pages past a request's table length must still name a
real pool slot (pad tables with 0) — their scores are masked by
`valid_len` before they can contribute.

Runs in interpret mode on CPU like the other kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, vl_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
                  n_pages: int):
    del tables_ref          # consumed by the index maps, not the body
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (page, d)
    v = v_ref[0, 0].astype(jnp.float32)                 # (page, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    slot = ki * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = slot < vl_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, valid_len, *,
                    scale: float = 1.0, interpret: bool = False):
    """q: (B, Hq, D); k_pages, v_pages: (n_pool, Hkv, page_size, D);
    block_tables: (B, n_pages) int32 physical page ids (pad with 0);
    valid_len: (B,) or scalar int32 valid cache tokens per request.

    Returns (B, Hq, D). A `valid_len` of 0 is degenerate (softmax over a
    fully-masked row): the output is the uniform average of the row's V
    pages, exactly matching the jnp oracle and `decode_attention` — real
    requests always have >= 1 cached token.
    """
    b, hq, d = q.shape
    n_pool, hkv, page_size, _ = k_pages.shape
    assert v_pages.shape == k_pages.shape, (v_pages.shape, k_pages.shape)
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    assert block_tables.ndim == 2 and block_tables.shape[0] == b, \
        block_tables.shape
    n_pages = block_tables.shape[1]
    grid = (b, hq, n_pages)

    q4 = q[:, :, None, :]     # (B, Hq, 1, D) so blocks are 2D tiles
    tables = jnp.asarray(block_tables, jnp.int32)
    vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))

    kernel = functools.partial(_paged_kernel, scale=scale,
                               page_size=page_size, n_pages=n_pages)
    # scalar-prefetch refs arrive as trailing index-map args; logical page
    # ki of batch row b_ lives at physical pool slot tables[b_, ki]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda b_, h, ki, tbl, vl_: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h, ki, tbl, vl_, g=g:
                         (tbl[b_, ki], h // g, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h, ki, tbl, vl_, g=g:
                         (tbl[b_, ki], h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda b_, h, ki, tbl, vl_: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        interpret=interpret,
    )(tables, vl, q4, k_pages, v_pages)
    return out[:, :, 0, :]
