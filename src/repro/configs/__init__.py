from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    MLAConfig,
    ModelConfig,
    RGLRUConfig,
    SSMConfig,
    get_config,
    list_archs,
    register,
    shape_applicable,
)

ASSIGNED_ARCHS = (
    "mixtral-8x7b",
    "minicpm3-4b",
    "deepseek-moe-16b",
    "mamba2-2.7b",
    "qwen2-vl-2b",
    "gemma3-12b",
    "recurrentgemma-2b",
    "gemma-2b",
    "whisper-base",
    "gemma3-27b",
)

__all__ = [
    "ASSIGNED_ARCHS", "INPUT_SHAPES", "InputShape", "MLAConfig",
    "ModelConfig", "RGLRUConfig", "SSMConfig", "get_config", "list_archs",
    "register", "shape_applicable",
]
