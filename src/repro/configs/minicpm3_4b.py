"""MiniCPM3-4B — dense with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B]
"""
from repro.configs.base import MLAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="minicpm3-4b",
    family="dense",
    citation="hf:openbmb/MiniCPM3-4B",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    tie_embeddings=True,
    activation="swiglu",
))
