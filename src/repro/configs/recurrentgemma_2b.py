"""RecurrentGemma-2B (Griffin) — RG-LRU recurrent blocks + local attention, 1:2.

[arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig, RGLRUConfig, register

CONFIG = register(ModelConfig(
    arch_id="recurrentgemma-2b",
    family="hybrid",
    citation="arXiv:2402.19427",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    sliding_window=2048,
    rglru=RGLRUConfig(d_rnn=2560, d_conv=4,
                      block_pattern=("rec", "rec", "attn")),
    tie_embeddings=True,
    activation="geglu",
))
