"""The paper's own deployment models (PerLLM §4.1).

Edge: Yi-6B, LLaMA2-7B, LLaMA3-8B, Yi-9B. Cloud: LLaMA2-33B.
These drive the edge-cloud cluster cost model in `repro.cluster`.
"""
from repro.configs.base import ModelConfig, register

YI_6B = register(ModelConfig(
    arch_id="yi-6b", family="dense", citation="hf:01-ai/Yi-6B",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000, activation="swiglu",
))

LLAMA2_7B = register(ModelConfig(
    arch_id="llama2-7b", family="dense", citation="arXiv:2307.09288",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=32000, activation="swiglu",
))

LLAMA3_8B = register(ModelConfig(
    arch_id="llama3-8b", family="dense", citation="hf:meta-llama/Meta-Llama-3-8B",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, rope_theta=500_000.0, activation="swiglu",
))

YI_9B = register(ModelConfig(
    arch_id="yi-9b", family="dense", citation="hf:01-ai/Yi-9B",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000, activation="swiglu",
))

LLAMA2_33B = register(ModelConfig(
    arch_id="llama2-33b", family="dense", citation="arXiv:2307.09288",
    n_layers=60, d_model=6656, n_heads=52, n_kv_heads=52, head_dim=128,
    d_ff=17920, vocab_size=32000, activation="swiglu",
))

EDGE_MODELS = ("yi-6b", "llama2-7b", "llama3-8b", "yi-9b")
CLOUD_MODEL = "llama2-33b"
