"""Whisper-base — encoder-decoder audio transformer; conv frontend is a stub.

[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="whisper-base",
    family="audio",
    citation="arXiv:2212.04356",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    enc_dec=True,
    n_encoder_layers=6,
    encoder_seq_len=1500,
    activation="geglu",    # whisper uses plain GELU MLP; modeled as gated GELU
    tie_embeddings=True,
))
