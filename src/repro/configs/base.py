"""Architecture config system.

Every assigned architecture (and the paper's own deployment models) is a
``ModelConfig``. The same config object drives:
  * model construction (`repro.models.model.Model`)
  * the dry-run (`repro.launch.dryrun`) via `input_specs()`
  * the scheduler's analytic cost model (`flops_per_token`, `kv_bytes_per_token`)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Config dataclass
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD (state-space duality) block config."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin RG-LRU recurrent block config."""

    d_rnn: int = 0          # lru width (0 -> d_model rounded up)
    d_conv: int = 4
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity ------------------------------------------------------------
    arch_id: str = "unnamed"
    family: str = "dense"          # dense | moe | ssm | vlm | hybrid | audio
    citation: str = ""

    # transformer core ------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    activation: str = "swiglu"     # swiglu | geglu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # positional ------------------------------------------------------------
    rope_theta: float = 10_000.0
    mrope: bool = False            # Qwen2-VL multimodal 3D RoPE

    # attention pattern -------------------------------------------------------
    sliding_window: int = 0            # 0 -> full attention
    local_global_pattern: Tuple[int, int] = (0, 0)   # (n_local, n_global) per block, e.g. (5, 1)

    # MoE ---------------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden (0 -> d_ff)

    # SSM / hybrid --------------------------------------------------------------
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # MLA ------------------------------------------------------------------------
    mla: Optional[MLAConfig] = None

    # encoder-decoder (audio) -----------------------------------------------------
    enc_dec: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500    # whisper: 30s of audio -> 1500 frames

    # vlm stub ---------------------------------------------------------------------
    vision_tokens: int = 0         # number of stub patch embeddings in inputs

    # numerics
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True when a 500k-token decode cache is sub-quadratic / windowed."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.sliding_window > 0:
            return True
        if self.local_global_pattern != (0, 0):
            return True
        return False

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing (whisper is enc-dec)

    # -------------------------------------------------------------- cost model
    def param_count(self) -> int:
        """Total parameter count (all experts)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k experts)."""
        return _param_count(self, active_only=True)

    def flops_per_token(self) -> float:
        """Forward FLOPs per generated/processed token, ~2 * active params."""
        return 2.0 * self.active_param_count()

    def train_flops_per_token(self) -> float:
        return 6.0 * self.active_param_count()

    def kv_bytes_per_token(self, bytes_per_elem: int = 2) -> float:
        """Per-token decode-state bytes (amortized over layers)."""
        if self.family == "ssm":
            return 0.0  # O(1) state, no per-token growth
        hd = self.resolved_head_dim
        per_layer = (self.mla.kv_lora_rank + self.mla.qk_rope_head_dim
                     if self.mla is not None else 2 * self.n_kv_heads * hd)
        n_attn = self.attention_layer_count()
        return float(n_attn * per_layer * bytes_per_elem)

    def attention_layer_count(self) -> int:
        if self.family == "ssm":
            return 0
        if self.rglru is not None:
            pat = self.rglru.block_pattern
            n_attn_per = sum(1 for b in pat if b == "attn")
            full_blocks = self.n_layers // len(pat)
            tail = self.n_layers % len(pat)
            return full_blocks * n_attn_per + sum(
                1 for b in pat[:tail] if b == "attn")
        return self.n_layers

    def param_bytes(self, bytes_per_elem: int = 2) -> float:
        return float(self.param_count() * bytes_per_elem)

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                vocab_size: int = 1024) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        hd = 64
        n_heads = max(2, d_model // hd)
        # keep the q:kv ratio of the full config
        ratio = max(1, self.n_heads // max(1, self.n_kv_heads))
        n_kv = max(1, n_heads // ratio)
        kw = dict(
            arch_id=self.arch_id + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=4 * d_model if self.family != "moe" else 2 * d_model,
            vocab_size=vocab_size,
            encoder_seq_len=32,
        )
        if self.n_experts:
            kw.update(n_experts=min(4, self.n_experts),
                      top_k=min(2, self.top_k),
                      n_shared_experts=min(1, self.n_shared_experts),
                      moe_d_ff=d_model)
        if self.ssm is not None:
            kw.update(ssm=SSMConfig(d_state=16, head_dim=32, chunk_size=16))
        if self.rglru is not None:
            kw.update(rglru=RGLRUConfig(d_rnn=d_model,
                                        block_pattern=self.rglru.block_pattern),
                      n_layers=max(n_layers, len(self.rglru.block_pattern)))
        if self.mla is not None:
            kw.update(mla=MLAConfig(q_lora_rank=128, kv_lora_rank=64,
                                    qk_nope_head_dim=32, qk_rope_head_dim=16,
                                    v_head_dim=32))
        if self.sliding_window:
            kw.update(sliding_window=64)
        if self.local_global_pattern != (0, 0):
            kw.update(local_global_pattern=self.local_global_pattern,
                      sliding_window=64,
                      n_layers=max(n_layers, sum(self.local_global_pattern)))
        if self.enc_dec:
            kw.update(enc_dec=True, n_encoder_layers=n_layers)
        if self.vision_tokens:
            kw.update(vision_tokens=16, mrope=self.mrope)
        return dataclasses.replace(self, **kw)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    embed = cfg.vocab_size * d
    unembed = 0 if cfg.tie_embeddings else cfg.vocab_size * d

    def attn_params() -> int:
        if cfg.mla is not None:
            m = cfg.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk_hd
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += cfg.n_heads * m.v_head_dim * d
            return p
        q = d * cfg.n_heads * hd
        kv = 2 * d * cfg.n_kv_heads * hd
        o = cfg.n_heads * hd * d
        return q + kv + o

    def mlp_params(hidden: int) -> int:
        return 3 * d * hidden  # gated MLP: up, gate, down

    def moe_layer(active: bool) -> int:
        h = cfg.moe_d_ff or cfg.d_ff
        router = d * cfg.n_experts
        shared = cfg.n_shared_experts * mlp_params(h)
        n_routed = cfg.top_k if active else cfg.n_experts
        return router + shared + n_routed * mlp_params(h)

    total = embed + unembed
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.d_inner(d)
        nh = s.n_heads(d)
        conv_dim = di + 2 * s.d_state  # x, B, C share the causal conv
        # in_proj emits (z, x, B, C, dt); out_proj folds back; +A, D, norm
        per_layer = (d * (2 * di + 2 * s.d_state + nh) + di * d
                     + s.d_conv * conv_dim + 2 * nh + di)
        total += cfg.n_layers * per_layer
        return total

    if cfg.rglru is not None:
        d_rnn = cfg.rglru.d_rnn or d
        rec_layer = 2 * d * d_rnn + d_rnn * d + 3 * d_rnn + cfg.rglru.d_conv * d_rnn
        attn_layer = attn_params()
        mlp = mlp_params(cfg.d_ff)
        n_attn = cfg.attention_layer_count()
        n_rec = cfg.n_layers - n_attn
        total += n_rec * (rec_layer + mlp) + n_attn * (attn_layer + mlp)
        return total

    per_layer = attn_params()
    if cfg.n_experts:
        per_layer += moe_layer(active_only)
    else:
        per_layer += mlp_params(cfg.d_ff)
    n_dec = cfg.n_layers
    total += n_dec * per_layer
    if cfg.enc_dec:
        # encoder self-attn + mlp, decoder gains cross-attn
        total += cfg.n_encoder_layers * (attn_params() + mlp_params(cfg.d_ff))
        total += n_dec * attn_params()  # cross attention
    return total


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import every config module for its register() side effect
    from repro.configs import (  # noqa: F401
        mixtral_8x7b, minicpm3_4b, deepseek_moe_16b, mamba2_2p7b,
        qwen2_vl_2b, gemma3_12b, recurrentgemma_2b, gemma_2b,
        whisper_base, gemma3_27b, paper_models,
    )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """Whether (arch, shape) is in scope (long_500k needs sub-quadratic)."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True
