"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed experts, top-6.

[arXiv:2401.06066]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    citation="arXiv:2401.06066",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,            # per-expert fine-grained hidden
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    activation="swiglu",
))
