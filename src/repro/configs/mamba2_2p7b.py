"""Mamba2-2.7B — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    citation="arXiv:2405.21060",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256),
))
