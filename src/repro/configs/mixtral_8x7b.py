"""Mixtral 8x7B — sparse MoE, 8 experts top-2, sliding-window attention.

[arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="mixtral-8x7b",
    family="moe",
    citation="arXiv:2401.04088",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    top_k=2,
    moe_d_ff=14336,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    activation="swiglu",
))
