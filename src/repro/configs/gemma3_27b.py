"""Gemma3-27B — dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="gemma3-27b",
    family="dense",
    citation="hf:google/gemma-3-1b-pt",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    local_global_pattern=(5, 1),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    activation="geglu",
))
