"""Gemma-2B — dense, GeGLU, head_dim=256, MQA (single KV head).

[arXiv:2403.08295]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="gemma-2b",
    family="dense",
    citation="arXiv:2403.08295",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    tie_embeddings=True,
    activation="geglu",
))
