"""Qwen2-VL-2B — VLM language backbone with M-RoPE; vision tower is a stub.

[arXiv:2409.12191]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    citation="arXiv:2409.12191",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    vision_tokens=256,     # stub patch embeddings prepended to the text
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    activation="swiglu",
))
