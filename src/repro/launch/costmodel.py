"""Jaxpr-walking FLOP/byte cost model.

XLA's `compiled.cost_analysis()` counts `while` (scan) bodies exactly once,
which silently undercounts layer-stacked models by ~n_layers×. This walker
traverses the closed jaxpr instead and multiplies scan bodies by their trip
count, giving deterministic *global* (unpartitioned) costs:

  flops — 2·M·N·K for dot_general (+ output-size for elementwise ops)
  bytes — unfused operand+result traffic per primitive (an upper bound;
          XLA fusion reduces real HBM traffic, so the roofline memory term
          derived from this is conservative)

Used by the §Roofline analysis; the compiled dry-run still provides memory
footprints and the collective schedule.
"""
from __future__ import annotations

import math
from typing import Dict

import jax


def _aval_bytes(aval) -> float:
    try:
        return math.prod(aval.shape) * aval.dtype.itemsize
    except Exception:  # abstract tokens etc.
        return 0.0


def _aval_size(aval) -> float:
    try:
        return float(math.prod(aval.shape))
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(d for i, d in enumerate(lhs.shape)
                  if i not in lc and i not in lb)
    n = math.prod(d for i, d in enumerate(rhs.shape)
                  if i not in rc and i not in rb)
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # out_elems × (2 × kernel_elems_per_output)
    kernel = math.prod(rhs.shape[:-1])  # rough: all but out-features
    return 2.0 * _aval_size(out) * kernel


_CHEAP = {"broadcast_in_dim", "reshape", "transpose", "squeeze", "slice",
          "dynamic_slice", "dynamic_update_slice", "concatenate", "pad",
          "gather", "scatter", "scatter-add", "convert_element_type",
          "iota", "copy", "rev", "select_n", "stop_gradient",
          "sharding_constraint", "device_put"}


def jaxpr_cost(jaxpr) -> Dict[str, float]:
    """Recursive cost of a (closed) jaxpr: {'flops', 'bytes'}."""
    flops = 0.0
    bytes_ = 0.0
    for eqn in jaxpr.eqns:
        p = eqn.primitive.name
        io_bytes = (sum(_aval_bytes(v.aval) for v in eqn.invars
                        if hasattr(v, "aval"))
                    + sum(_aval_bytes(v.aval) for v in eqn.outvars))
        if p in _CHEAP:
            bytes_ += io_bytes
        elif p == "dot_general":
            flops += _dot_flops(eqn)
            bytes_ += io_bytes
        elif p == "conv_general_dilated":
            flops += _conv_flops(eqn)
            bytes_ += io_bytes
        elif p == "scan":
            length = eqn.params["length"]
            inner = jaxpr_cost(eqn.params["jaxpr"].jaxpr)
            flops += length * inner["flops"]
            bytes_ += length * inner["bytes"]
        elif p == "while":
            # non-scan while: count body once (no static trip count)
            inner = jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)
            flops += inner["flops"]
            bytes_ += inner["bytes"]
        elif p == "shard_map":
            # body costs are per-shard; scale to global by mesh size
            sub = eqn.params["jaxpr"]
            sub = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            inner = jaxpr_cost(sub)
            n = eqn.params["mesh"].size if "mesh" in eqn.params else 1
            flops += n * inner["flops"]
            bytes_ += n * inner["bytes"]
        elif p == "cond":
            branches = [jaxpr_cost(b.jaxpr)
                        for b in eqn.params["branches"]]
            flops += max(b["flops"] for b in branches)
            bytes_ += max(b["bytes"] for b in branches)
        elif p in ("pjit", "closed_call", "core_call", "remat_call",
                   "custom_jvp_call", "custom_vjp_call", "remat2", "checkpoint",
                   "custom_vjp_call_jaxpr", "named_call"):
            key = "jaxpr" if "jaxpr" in eqn.params else (
                "call_jaxpr" if "call_jaxpr" in eqn.params else
                ("fun_jaxpr" if "fun_jaxpr" in eqn.params else None))
            if key is None:
                bytes_ += io_bytes
                continue
            sub = eqn.params[key]
            sub = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            inner = jaxpr_cost(sub)
            flops += inner["flops"]
            bytes_ += inner["bytes"]
        else:
            # elementwise / reduction default: 1 flop per output element
            flops += sum(_aval_size(v.aval) for v in eqn.outvars)
            bytes_ += io_bytes
    return {"flops": flops, "bytes": bytes_}


def step_cost(fn, *args) -> Dict[str, float]:
    """Global (unpartitioned) cost of fn(*args) via make_jaxpr."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return jaxpr_cost(jaxpr.jaxpr)
