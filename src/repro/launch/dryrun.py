import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch × shape × mesh) and extract
# memory / cost / collective statistics.
#
# The two lines above MUST stay the very first statements: JAX locks the
# device count on first initialization, and the production meshes need 512
# host placeholder devices. Nothing here allocates full-size arrays —
# params, optimizer state, batches and caches are all ShapeDtypeStructs.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]

import argparse
import json
import re
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    ASSIGNED_ARCHS, INPUT_SHAPES, InputShape, ModelConfig, get_config,
    shape_applicable,
)
from repro.launch.mesh import batch_axes_for, make_production_mesh
from repro.launch.shardings import batch_shardings, cache_shardings
from repro.models import model as M
from repro.models.parallel import (ParallelContext, opt_state_shardings,
                                   param_shardings)
from repro.training.optimizer import AdamWConfig, OptState, init_opt_state
from repro.training.train_loop import make_train_step

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in (optimized) HLO text.

    all-reduce is counted 2× (ring = reduce-scatter + all-gather traffic).
    Returns {op_kind: bytes, ..., 'total': bytes}.
    """
    out = {k: 0.0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(.+?)\s+(" + "|".join(COLLECTIVES)
                      + r")(?:-start|-done)?\(", stripped)
        if not m:
            continue
        shapes_part, kind = m.group(1), m.group(2)
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(shapes_part):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        factor = 2.0 if kind == "all-reduce" else 1.0
        out[kind] += nbytes * factor
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


def make_context(mesh, **kw) -> ParallelContext:
    return ParallelContext(mesh=mesh, batch_axes=batch_axes_for(mesh),
                           model_axis="model", **kw)


# ---------------------------------------------------------------------------
# Step builders: (jitted fn, arg ShapeDtypeStructs)
# ---------------------------------------------------------------------------


def build_train(cfg: ModelConfig, shape: InputShape, ctx: ParallelContext,
                microbatches: int = 1, acc_bf16: bool = False):
    opt_cfg = AdamWConfig()
    step = make_train_step(cfg, ctx, opt_cfg, microbatches=microbatches,
                           acc_dtype=jnp.bfloat16 if acc_bf16 else None)
    pshapes = M.params_shapes(cfg)
    oshapes = jax.eval_shape(init_opt_state, pshapes)
    bspecs = M.input_specs(cfg, shape)
    pshard = param_shardings(pshapes, ctx)
    moment = opt_state_shardings(pshapes, ctx)
    oshard = OptState(step=NamedSharding(ctx.mesh, P()),
                      m=moment, v=moment)
    bshard = batch_shardings(cfg, ctx, shape)
    fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                 donate_argnums=(0, 1))
    return fn, (pshapes, oshapes, bspecs)


def build_prefill(cfg: ModelConfig, shape: InputShape, ctx: ParallelContext):
    pshapes = M.params_shapes(cfg)
    bspecs = M.input_specs(cfg, shape)
    cshapes = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    pshard = param_shardings(pshapes, ctx)
    bshard = batch_shardings(cfg, ctx, shape)
    cshard = cache_shardings(cfg, ctx, shape.global_batch, shape.seq_len)

    def fn(params, batch, cache):
        return M.prefill(params, batch, cache, cfg=cfg, ctx=ctx)

    jfn = jax.jit(fn, in_shardings=(pshard, bshard, cshard),
                  out_shardings=(None, cshard), donate_argnums=(2,))
    return jfn, (pshapes, bspecs, cshapes)


def build_decode(cfg: ModelConfig, shape: InputShape, ctx: ParallelContext):
    pshapes = M.params_shapes(cfg)
    bspecs = M.input_specs(cfg, shape)
    cshapes = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    cp = shape.global_batch == 1
    pshard = param_shardings(pshapes, ctx)
    cshard = cache_shardings(cfg, ctx, shape.global_batch, shape.seq_len,
                             context_parallel=cp)
    tok_shard = batch_shardings(cfg, ctx, shape)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, tokens, cache, pos):
        extras = {}
        return M.decode_step(params, tokens, cache, pos, cfg=cfg, ctx=ctx,
                             batch_extras=extras)

    jfn = jax.jit(
        fn,
        in_shardings=(pshard, tok_shard["tokens"], cshard,
                      NamedSharding(ctx.mesh, P())),
        out_shardings=(None, cshard), donate_argnums=(2,))
    return jfn, (pshapes, bspecs["tokens"], cshapes, pos)


def build(cfg, shape, ctx, microbatches: int = 1, acc_bf16: bool = False):
    if shape.kind == "train":
        return build_train(cfg, shape, ctx, microbatches=microbatches,
                           acc_bf16=acc_bf16)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, ctx)
    return build_decode(cfg, shape, ctx)


def _raw_step(cfg, shape, ctx):
    """Unjitted step function (for the jaxpr cost model)."""
    if shape.kind == "train":
        return make_train_step(cfg, ctx, AdamWConfig())
    if shape.kind == "prefill":
        return lambda p, b, c: M.prefill(p, b, c, cfg=cfg, ctx=ctx)
    return lambda p, t, c, pos: M.decode_step(p, t, c, pos, cfg=cfg, ctx=ctx)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            verbose: bool = True, ctx_overrides: Optional[dict] = None,
            microbatches: int = 1, acc_bf16: bool = False):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k needs sub-quadratic attention "
                          "(DESIGN.md §Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_context(mesh, **(ctx_overrides or {}))
    t0 = time.time()
    fn, args = build(cfg, shape, ctx, microbatches=microbatches,
                     acc_bf16=acc_bf16)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())

    # loop-aware global FLOPs/bytes (costmodel.py): XLA's cost_analysis
    # counts scan bodies once, so it badly undercounts stacked layers
    from repro.launch.costmodel import step_cost
    raw_step = _raw_step(cfg, shape, ctx)
    gc = step_cost(raw_step, *args)

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "multi_pod": multi_pod,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0) if cost else None,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else None,
        "global_flops": gc["flops"],
        "global_bytes_unfused": gc["bytes"],
        "n_devices": int(mesh.devices.size),
        "collective_bytes": coll,
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                report[attr] = v
        # The CPU host backend promotes bf16 dot operands to f32, so
        # temp_size overstates TPU HBM by roughly the bf16:f32 ratio of the
        # big transients. Record a corrected estimate alongside the raw
        # number (EXPERIMENTS.md §Dry-run discusses the correction).
        report["temp_tpu_estimate_bytes"] = int(
            report.get("temp_size_in_bytes", 0) * 0.55)
    if verbose:
        print(json.dumps(report, indent=2, default=float))
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None, help="append reports to file")
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation chunks for train shapes "
                         "(SPerf memory lever)")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=["dense", "capacity", "ep_a2a"],
                    help="MoE dispatch (dense = paper baseline; ep_a2a = "
                         "§Perf optimized expert-parallel all-to-all)")
    args = ap.parse_args(argv)

    overrides = {}
    if args.expert_parallel:
        overrides["moe_expert_parallel"] = True
    if args.moe_dispatch:
        overrides["moe_dispatch"] = args.moe_dispatch

    pairs = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    reports = []
    failures = 0
    for a, s in pairs:
        try:
            rep = run_one(a, s, multi_pod=args.multi_pod,
                          ctx_overrides=overrides,
                          microbatches=args.microbatches)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            rep = {"arch": a, "shape": s, "error": f"{type(e).__name__}: {e}"}
            print(json.dumps(rep, default=str), file=sys.stderr)
        reports.append(rep)
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rep, default=float) + "\n")
    ok = sum(1 for r in reports if not r.get("error"))
    print(f"\ndryrun: {ok}/{len(reports)} lowered+compiled "
          f"({failures} failures)", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
