"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — only `launch/dryrun.py` forces the 512-way
host-device platform, everything else sees the real devices.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 v5e pod slice; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever this host has (CPU smoke testing)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def batch_axes_for(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
