"""Sharding rules for batches and KV/recurrent caches on the production mesh.

Parameters are handled by `repro.models.parallel.param_shardings`; this
module covers the *runtime state*: input batches, decode caches, optimizer
state trees.

Cache rules (name + shape based, divisibility-checked):
  k/v/cross_k/cross_v  (…, B, S, Hkv, hd): B→batch axes; Hkv→model (else
      hd→model); for the long-context decode shape (B=1, S=full) the cache
      *sequence* is context-parallel over "data".
  ckv/krope            (…, B, S, r): B→batch; r→model.
  state                (…, B, nh, hd, ds): B→batch; nh→model.
  conv                 (…, B, k, C): B→batch; C→model.
  h                    (…, B, dr): B→batch; dr→model.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as M
from repro.models.parallel import ParallelContext


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _batch_axis_tree(cfg: ModelConfig, max_seq: int):
    c1 = jax.eval_shape(lambda: M.init_cache(cfg, 1, max_seq))
    c2 = jax.eval_shape(lambda: M.init_cache(cfg, 2, max_seq))
    return jax.tree.map(
        lambda a, b: next(
            (i for i, (x, y) in enumerate(zip(a.shape, b.shape, strict=True))
             if x != y),
            -1),
        c1, c2)


def cache_specs_tree(cfg: ModelConfig, ctx: ParallelContext, batch: int,
                     max_seq: int, context_parallel: bool = False):
    """PartitionSpec tree matching init_cache(cfg, batch, max_seq)."""
    shapes = jax.eval_shape(lambda: M.init_cache(cfg, batch, max_seq))
    baxes = _batch_axis_tree(cfg, max_seq)
    msize = ctx.axis_size(ctx.model_axis)
    bdiv = ctx.batch_size_divisor
    cp_size = ctx.axis_size("data")

    def rule(path, leaf, bax):
        name = _leaf_name(path)
        spec = [None] * leaf.ndim
        if bax >= 0 and leaf.shape[bax] % bdiv == 0 and leaf.shape[bax] > 1:
            spec[bax] = ctx.batch_spec
        if name in ("k", "v", "cross_k", "cross_v"):
            s_dim, h_dim, d_dim = bax + 1, bax + 2, bax + 3
            if (context_parallel and spec[bax] is None
                    and leaf.shape[s_dim] == max_seq
                    and leaf.shape[s_dim] % cp_size == 0):
                spec[s_dim] = "data"
            if leaf.shape[h_dim] % msize == 0:
                spec[h_dim] = ctx.model_axis
            elif (name in ("k", "v") and spec[s_dim] is None
                    and spec[bax] is not None
                    and leaf.shape[s_dim] % msize == 0):
                # matches layers.kv_cache_cp: batch-shardable decode goes
                # context-parallel over `model`
                spec[s_dim] = ctx.model_axis
            elif leaf.shape[d_dim] % msize == 0:
                spec[d_dim] = ctx.model_axis
        elif name in ("ckv", "krope"):
            r_dim = leaf.ndim - 1
            if leaf.shape[r_dim] % msize == 0:
                spec[r_dim] = ctx.model_axis
        elif name == "state":
            nh_dim = bax + 1
            if leaf.shape[nh_dim] % msize == 0:
                spec[nh_dim] = ctx.model_axis
        elif name in ("conv", "h"):
            last = leaf.ndim - 1
            if leaf.shape[last] % msize == 0:
                spec[last] = ctx.model_axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: rule(path, leaf,
                                _lookup(baxes, path)), shapes)


def _lookup(tree, path):
    node = tree
    for entry in path:
        key = getattr(entry, "key", getattr(entry, "idx", None))
        node = node[key]
    return node


def cache_shardings(cfg: ModelConfig, ctx: ParallelContext, batch: int,
                    max_seq: int, context_parallel: bool = False):
    specs = cache_specs_tree(cfg, ctx, batch, max_seq, context_parallel)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(cfg: ModelConfig, ctx: ParallelContext,
                    shape: InputShape):
    """Shardings matching `input_specs(cfg, shape)`."""
    specs = M.input_specs(cfg, shape)
    bdiv = ctx.batch_size_divisor

    def rule(name, leaf):
        spec = [None] * len(leaf.shape)
        bdim = 1 if name == "positions" else 0   # positions: (3, B, S)
        if leaf.shape[bdim] % bdiv == 0 and leaf.shape[bdim] > 1:
            spec[bdim] = ctx.batch_spec
        return NamedSharding(ctx.mesh, P(*spec))

    return {k: rule(k, v) for k, v in specs.items()}
