"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
        --reduced --steps 50 --batch 8 --seq 128

On a real TPU pod, drop --reduced and pass --mesh 16x16 (the sharded
train_step is exactly what `launch/dryrun.py` compiles in the dry-run).
"""
import argparse

import jax

from repro.configs import get_config, list_archs
from repro.models.parallel import cpu_context
from repro.training import AdamWConfig, train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer d_model<=512 variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"training {cfg.arch_id}: {cfg.param_count()/1e6:.1f}M params on "
          f"{jax.device_count()} device(s)")
    params, opt, hist = train(
        cfg, ctx=cpu_context(), steps=args.steps, batch_size=args.batch,
        seq_len=args.seq, seed=args.seed,
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps))
    print(f"done: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
