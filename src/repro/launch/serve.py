"""Serving launcher: continuous-batching engine over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 16 --max-new 8
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import init_params
from repro.serving import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(jax.random.key(0), cfg)
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq,
                        temperature=args.temperature)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(list(rng.integers(0, cfg.vocab_size, plen)),
                   max_new_tokens=args.max_new)
    done = eng.run_until_idle()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"{cfg.arch_id}: served {len(done)} requests, {toks} tokens in "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
