"""Serving launcher: continuous-batching engine over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 16 --max-new 8

With `--policy NAME` the launcher instead serves through a PerLLM fleet
(2 reduced edge engines + 1 reduced cloud engine) scheduled by the named
policy from the registry (see `repro.core.available_policies()`):

    PYTHONPATH=src python -m repro.launch.serve --policy perllm --requests 12

`--paged [KV_BLOCKS]` runs the engine(s) on the paged KV cache: admission
allocates block-pool pages (and stalls on exhaustion) instead of relying
on the dense `max_batch × max_seq` reservation; evicted requests keep
their prefill (see docs/serving.md).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import init_params
from repro.serving import ServingEngine


def _run_fleet(args) -> None:
    """Edge-cloud fleet scheduled by a registry policy (`--policy`)."""
    from repro.cluster import paper_testbed
    from repro.core import available_policies, make_policy
    from repro.serving.perllm_server import PerLLMServer

    # specs carry the engines' block granularity so the C5 constraint's
    # blocks-needed estimate uses the same units as the engine pools
    specs = paper_testbed(n_edge=2, kv_block_tokens=args.kv_block_tokens)
    try:
        policy = make_policy(args.policy, len(specs))
    except KeyError:
        raise SystemExit(f"unknown policy {args.policy!r}; available: "
                         + ", ".join(available_policies())) from None
    key = jax.random.key(0)
    edge_cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=64,
                                              vocab_size=256)
    cloud_cfg = get_config("gemma3-12b").reduced(n_layers=2, d_model=128,
                                                 vocab_size=256)
    kv = _kv_kwargs(args)
    engines = [ServingEngine(edge_cfg, init_params(key, edge_cfg),
                             max_batch=2, max_seq=64, **kv)
               for _ in range(2)]
    engines.append(ServingEngine(cloud_cfg, init_params(key, cloud_cfg),
                                 max_batch=4, max_seq=64, **kv))
    srv = PerLLMServer(specs, engines, scheduler=policy)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        plen = int(rng.integers(4, 16))
        srv.submit(list(rng.integers(0, 256, plen)),
                   max_new_tokens=args.max_new)
    srv.run_until_idle()
    dt = time.time() - t0
    s = srv.stats
    if not s["served"]:
        print(f"{policy.name}: served 0 requests in {dt:.1f}s")
        return
    print(f"{policy.name}: served {s['served']} requests in {dt:.1f}s — "
          f"deadline_met={s['deadline_met']*100:.0f}% "
          f"mean_latency={s['mean_latency']:.2f}s "
          f"per_server={s['per_server']}")


def _kv_kwargs(args) -> dict:
    if args.paged is None:
        return {}
    return dict(paged=True,
                kv_blocks=args.paged if args.paged > 0 else None,
                kv_block_tokens=args.kv_block_tokens)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--policy", default=None,
                    help="serve through an edge-cloud fleet scheduled by "
                         "this registered policy (perllm, fineinfer, ...)")
    ap.add_argument("--paged", type=int, nargs="?", const=0, default=None,
                    metavar="KV_BLOCKS",
                    help="paged KV cache: allocate block-pool pages at "
                         "admission (optional pool size in blocks; bare "
                         "--paged sizes the pool to the dense equivalent)")
    ap.add_argument("--kv-block-tokens", type=int, default=16,
                    help="tokens of KV per block in --paged mode")
    args = ap.parse_args(argv)

    if args.policy:
        _run_fleet(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(jax.random.key(0), cfg)
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq,
                        temperature=args.temperature, **_kv_kwargs(args))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(list(rng.integers(0, cfg.vocab_size, plen)),
                   max_new_tokens=args.max_new)
    done = eng.run_until_idle()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"{cfg.arch_id}: served {len(done)} requests, {toks} tokens in "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
