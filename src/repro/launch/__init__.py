from repro.launch.mesh import batch_axes_for, make_production_mesh

__all__ = ["batch_axes_for", "make_production_mesh"]
