"""Shipped configuration for the repro-check rules.

The config is plain Python data: each rule reads its own section. Paths
are *suffix-matched* against the analyzed files' normalized relative
paths, so ``cluster/simulator.py`` matches ``src/repro/cluster/
simulator.py`` regardless of where the checker is invoked from. Tests
pass a hand-built config to exercise rules against fixture trees.
"""
from __future__ import annotations

import copy

DEFAULT_CONFIG = {
    # ------------------------------------------------------------------
    # R1 — ledger conservation (kv_used / refcounts / prefix pins /
    # link bookings)
    # ------------------------------------------------------------------
    "r1": {
        # files whose functions are path-enumerated for charge/release
        "ledger_files": [
            "cluster/simulator.py",
            "serving/perllm_server.py",
            "serving/kvcache.py",
            "serving/engine.py",
        ],
        # files that additionally maintain the mirrored prefix-pin ledger:
        # a path that frees kv pages *and* resets the claim record must
        # also unpin (the PR 6 requeue bug shape)
        "pin_files": ["cluster/simulator.py"],
        # files where every subscript store to a link ledger must sit
        # inside a `for <lk> in <path>` loop (whole-path booking)
        "link_files": [
            "cluster/simulator.py",
            "cluster/network.py",
            "serving/perllm_server.py",
        ],
        "link_ledger_names": ["link_free", "links", "free_at"],
        # single-link maps: `name = self._single_link[j]` + an
        # `if name is not None:` guard marks a one-link path whose
        # direct booking covers the whole path by construction
        "single_link_names": ["_single_link"],
        # index-expression substrings that mark a vectorized whole-path
        # booking (`link_free[path_idx] += ...`, `np.add.at(...)`)
        "path_index_markers": ["path"],
        # attribute names that form the claim record; resetting them to
        # the sentinel without releasing is an orphan
        "claim_resets": {"kv_server": -1, "kv_blocks": 0},
        # files whose functions participate in the BlockAllocator
        # refcount discipline (R1c)
        "refcount_files": ["serving/kvcache.py", "serving/engine.py"],
        # method names that charge / release the shared-page refcount
        "refcount_charge": ["allocate", "_allocate_fresh", "ref",
                            "fork", "import_pages"],
        "refcount_release": ["free", "release", "reclaim"],
        # functions that *intentionally* end with a net claim: they are
        # the charging half of a charge/release pair whose release lives
        # in a sibling (e.g. _kv_admit charges, _kv_free releases)
        "owner_functions": [
            "_kv_admit", "_kv_migrate", "_prefix_attach", "register",
            "_admit", "_resume",
        ],
        # never analyzed: constructors initialize ledgers from nothing
        "exempt_functions": ["__init__", "__post_init__"],
        "max_paths": 256,
    },
    # ------------------------------------------------------------------
    # R2 — event-handler exhaustiveness
    # ------------------------------------------------------------------
    "r2": {
        "events_file": "core/runtime.py",
        "event_base": "Event",
        "dispatch_class": "Runtime",
        "dispatch_table": "_HANDLERS",
        # concrete runtimes that must handle (or be exempted from) every
        # event in the dispatch table
        "runtimes": ["_EventSimRuntime", "_ReferenceEventRuntime",
                     "PerLLMServer"],
        # handler -> reason; a `pass`-inherited handler is fine only if
        # listed here (silent drops must be deliberate)
        "exemptions": {
            "_EventSimRuntime": {
                "on_infer_start": "event sim schedules InferDone "
                                  "directly; InferStart is never pushed",
            },
            "_ReferenceEventRuntime": {
                "on_infer_start": "reference core mirrors the event sim: "
                                  "InferDone is scheduled directly and "
                                  "InferStart is never pushed",
            },
            "PerLLMServer": {
                "on_infer_done": "live server detects completions inside "
                                 "engine ticks (on_infer_start); "
                                 "InferDone is never pushed",
            },
        },
    },
    # ------------------------------------------------------------------
    # R3 — decision / result / view field coverage
    # ------------------------------------------------------------------
    "r3": {
        "api_file": "core/api.py",
        "decision_classes": ["Decision", "Allocation"],
        # module groups that must each read every Decision/Allocation
        # field (api.py holds the shared helpers both runtimes call)
        "reader_groups": {
            "event-simulator": ["core/api.py", "core/runtime.py",
                                "cluster/simulator.py"],
            "live-server": ["core/api.py", "core/runtime.py",
                            "serving/perllm_server.py",
                            "serving/engine.py"],
        },
        # fields exempt from the both-groups read requirement, with the
        # guarding reason
        "decision_guards": {
            "slacks": "observational (feedback/diagnostics only)",
        },
        "result_class": "SimResult",
        "result_file": "cluster/simulator.py",
        "view_class": "ClusterView",
        # builders per group: files scanned for ClusterView(...) calls;
        # helpers are functions whose returned dict keys also count
        # (they are splatted into the call via **kwargs)
        # the event-simulator group's keyword-constructed ClusterView
        # lives in the reference core; the array core materializes the
        # same view from its ledger arrays (`ClusterView.__new__` +
        # wholesale `__dict__` fill, invisible to this AST scan) and is
        # pinned field-for-field to the reference by the golden and
        # property equivalence tests
        "view_builders": {
            "event-simulator": ["cluster/reference_sim.py"],
            "live-server": ["serving/perllm_server.py"],
        },
        "view_helpers": {"cluster/network.py": ["link_view_kwargs"]},
        "view_guards": {
            "kv_prefix_tokens": "simulator-only mirrored prefix ledger; "
                                "the live server's PrefixIndex serves "
                                "hits engine-side",
        },
    },
    # ------------------------------------------------------------------
    # R4 — determinism discipline
    # ------------------------------------------------------------------
    "r4": {
        "scope": ["repro/cluster/", "repro/core/", "repro/serving/"],
        "exempt_files": ["serving/engine.py"],
        "wallclock": ["time", "monotonic", "perf_counter",
                      "perf_counter_ns", "time_ns", "monotonic_ns"],
        "np_random_allowed": ["default_rng", "Generator", "SeedSequence",
                              "PCG64", "Philox", "BitGenerator"],
        # Generator constructors that must receive an explicit seed —
        # called empty they pull OS entropy (nondeterministic streams)
        "seeded_ctors": ["default_rng", "PCG64", "Philox"],
    },
    # ------------------------------------------------------------------
    # R6 — trace-emission coverage (event base / dispatch table come
    # from the r2 section; this section adds the audit set)
    # ------------------------------------------------------------------
    "r6": {
        "runtimes": ["_EventSimRuntime", "_ReferenceEventRuntime",
                     "PerLLMServer"],
        # trace-recorder emit spellings and the helper-method prefix a
        # handler may reach instead of calling the recorder directly
        "emit_methods": ["append", "append_rows", "complete"],
        "trace_prefix": "_trace",
        "max_depth": 6,
        # handler -> reason; a handled event with no reachable emission
        # is fine only when the non-emission is deliberate
        "exemptions": {
            "_EventSimRuntime": {
                "on_tx_done": "TX span is emitted at completion "
                              "(_trace_complete) over the booking's "
                              "realized arrival->ready window",
                "on_bandwidth_change": "link repricing is cluster "
                                       "state, not a request-lifecycle "
                                       "event; no sid to attribute",
            },
            "_ReferenceEventRuntime": {
                "on_tx_done": "mirrors the event sim: TX span lands at "
                              "completion via _trace_complete",
                "on_bandwidth_change": "link repricing is cluster "
                                       "state, not a request-lifecycle "
                                       "event; no sid to attribute",
            },
            "PerLLMServer": {
                "on_deferred": "deferred dispatches were stamped "
                               "ARRIVAL/DECISION at place(); their "
                               "lifecycle spans land at _finish",
                "on_bandwidth_change": "link repricing is cluster "
                                       "state, not a request-lifecycle "
                                       "event; no sid to attribute",
            },
        },
    },
    # ------------------------------------------------------------------
    # R7 — jit tracing-safety (compute layer)
    # ------------------------------------------------------------------
    "r7": {
        # path substrings selecting the compute layer; fixtures under
        # tests/fixtures/repro_check/kernels/ match too
        "scope": ["kernels/", "models/", "serving/"],
    },
    # ------------------------------------------------------------------
    # R8 — recompilation hazards (jitted callees fed per-request shapes)
    # ------------------------------------------------------------------
    "r8": {
        "scope": ["kernels/", "models/", "serving/"],
        # override the entry-point set for the call-graph walk; empty
        # means "every public (non-underscore) method" of each class
        # that jits callables onto self
        "entry_methods": [],
    },
    # ------------------------------------------------------------------
    # R9 — Pallas pallas_call wiring consistency
    # ------------------------------------------------------------------
    "r9": {
        "scope": ["kernels/"],
    },
    # ------------------------------------------------------------------
    # R5 — unit-suffix arithmetic
    # ------------------------------------------------------------------
    "r5": {
        "suffixes": ["_s", "_ms", "_us", "_tokens", "_blocks", "_bytes",
                     "_j", "_bw"],
        "bare_units": ["tokens", "blocks", "bytes"],
    },
}


def default_config() -> dict:
    return copy.deepcopy(DEFAULT_CONFIG)
