"""R6 — trace-emission coverage.

Every concrete ``Event`` subclass a runtime handles must leave a mark in
the request-lifecycle trace: the MRO-resolved handler — or a method it
reaches through ``self.X(...)`` / ``super().X(...)`` calls — must either
call an emit method (``.append`` / ``.append_rows``) on a receiver chain
containing ``trace``, or call a ``_trace*``-prefixed helper. Handlers
whose resolved body is a ``pass``/``raise`` stub are R2's domain and are
skipped here; deliberate non-emissions (e.g. ``BandwidthChange`` — not a
request-lifecycle event) are listed in the config exemptions with a
reason.

The rule shares R2's dispatch-table discovery: the event base, dispatch
class and ``_HANDLERS`` table come from the ``r2`` config section; the
``r6`` section adds the runtimes to audit, the emit-call spellings and
the exemption table.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from ..core import Finding, SourceFile
from .r2_events import _ClassIndex, _dispatch_table

RULE_ID = "R6"


def _method_defs(files: List[SourceFile]) -> Dict[str, Dict[str, ast.AST]]:
    """class name -> {method name: FunctionDef} over all files."""
    out: Dict[str, Dict[str, ast.AST]] = {}
    for sf in files:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            out[node.name] = {
                st.name: st for st in node.body
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef))}
    return out


def _find_def(defs: Dict[str, Dict[str, ast.AST]], mro: List[str],
              method: str) -> Optional[Tuple[str, ast.AST]]:
    for c in mro:
        fn = defs.get(c, {}).get(method)
        if fn is not None:
            return c, fn
    return None


def _receiver_is_trace(node: ast.expr) -> bool:
    """True if the attribute/name chain mentions ``trace`` (e.g.
    ``self.trace`` or a bare ``trace`` local)."""
    while isinstance(node, ast.Attribute):
        if node.attr == "trace":
            return True
        node = node.value
    return isinstance(node, ast.Name) and node.id == "trace"


def _emits(runtime: str, handler: str, index: _ClassIndex,
           defs: Dict[str, Dict[str, ast.AST]], cfg: dict) -> bool:
    """Does `handler` on `runtime` — or anything it reaches via
    ``self.X()`` / ``super().X()`` — emit a trace row?"""
    emit_methods = set(cfg["emit_methods"])
    prefix = cfg["trace_prefix"]
    max_depth = cfg.get("max_depth", 6)
    rt_mro = index.mro(runtime)
    seen = set()
    queue: List[Tuple[List[str], str, int]] = [(rt_mro, handler, 0)]
    while queue:
        mro, method, depth = queue.pop(0)
        found = _find_def(defs, mro, method)
        if found is None:
            continue
        cls, fn = found
        if (cls, method) in seen:
            continue
        seen.add((cls, method))
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call) or \
                    not isinstance(node.func, ast.Attribute):
                continue
            f = node.func
            if f.attr in emit_methods and _receiver_is_trace(f.value):
                return True
            if f.attr.startswith(prefix):
                return True
            if depth >= max_depth:
                continue
            if isinstance(f.value, ast.Name) and f.value.id == "self":
                queue.append((rt_mro, f.attr, depth + 1))
            elif isinstance(f.value, ast.Call) and \
                    isinstance(f.value.func, ast.Name) and \
                    f.value.func.id == "super":
                # resolve past the defining class, like super() would
                cmro = index.mro(cls)
                queue.append((cmro[1:], f.attr, depth + 1))
    return False


def check(files: List[SourceFile], config: dict) -> List[Finding]:
    cfg = config["r6"]
    r2cfg = config["r2"]
    findings: List[Finding] = []
    ev_file = next((sf for sf in files
                    if sf.relpath.endswith(r2cfg["events_file"])), None)
    if ev_file is None:
        return findings     # fixture trees without the events file
    index = _ClassIndex(files)
    defs = _method_defs(files)
    table, _line = _dispatch_table(ev_file, r2cfg["dispatch_class"],
                                   r2cfg["dispatch_table"])
    if not table:
        return findings     # R2 reports the missing table
    for rt in cfg["runtimes"]:
        if rt not in index.classes:
            continue
        _bases, _methods, rt_file, rt_line = index.classes[rt]
        exempt = cfg["exemptions"].get(rt, {})
        for ev_name, handler in sorted(table.items()):
            resolved = index.resolve(rt, handler)
            if resolved is None:
                continue            # R2 reports the missing handler
            _definer, kind = resolved
            if kind in ("pass", "raise"):
                continue            # stubs are R2's domain
            if handler in exempt:
                continue
            if _emits(rt, handler, index, defs, cfg):
                continue
            findings.append(Finding(
                rt_file, rt_line, RULE_ID,
                f"{rt}: {ev_name} handler {handler} (and every method it "
                f"reaches) never emits a trace row — requests passing "
                f"through it are invisible to the lifecycle trace; "
                f"instrument it or add an r6 exemption with a reason"))
    return findings
