"""R5 — unit-suffix arithmetic.

Flags ``+``/``-`` expressions whose two operands are plain identifiers
(names or attribute reads) carrying *conflicting* unit suffixes
(``_s`` vs ``_tokens`` vs ``_blocks`` vs ``_bytes`` vs ``_j`` vs
``_bw``...). Adding seconds to tokens is never meaningful; conversions
go through a named helper (``kv_blocks_needed``) or a multiplication,
both of which this rule ignores.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from ..core import Finding, SourceFile

RULE_ID = "R5"


def _ident(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _unit(name: str, suffixes, bare) -> Optional[str]:
    if name in bare:
        return "_" + name
    for s in suffixes:
        if name.endswith(s) and len(name) > len(s):
            return s
    return None


def check(files: List[SourceFile], config: dict) -> List[Finding]:
    cfg = config["r5"]
    suffixes = sorted(cfg["suffixes"], key=len, reverse=True)
    bare = set(cfg["bare_units"])
    findings: List[Finding] = []
    for sf in files:
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.BinOp) and
                    isinstance(node.op, (ast.Add, ast.Sub))):
                continue
            ln, rn = _ident(node.left), _ident(node.right)
            if ln is None or rn is None:
                continue
            lu = _unit(ln, suffixes, bare)
            ru = _unit(rn, suffixes, bare)
            if lu and ru and lu != ru:
                op = "+" if isinstance(node.op, ast.Add) else "-"
                findings.append(Finding(
                    sf.relpath, node.lineno, RULE_ID,
                    f"`{ln} {op} {rn}` mixes units {lu} and {ru} — "
                    f"convert explicitly before combining"))
    return findings
