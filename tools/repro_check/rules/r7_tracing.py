"""R7 — jit tracing-safety.

Inside a jitted function (``@jax.jit``, ``functools.partial(jax.jit,
...)``, ``self.f = jax.jit(...)``) every non-static argument is a
tracer: Python ``if``/``while``/``assert``/``for`` on a value derived
from one either raises ``ConcretizationTypeError`` or — worse — bakes
one branch in silently. The same applies inside a Pallas kernel body,
where every positional ref (and ``pl.program_id``) is traced. This rule
runs a per-function forward taint walk from the traced parameters and
flags:

- Python control flow (``if``/``while``/``assert``/ternary/``for``)
  whose test or iterable is taint-reachable from a traced argument;
- ``bool()``/``int()``/``float()`` and ``.item()``/``.tolist()`` on
  traced values (host synchronization / concretization);
- host side effects: bare ``print(...)`` (use ``jax.debug.print``),
  ``global`` mutation, and ``np.``/``numpy.`` host ops applied to
  traced values;
- ``static_argnames`` entries whose default is a non-hashable literal
  (list/dict/set) — jit's cache key would raise ``TypeError``.

Attribute reads that are static at trace time (``.shape``, ``.ndim``,
``.dtype``, ...) and ``len()``/``isinstance()``/``type()`` results
un-taint, so shape-driven control flow stays legal. Keyword-only
kernel parameters bound via ``functools.partial`` are compile-time
constants and start untainted. Nested function definitions are not
descended into (``pl.when``-style sub-kernels handle traced
predicates by construction).
"""
from __future__ import annotations

import ast
from typing import List, Set

from ..core import Finding, SourceFile
from . import jitutil

RULE_ID = "R7"

# attribute reads whose result is a static Python value at trace time
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "sharding",
                "aval", "weak_type", "nbytes"}
# builtins whose result on a tracer is a static Python value
UNTAINT_CALLS = {"len", "isinstance", "type", "hash", "id"}
CONCRETIZE_CALLS = {"bool", "int", "float"}
HOST_METHODS = {"item", "tolist", "block_until_ready"}
NONHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp)


def _is_program_id(func: ast.AST) -> bool:
    d = jitutil.dotted(func)
    return d is not None and d.split(".")[-1] in ("program_id",
                                                  "num_programs")


def _tainted(expr: ast.AST, env: Set[str]) -> bool:
    if isinstance(expr, ast.Name):
        return expr.id in env
    if isinstance(expr, ast.Attribute):
        if expr.attr in STATIC_ATTRS:
            return False
        return _tainted(expr.value, env)
    if isinstance(expr, ast.Subscript):
        return _tainted(expr.value, env)
    if isinstance(expr, ast.Call):
        if _is_program_id(expr.func):
            return True
        if isinstance(expr.func, ast.Name) and expr.func.id in UNTAINT_CALLS:
            return False
        if isinstance(expr.func, ast.Attribute) \
                and _tainted(expr.func.value, env):
            return True
        return any(_tainted(a, env) for a in expr.args) or \
            any(_tainted(kw.value, env) for kw in expr.keywords)
    if isinstance(expr, ast.BinOp):
        return _tainted(expr.left, env) or _tainted(expr.right, env)
    if isinstance(expr, ast.BoolOp):
        return any(_tainted(v, env) for v in expr.values)
    if isinstance(expr, ast.UnaryOp):
        return _tainted(expr.operand, env)
    if isinstance(expr, ast.Compare):
        return _tainted(expr.left, env) or \
            any(_tainted(c, env) for c in expr.comparators)
    if isinstance(expr, ast.IfExp):
        return _tainted(expr.test, env) or _tainted(expr.body, env) or \
            _tainted(expr.orelse, env)
    if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
        return any(_tainted(e, env) for e in expr.elts)
    if isinstance(expr, ast.Dict):
        return any(v is not None and _tainted(v, env) for v in expr.values)
    if isinstance(expr, ast.Starred):
        return _tainted(expr.value, env)
    if isinstance(expr, (ast.JoinedStr, ast.FormattedValue)):
        vals = expr.values if isinstance(expr, ast.JoinedStr) \
            else [expr.value]
        return any(_tainted(v, env) for v in vals)
    if isinstance(expr, ast.Slice):
        return any(p is not None and _tainted(p, env)
                   for p in (expr.lower, expr.upper, expr.step))
    return False


class _FnReport:
    """Findings for one jitted function / kernel body."""

    def __init__(self, sf: SourceFile, params: Set[str], statics: Set[str],
                 kind: str):
        self.sf = sf
        self.params = params           # traced parameter names
        self.statics = statics
        self.kind = kind               # 'jitted function' | 'Pallas kernel'
        self.findings: List[Finding] = []

    def flag(self, line: int, msg: str) -> None:
        self.findings.append(Finding(self.sf.relpath, line, RULE_ID, msg))

    # -- expression-level hazards (concretization / host effects) --------

    def scan_expr(self, node: ast.AST, env: Set[str]) -> None:
        """Walk an expression (or simple statement), skipping nested
        function bodies, flagging concretization and host effects."""
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs (pl.when bodies, scan carriers) are traced by
            # the combinator that consumes them — out of scope here
            for dec in node.decorator_list:
                self.scan_expr(dec, env)
            return
        if isinstance(node, ast.Lambda):
            return
        if isinstance(node, ast.Call):
            fd = jitutil.dotted(node.func)
            if isinstance(node.func, ast.Name) \
                    and node.func.id in CONCRETIZE_CALLS \
                    and any(_tainted(a, env) for a in node.args):
                self.flag(node.lineno,
                          f"`{node.func.id}()` concretizes a traced value "
                          f"inside a {self.kind} — forces host sync or "
                          f"raises ConcretizationTypeError")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in HOST_METHODS \
                    and _tainted(node.func.value, env):
                self.flag(node.lineno,
                          f"`.{node.func.attr}()` on a traced value inside "
                          f"a {self.kind} — host synchronization defeats "
                          f"async dispatch and fails under trace")
            elif isinstance(node.func, ast.Name) and node.func.id == "print":
                self.flag(node.lineno,
                          f"host `print(...)` inside a {self.kind} runs at "
                          f"trace time only — use jax.debug.print")
            elif fd is not None \
                    and (fd.startswith("np.") or fd.startswith("numpy.")) \
                    and (any(_tainted(a, env) for a in node.args) or
                         any(_tainted(kw.value, env)
                             for kw in node.keywords)):
                self.flag(node.lineno,
                          f"`{fd}(...)` applies a host numpy op to a traced "
                          f"value inside a {self.kind} — use jnp/jax.lax")
        if isinstance(node, ast.IfExp) and _tainted(node.test, env):
            self.flag(node.lineno,
                      self._branch_msg("conditional expression", node.test))
        for child in ast.iter_child_nodes(node):
            self.scan_expr(child, env)

    # -- control-flow hazards --------------------------------------------

    def _branch_msg(self, what: str, test: ast.AST) -> str:
        msg = (f"Python {what} on a value data-dependent on traced "
               f"arguments of a {self.kind} — use jnp.where/lax.cond"
               + ("/pl.when" if self.kind == "Pallas kernel" else ""))
        bare = sorted({n.id for n in ast.walk(test)
                       if isinstance(n, ast.Name) and n.id in self.params})
        if bare and self.kind == "jitted function":
            msg += (f"; if `{'`/`'.join(bare)}` is a compile-time "
                    f"constant, add it to static_argnames")
        return msg

    def walk_block(self, stmts: List[ast.stmt], env: Set[str]) -> Set[str]:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                for dec in getattr(stmt, "decorator_list", []):
                    self.scan_expr(dec, env)
                continue
            if isinstance(stmt, ast.Global):
                self.flag(stmt.lineno,
                          f"`global` mutation inside a {self.kind} is a "
                          f"trace-time side effect — it runs once per "
                          f"compile, not per call")
                continue
            if isinstance(stmt, ast.If):
                self.scan_expr(stmt.test, env)
                if _tainted(stmt.test, env):
                    self.flag(stmt.lineno,
                              self._branch_msg("`if`", stmt.test))
                a = self.walk_block(stmt.body, set(env))
                b = self.walk_block(stmt.orelse, set(env))
                env.clear()
                env.update(a | b)
            elif isinstance(stmt, ast.While):
                self.scan_expr(stmt.test, env)
                if _tainted(stmt.test, env):
                    self.flag(stmt.lineno,
                              self._branch_msg("`while`", stmt.test))
                for _ in range(2):
                    env.update(self.walk_block(stmt.body, set(env)))
            elif isinstance(stmt, ast.For):
                self.scan_expr(stmt.iter, env)
                if _tainted(stmt.iter, env):
                    self.flag(stmt.lineno,
                              f"Python `for` over a traced iterable inside "
                              f"a {self.kind} — use lax.fori_loop/lax.scan")
                    for n in ast.walk(stmt.target):
                        if isinstance(n, ast.Name):
                            env.add(n.id)
                for _ in range(2):
                    env.update(self.walk_block(stmt.body, set(env)))
                env.update(self.walk_block(stmt.orelse, set(env)))
            elif isinstance(stmt, ast.Assert):
                self.scan_expr(stmt.test, env)
                if _tainted(stmt.test, env):
                    self.flag(stmt.lineno,
                              self._branch_msg("`assert`", stmt.test))
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self.scan_expr(item.context_expr, env)
                env.update(self.walk_block(stmt.body, set(env)))
            elif isinstance(stmt, ast.Try):
                env.update(self.walk_block(stmt.body, set(env)))
                for h in stmt.handlers:
                    env.update(self.walk_block(h.body, set(env)))
                env.update(self.walk_block(stmt.orelse, set(env)))
                env.update(self.walk_block(stmt.finalbody, set(env)))
            elif isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                self.scan_expr(stmt, env)
                value = stmt.value
                if value is None:
                    continue
                is_tainted = _tainted(value, env)
                targets = stmt.targets if isinstance(stmt, ast.Assign) \
                    else [stmt.target]
                for tgt in targets:
                    names = [n.id for n in ast.walk(tgt)
                             if isinstance(n, ast.Name)
                             and isinstance(n.ctx, ast.Store)]
                    if isinstance(stmt, ast.AugAssign):
                        if is_tainted:
                            env.update(names)
                    elif is_tainted:
                        env.update(names)
                    else:
                        env.difference_update(names)
            else:
                self.scan_expr(stmt, env)
        return env


def _analyze(sf: SourceFile, fn, traced: Set[str], statics: Set[str],
             kind: str) -> List[Finding]:
    rep = _FnReport(sf, traced, statics, kind)
    if isinstance(fn, ast.Lambda):
        env = set(traced)
        rep.scan_expr(fn.body, env)
        if isinstance(fn.body, ast.IfExp) and _tainted(fn.body.test, env):
            pass  # already flagged by scan_expr
    else:
        rep.walk_block(fn.body, set(traced))
    return rep.findings


def _static_default_findings(sf: SourceFile, jf) -> List[Finding]:
    out: List[Finding] = []
    if isinstance(jf.fn, ast.Lambda):
        return out
    defaults = jitutil.param_defaults(jf.fn)
    for name in sorted(jf.statics):
        d = defaults.get(name)
        if d is not None and isinstance(d, NONHASHABLE):
            out.append(Finding(
                sf.relpath, d.lineno, RULE_ID,
                f"static_argnames entry `{name}` has a non-hashable "
                f"default — jit's cache key requires hashable statics"))
    return out


def check(files: List[SourceFile], config: dict) -> List[Finding]:
    cfg = config.get("r7", {})
    scope = cfg.get("scope", [])
    findings: List[Finding] = []
    for sf in files:
        if scope and not any(s in sf.relpath for s in scope):
            continue
        seen: Set[int] = set()
        for jf in jitutil.iter_jitted(sf.tree):
            if id(jf.fn) in seen:
                continue
            seen.add(id(jf.fn))
            params = set(jitutil.positional_params(jf.fn)) \
                | set(jitutil.kwonly_params(jf.fn))
            traced = {p for p in params
                      if p not in jf.statics and p != "self"}
            findings.extend(
                _analyze(sf, jf.fn, traced, jf.statics, "jitted function"))
            findings.extend(_static_default_findings(sf, jf))
        for pc in jitutil.iter_pallas_calls(sf.tree):
            k = pc.kernel
            if k is None or id(k) in seen:
                continue
            seen.add(id(k))
            pos = jitutil.positional_params(k)[pc.kernel_bound_pos:]
            # kw-only params come from functools.partial at build time:
            # compile-time constants, untainted
            findings.extend(
                _analyze(sf, k, set(pos), set(jitutil.kwonly_params(k)),
                         "Pallas kernel"))
    return findings
