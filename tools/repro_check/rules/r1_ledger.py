"""R1 — ledger conservation.

Enumerates bounded per-function control-flow paths and checks that every
path which *claims* a resource (kv_used page charge, BlockAllocator
refcount, prefix pin) or *resets* a claim record (``req.kv_server = -1``
/ ``req.kv_blocks = 0``) also carries the matching release — or hands
the claim off by returning a non-constant value, or is explicitly
annotated ``# repro-check: orphan(<counter>)``.

The enumerator is condition-correlated for the two guard idioms the
repo uses: ``if x is None:`` after ``x = ...allocate(...)`` cancels the
charge on the None branch, and two ``if shared:`` tests on the same
un-reassigned name take consistent branches (so a charge guarded by
``if shared:`` is matched against a release under the same guard).

Sub-checks:

R1a  a path that resets the claim record must release the pages (or
     hand the still-claimed object off via a value return).
R1b  in pin-ledger files, a path that frees kv pages *and* resets the
     claim record must also unpin the shared prefix (the PR 6 requeue
     bug shape: ``_kv_free`` without ``_prefix_unpin`` leaked pins).
R1c  in refcount files, a path with a net-positive refcount charge that
     ends in a constant return (None/False — i.e. "I failed") leaked
     the charge.
R1d  every subscript store to a link ledger (``link_free``/``links``/
     ``free_at``) must cover the whole path, not one link. Accepted
     whole-path forms: a store inside a ``for ... in <path>`` loop; a
     vectorized store / ``np.add.at`` whose index expression mentions
     the path (``link_free[path_idx] = ...``); and the single-link fast
     path — a store indexed by a name assigned from a configured
     single-link map (``name = self._single_link[j]``) under an
     ``if name is not None:`` guard, where a one-link path *is* the
     whole path by construction.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..core import Finding, SourceFile, end_line

RULE_ID = "R1"


@dataclass(frozen=True)
class Ev:
    kind: str               # charge | release | reset | pin_charge |
                            # pin_release | cancel
    counter: str            # kv_used | refcount | prefix_pin
    line: int
    target: Optional[str] = None   # assign target (None-guard cancelling)


# a path: (events, terminal, assumptions); terminals are
# fall | return_expr | return_const | raise
Path = Tuple[Tuple[Ev, ...], str, dict]


def _call_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _const_int(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = _const_int(node.operand)
        return None if inner is None else -inner
    return None


def _is_kv_used_sub(node: ast.AST) -> bool:
    if not isinstance(node, ast.Subscript):
        return False
    v = node.value
    name = v.attr if isinstance(v, ast.Attribute) else \
        (v.id if isinstance(v, ast.Name) else None)
    return name == "kv_used"


def _test_info(test: ast.AST) -> Optional[Tuple[str, str, bool]]:
    """(kind, name, body_value) for correlatable tests.

    kind 'truthy': body taken when name is truthy (body_value=True) or
    falsy (``not name``). kind 'none': body taken when name is None
    (``x is None``) or not None (``x is not None``).
    """
    if isinstance(test, ast.Name):
        return "truthy", test.id, True
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not) and \
            isinstance(test.operand, ast.Name):
        return "truthy", test.operand.id, False
    if isinstance(test, ast.Compare) and len(test.ops) == 1 and \
            isinstance(test.comparators[0], ast.Constant) and \
            test.comparators[0].value is None and \
            isinstance(test.left, ast.Name):
        if isinstance(test.ops[0], ast.Is):
            return "none", test.left.id, True
        if isinstance(test.ops[0], ast.IsNot):
            return "none", test.left.id, False
    return None


class _StmtEvents:
    """Extract ledger events from one simple statement."""

    def __init__(self, cfg: dict):
        self.cfg = cfg

    def _calls(self, node: ast.AST, target: Optional[str],
               line: int) -> List[Ev]:
        evs: List[Ev] = []
        for call in (n for n in ast.walk(node) if isinstance(n, ast.Call)):
            name = _call_name(call)
            if name == "_kv_free":
                evs.append(Ev("release", "kv_used", line))
            elif name == "_prefix_unpin":
                evs.append(Ev("pin_release", "prefix_pin", line))
            elif name == "_prefix_attach":
                evs.append(Ev("pin_charge", "prefix_pin", line))
            elif name in self.cfg["refcount_charge"]:
                evs.append(Ev("charge", "refcount", line, target=target))
            elif name in self.cfg["refcount_release"]:
                evs.append(Ev("release", "refcount", line))
        return evs

    def _resets(self, targets, values, line: int) -> List[Ev]:
        evs: List[Ev] = []
        resets = self.cfg["claim_resets"]
        for tgt, val in zip(targets, values, strict=True):
            if isinstance(tgt, ast.Attribute) and tgt.attr in resets \
                    and val is not None \
                    and _const_int(val) == resets[tgt.attr]:
                evs.append(Ev("reset", "kv_used", line))
        return evs

    def events(self, st: ast.stmt) -> List[Ev]:
        line = st.lineno
        evs: List[Ev] = []
        if isinstance(st, ast.AugAssign) and _is_kv_used_sub(st.target):
            if isinstance(st.op, ast.Add):
                evs.append(Ev("charge", "kv_used", line))
            elif isinstance(st.op, ast.Sub):
                evs.append(Ev("release", "kv_used", line))
            evs.extend(self._calls(st.value, None, line))
            return evs
        if isinstance(st, ast.Assign):
            # single Name target with a charging call on the RHS keeps
            # the target so a later `if target is None` can cancel it
            target = st.targets[0].id \
                if len(st.targets) == 1 and isinstance(st.targets[0], ast.Name) \
                else None
            evs.extend(self._calls(st.value, target, line))
            for tgt in st.targets:
                if isinstance(tgt, ast.Tuple) and \
                        isinstance(st.value, ast.Tuple) and \
                        len(tgt.elts) == len(st.value.elts):
                    evs.extend(self._resets(tgt.elts, st.value.elts, line))
                else:
                    evs.extend(self._resets([tgt], [st.value], line))
            return evs
        evs.extend(self._calls(st, None, line))
        return evs


class _PathEnumerator:
    def __init__(self, extractor: _StmtEvents, max_paths: int):
        self.ex = extractor
        self.max_paths = max_paths

    def paths(self, stmts: List[ast.stmt], assume: dict) -> List[Path]:
        acc: List[Path] = [((), "fall", dict(assume))]
        for st in stmts:
            nxt: List[Path] = []
            for evs, term, asm in acc:
                if term != "fall":
                    nxt.append((evs, term, asm))
                    continue
                for evs2, term2, asm2 in self._stmt(st, asm):
                    nxt.append((evs + evs2, term2, asm2))
            acc = nxt[: self.max_paths]
        return acc

    def _assigned_names(self, st: ast.stmt) -> List[str]:
        if isinstance(st, ast.Assign):
            return [t.id for t in st.targets if isinstance(t, ast.Name)]
        if isinstance(st, (ast.AugAssign, ast.AnnAssign)) and \
                isinstance(st.target, ast.Name):
            return [st.target.id]
        return []

    def _stmt(self, st: ast.stmt, asm: dict) -> List[Path]:
        if isinstance(st, ast.Return):
            term = "return_const" if st.value is None or \
                isinstance(st.value, ast.Constant) else "return_expr"
            return [(tuple(self.ex.events(st)), term, asm)]
        if isinstance(st, ast.Raise):
            return [((), "raise", asm)]
        if isinstance(st, (ast.Break, ast.Continue)):
            return [((), "fall", asm)]
        if isinstance(st, ast.If):
            return self._if(st, asm)
        if isinstance(st, (ast.For, ast.AsyncFor, ast.While)):
            once = self.paths(list(st.body) + list(st.orelse or []), asm)
            skip = self.paths(list(st.orelse), asm) if st.orelse \
                else [((), "fall", asm)]
            return once + skip
        if isinstance(st, (ast.With, ast.AsyncWith)):
            return self.paths(st.body, asm)
        if isinstance(st, ast.Try):
            out = self.paths(
                list(st.body) + list(st.orelse) + list(st.finalbody), asm)
            for h in st.handlers:
                out.extend(self.paths(list(h.body) + list(st.finalbody),
                                      asm))
            return out
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            return [((), "fall", asm)]
        evs = tuple(self.ex.events(st))
        names = self._assigned_names(st)
        if names:
            asm = {k: v for k, v in asm.items() if k[1] not in names}
        return [(evs, "fall", asm)]

    def _if(self, st: ast.If, asm: dict) -> List[Path]:
        info = _test_info(st.test)
        out: List[Path] = []
        branches = [(True, st.body), (False, list(st.orelse))]
        for is_body, stmts in branches:
            pre: Tuple[Ev, ...] = ()
            asm2 = dict(asm)
            if info is not None:
                kind, name, body_val = info
                val = body_val if is_body else (not body_val)
                known = asm.get((kind, name))
                if known is not None and known != val:
                    continue                    # inconsistent branch
                asm2[(kind, name)] = val
                # the None branch of an `x is None` guard cancels x's
                # pending charge: allocation failed, nothing was claimed
                if kind == "none" and val:
                    pre = (Ev("cancel", "", st.lineno, target=name),)
                if kind == "truthy" and not val:
                    pre = (Ev("cancel", "", st.lineno, target=name),)
            if stmts:
                for evs, term, asm3 in self.paths(stmts, asm2):
                    out.append((pre + evs, term, asm3))
            else:
                out.append((pre, "fall", asm2))
        return out


def _apply_cancels(evs: Tuple[Ev, ...]) -> List[Ev]:
    """Drop charges whose assign target was observed to be None/falsy."""
    out: List[Optional[Ev]] = list(evs)
    for i, ev in enumerate(out):
        if ev is not None and ev.kind == "cancel" and ev.target:
            for j in range(i - 1, -1, -1):
                prev = out[j]
                if prev is not None and prev.kind == "charge" and \
                        prev.target == ev.target:
                    out[j] = None
                    break
    return [e for e in out if e is not None and e.kind != "cancel"]


def _check_function(fn, sf: SourceFile, cfg: dict, findings: List[Finding],
                    in_pin_file: bool, in_refcount_file: bool) -> None:
    if fn.name in cfg["exempt_functions"]:
        return
    is_owner = fn.name in cfg["owner_functions"]
    annotated = sf.orphan_counters(fn.lineno, end_line(fn))
    enum = _PathEnumerator(_StmtEvents(cfg), cfg["max_paths"])
    seen = set()
    for evs_raw, term, _asm in enum.paths(fn.body, {}):
        evs = _apply_cancels(evs_raw)
        kv_release = any(e.kind == "release" and e.counter == "kv_used"
                         for e in evs)
        any_release = any(e.kind == "release" for e in evs)
        resets = [e for e in evs if e.kind == "reset"]
        pin_release = any(e.kind == "pin_release" for e in evs)
        # R1a — claim record reset without a release on the same path
        if resets and not any_release and term != "return_expr" \
                and "kv_used" not in annotated:
            key = ("a", resets[0].line)
            if key not in seen:
                seen.add(key)
                findings.append(Finding(
                    sf.relpath, resets[0].line, RULE_ID,
                    f"{fn.name}: kv claim record reset without releasing "
                    f"the pages on this path (kv_used); release, hand the "
                    f"claim off, or annotate `# repro-check: "
                    f"orphan(kv_used)`"))
        # R1b — freed + reset but prefix pin not released (PR 6 shape)
        if in_pin_file and kv_release and resets and not pin_release \
                and "prefix_pin" not in annotated:
            key = ("b", resets[0].line)
            if key not in seen:
                seen.add(key)
                findings.append(Finding(
                    sf.relpath, resets[0].line, RULE_ID,
                    f"{fn.name}: kv pages freed and claim reset but the "
                    f"shared-prefix pin is not released on this path "
                    f"(prefix_pin); call _prefix_unpin or annotate "
                    f"`# repro-check: orphan(prefix_pin)`"))
        # R1c — net refcount charge leaked through a failure return
        if in_refcount_file and not is_owner and term == "return_const" \
                and "refcount" not in annotated:
            charges = [e for e in evs
                       if e.kind == "charge" and e.counter == "refcount"]
            n_rel = sum(e.kind == "release" and e.counter == "refcount"
                        for e in evs)
            if len(charges) > n_rel:
                key = ("c", charges[-1].line)
                if key not in seen:
                    seen.add(key)
                    findings.append(Finding(
                        sf.relpath, charges[-1].line, RULE_ID,
                        f"{fn.name}: refcount charged here but a failure "
                        f"path returns a constant without releasing it "
                        f"(refcount); free the blocks or annotate "
                        f"`# repro-check: orphan(refcount)`"))


def _check_link_bookings(sf: SourceFile, cfg: dict,
                         findings: List[Finding]) -> None:
    ledgers = set(cfg["link_ledger_names"])
    singles = set(cfg.get("single_link_names", []))
    markers = list(cfg.get("path_index_markers", ["path"]))

    def _unparse(node) -> str:
        try:
            return ast.unparse(node)
        except Exception:
            return ""

    class V(ast.NodeVisitor):
        def __init__(self):
            self.loop_iters: List[str] = []
            # names assigned from a single-link map (`n = _single_link[j]`)
            self.single_names: set = set()
            # names currently guarded by `if <name> is not None:`
            self.not_none: List[str] = []

        def visit_For(self, node):
            self.loop_iters.append(_unparse(node.iter))
            self.generic_visit(node)
            self.loop_iters.pop()

        def visit_If(self, node):
            info = _test_info(node.test)
            self.visit(node.test)
            guard = None
            if info is not None and info[0] == "none" and not info[2]:
                guard = info[1]          # `x is not None` — body branch
            if guard is not None:
                self.not_none.append(guard)
            for st in node.body:
                self.visit(st)
            if guard is not None:
                self.not_none.pop()
            for st in node.orelse:
                self.visit(st)

        def _store_name(self, tgt) -> Optional[str]:
            if not isinstance(tgt, ast.Subscript):
                return None
            v = tgt.value
            name = v.attr if isinstance(v, ast.Attribute) else \
                (v.id if isinstance(v, ast.Name) else None)
            return name if name in ledgers else None

        def _index_ok(self, idx) -> bool:
            # vectorized whole-path booking: the index expression itself
            # names the path (`link_free[path_idx] = ...`)
            text = _unparse(idx)
            if any(m in text for m in markers):
                return True
            # single-link fast path: index assigned from a single-link
            # map and proven non-None — a one-link path is the whole path
            return isinstance(idx, ast.Name) \
                and idx.id in self.single_names \
                and idx.id in self.not_none

        def _check(self, tgt, line):
            name = self._store_name(tgt)
            if name is None:
                return
            if any("path" in it for it in self.loop_iters):
                return
            if self._index_ok(tgt.slice):
                return
            findings.append(Finding(
                sf.relpath, line, RULE_ID,
                f"link ledger `{name}[...]` booked outside a "
                f"`for ... in <path>` loop — a booking must cover "
                f"every link on the path"))

        def visit_Assign(self, node):
            if len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Subscript):
                v = node.value.value
                base = v.attr if isinstance(v, ast.Attribute) else \
                    (v.id if isinstance(v, ast.Name) else None)
                if base in singles:
                    self.single_names.add(node.targets[0].id)
            for tgt in node.targets:
                self._check(tgt, node.lineno)
            self.generic_visit(node)

        def visit_AugAssign(self, node):
            self._check(node.target, node.lineno)
            self.generic_visit(node)

        def visit_Call(self, node):
            # vectorized booking via `np.add.at(ledger, idx, dur)` — the
            # ufunc form of `ledger[idx] += dur`; same whole-path rule
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "at" \
                    and isinstance(f.value, ast.Attribute) \
                    and isinstance(f.value.value, ast.Name) \
                    and f.value.value.id in ("np", "numpy") \
                    and len(node.args) >= 2:
                tgt = node.args[0]
                name = tgt.attr if isinstance(tgt, ast.Attribute) else \
                    (tgt.id if isinstance(tgt, ast.Name) else None)
                if name in ledgers \
                        and not any("path" in it for it in self.loop_iters) \
                        and not self._index_ok(node.args[1]):
                    findings.append(Finding(
                        sf.relpath, node.lineno, RULE_ID,
                        f"link ledger `{name}` booked via np.{f.value.attr}"
                        f".at without indexing the whole path — a booking "
                        f"must cover every link on the path"))
            self.generic_visit(node)

    V().visit(sf.tree)


def check(files: List[SourceFile], config: dict) -> List[Finding]:
    cfg = config["r1"]
    findings: List[Finding] = []
    for sf in files:
        if sf.matches(cfg["ledger_files"]):
            in_pin = sf.matches(cfg["pin_files"])
            in_ref = sf.matches(cfg["refcount_files"])
            funcs = [n for n in ast.walk(sf.tree)
                     if isinstance(n, (ast.FunctionDef,
                                       ast.AsyncFunctionDef))]
            for fn in funcs:
                _check_function(fn, sf, cfg, findings, in_pin, in_ref)
        if sf.matches(cfg["link_files"]):
            _check_link_bookings(sf, cfg, findings)
    return findings
