"""Rule registry. Each module exposes RULE_ID and check(files, config)."""
from . import (r1_ledger, r2_events, r3_coverage, r4_determinism,
               r5_units, r6_trace, r7_tracing, r8_recompile, r9_pallas)

ALL_RULES = {
    m.RULE_ID: m
    for m in (r1_ledger, r2_events, r3_coverage, r4_determinism, r5_units,
              r6_trace, r7_tracing, r8_recompile, r9_pallas)
}
