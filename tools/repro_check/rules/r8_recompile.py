"""R8 — recompilation hazards.

``jax.jit`` specializes on argument *shapes*: a call site whose operand
shapes derive from per-request Python values recompiles silently for
every distinct shape — the classic 100× first-token stall. This rule
finds every class that jits callables onto ``self`` (``self._decode =
jax.jit(...)``), walks the call graph from its public entry points
(``step``/``submit``/...), and runs a two-level taint analysis per
reachable method:

- **value-taint**: per-request Python values — method parameters,
  element reads from ``self`` containers (``self.queue[0]``),
  ``.pop(...)`` results, attributes (``req.prompt``) and ``len()`` of
  tainted values;
- **shape-taint**: arrays whose *shape* depends on a value-tainted
  quantity — ``[0] * n``, list concatenation with such a list, and
  array constructors (``jnp.asarray``/``zeros``/``arange``/...) fed a
  tainted non-literal argument. A *literal* list fed to a data
  constructor keeps a static shape even when its elements are tainted
  (``jnp.asarray([[tok]])`` is fine).

Flagged: passing a shape-tainted operand to a jitted callee (pad or
bucket to a fixed shape set instead), ``**``-splatting kwargs into a
jitted callee (dict key order enters the cache key), and jitted
lambdas that close over a locally-constructed array (it is baked into
the compiled graph as a constant).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from ..core import Finding, SourceFile
from . import jitutil

RULE_ID = "R8"

NONE, VAL, SHAPE = 0, 1, 2

# constructors whose output shape follows a *shape/size argument*: any
# tainted argument (even inside a literal tuple) makes the shape dynamic
SHAPE_CTORS = {"zeros", "ones", "full", "empty", "arange", "linspace",
               "broadcast_to", "reshape", "tile", "repeat", "pad",
               "zeros_like_shape"}
# constructors whose output shape follows the *data argument*: a literal
# list pins the shape; a tainted non-literal argument does not
DATA_CTORS = {"asarray", "array", "stack", "concatenate", "hstack",
              "vstack"}


def _ctor_kind(func: ast.AST) -> Optional[str]:
    d = jitutil.dotted(func)
    if d is None:
        return None
    last = d.split(".")[-1]
    if last in SHAPE_CTORS:
        return "shape"
    if last in DATA_CTORS:
        return "data"
    return None


def _is_self_attr(node: ast.AST) -> bool:
    return isinstance(node, ast.Attribute) \
        and isinstance(node.value, ast.Name) and node.value.id == "self"


class _Taint:
    def __init__(self, env: Dict[str, int]):
        self.env = env

    def level(self, expr: ast.AST) -> int:
        env = self.env
        if isinstance(expr, ast.Name):
            return env.get(expr.id, NONE)
        if isinstance(expr, ast.Attribute):
            # req.prompt: attribute of a tainted object is per-request
            return VAL if self.level(expr.value) > NONE else NONE
        if isinstance(expr, ast.Subscript):
            base = self.level(expr.value)
            if base == SHAPE:
                return SHAPE              # slicing a dynamic-shape array
            if base == VAL:
                return VAL
            # element read from a self container: per-request state
            return VAL if _is_self_attr(expr.value) else NONE
        if isinstance(expr, ast.Call):
            return self._call_level(expr)
        if isinstance(expr, ast.BinOp):
            l, r = self.level(expr.left), self.level(expr.right)
            if isinstance(expr.op, ast.Mult):
                # [pad] * n with n per-request → dynamic-length list
                sides = ((expr.left, r), (expr.right, l))
                for lit, other in sides:
                    if isinstance(lit, (ast.List, ast.Tuple,
                                        ast.Constant)) and other >= VAL:
                        return SHAPE
            return max(l, r)
        if isinstance(expr, ast.BoolOp):
            return max((self.level(v) for v in expr.values), default=NONE)
        if isinstance(expr, ast.UnaryOp):
            return self.level(expr.operand)
        if isinstance(expr, ast.Compare):
            lv = max((self.level(c) for c in expr.comparators),
                     default=NONE)
            return min(max(self.level(expr.left), lv), VAL)
        if isinstance(expr, ast.IfExp):
            return max(self.level(expr.test), self.level(expr.body),
                       self.level(expr.orelse))
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            # literal container: static length; tainted elements stay VAL
            lv = max((self.level(e) for e in expr.elts), default=NONE)
            return min(lv, VAL) if not any(
                isinstance(e, ast.Starred) for e in expr.elts) else lv
        if isinstance(expr, ast.Dict):
            return max((self.level(v) for v in expr.values
                        if v is not None), default=NONE)
        if isinstance(expr, ast.Starred):
            return self.level(expr.value)
        return NONE

    def _call_level(self, call: ast.Call) -> int:
        kind = _ctor_kind(call.func)
        args = list(call.args) + [kw.value for kw in call.keywords]
        if kind == "shape":
            # any tainted arg — including inside a literal shape tuple
            def deep(a):
                if isinstance(a, (ast.Tuple, ast.List)):
                    return max((deep(e) for e in a.elts), default=NONE)
                return self.level(a)
            if max((deep(a) for a in args), default=NONE) >= VAL:
                return SHAPE
            return NONE
        if kind == "data":
            lv = NONE
            for a in args:
                la = self.level(a)
                if la >= VAL and not isinstance(a, (ast.List, ast.Tuple,
                                                   ast.Constant)):
                    return SHAPE
                lv = max(lv, min(la, VAL))
            return lv
        if isinstance(call.func, ast.Attribute) and call.func.attr == "pop":
            return VAL                    # queue.pop(...) hands out a request
        recv = self.level(call.func.value) \
            if isinstance(call.func, ast.Attribute) else NONE
        lv = max((self.level(a) for a in args), default=NONE)
        return min(max(recv, lv), VAL)    # unknown callees cap at value


def _module_jitted_names(tree: ast.Module) -> Set[str]:
    names: Set[str] = set()
    for jf in jitutil.iter_jitted(tree):
        if isinstance(jf.fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(jf.fn.name)
    return names


def _jit_attrs(cls: ast.ClassDef) -> Dict[str, ast.Call]:
    """attr name -> the jax.jit(...) call assigned to self.<attr>."""
    out: Dict[str, ast.Call] = {}
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and _is_self_attr(node.targets[0]) \
                and isinstance(node.value, ast.Call) \
                and jitutil.is_jax_jit(node.value.func):
            out[node.targets[0].attr] = node.value
    return out


def _methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {s.name: s for s in cls.body
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _reachable(cls: ast.ClassDef, entries: List[str]) -> List[str]:
    methods = _methods(cls)
    seen: List[str] = []
    queue = [e for e in entries if e in methods]
    while queue:
        name = queue.pop(0)
        if name in seen:
            continue
        seen.append(name)
        for node in ast.walk(methods[name]):
            if isinstance(node, ast.Call) and _is_self_attr(node.func) \
                    and node.func.attr in methods:
                queue.append(node.func.attr)
    return seen


class _MethodScan:
    def __init__(self, sf: SourceFile, jit_names: Set[str],
                 module_jitted: Set[str]):
        self.sf = sf
        self.jit_names = jit_names
        self.module_jitted = module_jitted
        self.findings: List[Finding] = []
        self._flagged: Set[Tuple[int, str]] = set()

    def flag(self, line: int, name: str, msg: str) -> None:
        if (line, name) in self._flagged:
            return
        self._flagged.add((line, name))
        self.findings.append(Finding(self.sf.relpath, line, RULE_ID, msg))

    def _jitted_callee(self, func: ast.AST) -> Optional[str]:
        if _is_self_attr(func) and func.attr in self.jit_names:
            return f"self.{func.attr}"
        if isinstance(func, ast.Name) and func.id in self.module_jitted:
            return func.id
        return None

    def scan_expr(self, node: ast.AST, taint: _Taint) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return
        if isinstance(node, ast.Call):
            callee = self._jitted_callee(node.func)
            if callee is not None:
                for kw in node.keywords:
                    if kw.arg is None:
                        self.flag(node.lineno, callee,
                                  f"`**` kwargs splat into jitted "
                                  f"`{callee}` — dict keys and order "
                                  f"enter the jit cache key; pass "
                                  f"explicit keywords")
                shaped = [a for a in list(node.args)
                          + [kw.value for kw in node.keywords
                             if kw.arg is not None]
                          if taint.level(a) == SHAPE]
                if shaped:
                    self.flag(node.lineno, callee,
                              f"operand shape at this `{callee}` call "
                              f"derives from per-request Python values — "
                              f"every distinct shape silently recompiles; "
                              f"pad or bucket to a fixed shape set")
        for child in ast.iter_child_nodes(node):
            self.scan_expr(child, taint)

    def walk_block(self, stmts: List[ast.stmt],
                   env: Dict[str, int]) -> Dict[str, int]:
        taint = _Taint(env)
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(stmt, ast.If):
                self.scan_expr(stmt.test, taint)
                a = self.walk_block(stmt.body, dict(env))
                b = self.walk_block(stmt.orelse, dict(env))
                for k in set(a) | set(b):
                    env[k] = max(a.get(k, NONE), b.get(k, NONE))
            elif isinstance(stmt, (ast.While, ast.For)):
                if isinstance(stmt, ast.For):
                    self.scan_expr(stmt.iter, taint)
                    lv = min(taint.level(stmt.iter), VAL)
                    if lv:
                        for n in ast.walk(stmt.target):
                            if isinstance(n, ast.Name):
                                env[n.id] = lv
                else:
                    self.scan_expr(stmt.test, taint)
                for _ in range(2):
                    env.update(self.walk_block(stmt.body, dict(env)))
                env.update(self.walk_block(stmt.orelse, dict(env)))
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self.scan_expr(item.context_expr, taint)
                env.update(self.walk_block(stmt.body, dict(env)))
            elif isinstance(stmt, ast.Try):
                env.update(self.walk_block(stmt.body, dict(env)))
                for h in stmt.handlers:
                    env.update(self.walk_block(h.body, dict(env)))
                env.update(self.walk_block(stmt.orelse, dict(env)))
                env.update(self.walk_block(stmt.finalbody, dict(env)))
            elif isinstance(stmt, (ast.Assign, ast.AnnAssign,
                                   ast.AugAssign)):
                self.scan_expr(stmt, taint)
                if stmt.value is None:
                    continue
                lv = taint.level(stmt.value)
                targets = stmt.targets if isinstance(stmt, ast.Assign) \
                    else [stmt.target]
                for tgt in targets:
                    if isinstance(tgt, ast.Name):
                        if isinstance(stmt, ast.AugAssign):
                            env[tgt.id] = max(env.get(tgt.id, NONE), lv)
                        else:
                            env[tgt.id] = lv
                    elif isinstance(tgt, ast.Subscript) \
                            and isinstance(tgt.value, ast.Name):
                        env[tgt.value.id] = max(
                            env.get(tgt.value.id, NONE), lv)
                    elif isinstance(tgt, ast.Tuple):
                        for n in tgt.elts:
                            if isinstance(n, ast.Name):
                                env[n.id] = lv
            else:
                self.scan_expr(stmt, taint)
        return env


def _closure_capture_findings(sf: SourceFile,
                              cls: ast.ClassDef) -> List[Finding]:
    """jax.jit(lambda ...) whose body reads a local bound to an array
    constructor — the array is baked into the jitted graph."""
    out: List[Finding] = []
    for meth in _methods(cls).values():
        assigns = jitutil.local_assignments(meth)
        for node in ast.walk(meth):
            if not (isinstance(node, ast.Call)
                    and jitutil.is_jax_jit(node.func) and node.args
                    and isinstance(node.args[0], ast.Lambda)):
                continue
            lam = node.args[0]
            params = set(jitutil.positional_params(lam)) \
                | set(jitutil.kwonly_params(lam))
            for name_node in ast.walk(lam.body):
                if not (isinstance(name_node, ast.Name)
                        and isinstance(name_node.ctx, ast.Load)
                        and name_node.id not in params):
                    continue
                bound = assigns.get(name_node.id)
                if isinstance(bound, ast.Call) \
                        and _ctor_kind(bound.func) is not None:
                    out.append(Finding(
                        sf.relpath, node.lineno, RULE_ID,
                        f"jitted lambda closes over array "
                        f"`{name_node.id}` — it is baked into the "
                        f"compiled graph as a constant; pass it as an "
                        f"argument instead"))
    return out


def check(files: List[SourceFile], config: dict) -> List[Finding]:
    cfg = config.get("r8", {})
    scope = cfg.get("scope", [])
    entry_override = cfg.get("entry_methods", [])
    findings: List[Finding] = []
    for sf in files:
        if scope and not any(s in sf.relpath for s in scope):
            continue
        module_jitted = _module_jitted_names(sf.tree)
        for cls in ast.walk(sf.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            jit_names = set(_jit_attrs(cls))
            if not jit_names:
                continue
            findings.extend(_closure_capture_findings(sf, cls))
            methods = _methods(cls)
            entries = entry_override or sorted(
                m for m in methods if not m.startswith("_"))
            scan = _MethodScan(sf, jit_names, module_jitted)
            for name in _reachable(cls, entries):
                meth = methods[name]
                env = {p: VAL
                       for p in jitutil.positional_params(meth)
                       + jitutil.kwonly_params(meth) if p != "self"}
                scan.walk_block(meth.body, env)
            findings.extend(scan.findings)
    return findings
