"""R2 — event-handler exhaustiveness.

Three checks, whole-program:

1. Every ``Event`` subclass defined in the events file must be a key of
   the ``Runtime._HANDLERS`` dispatch table (subclassed events may route
   to a handled base, so only root-of-dispatch events are required).
2. Every concrete runtime must *really* handle every handler in the
   table: the MRO-resolved method must have a non-``pass`` body (an
   explicit ``raise`` counts — loud is fine, silent drop is not), or be
   listed in the config exemptions with a reason.
3. No dead handlers: an ``on_*`` method on a runtime that no dispatch
   table entry routes to is unreachable via ``handle``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from ..core import Finding, SourceFile

RULE_ID = "R2"


def _base_name(b: ast.expr) -> Optional[str]:
    if isinstance(b, ast.Name):
        return b.id
    if isinstance(b, ast.Attribute):
        return b.attr
    return None


def _method_kind(fn: ast.FunctionDef) -> str:
    """'pass' for a stub body, 'raise' if it only raises, else 'real'."""
    body = list(fn.body)
    if body and isinstance(body[0], ast.Expr) and \
            isinstance(body[0].value, ast.Constant) and \
            isinstance(body[0].value.value, str):
        body = body[1:]                          # drop docstring
    if all(isinstance(st, ast.Pass) for st in body):
        return "pass"
    if len(body) == 1 and isinstance(body[0], ast.Raise):
        return "raise"
    return "real"


class _ClassIndex:
    """name -> (bases, {method: kind}, file, line) over all files."""

    def __init__(self, files: List[SourceFile]):
        self.classes: Dict[str, Tuple[List[str], Dict[str, str],
                                      str, int]] = {}
        for sf in files:
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.ClassDef):
                    continue
                bases = [b for b in map(_base_name, node.bases) if b]
                methods = {st.name: _method_kind(st) for st in node.body
                           if isinstance(st, (ast.FunctionDef,
                                              ast.AsyncFunctionDef))}
                self.classes[node.name] = (bases, methods, sf.relpath,
                                           node.lineno)

    def mro(self, name: str) -> List[str]:
        """Depth-first left-to-right linearization (good enough here)."""
        out, stack = [], [name]
        while stack:
            cur = stack.pop(0)
            if cur in out or cur not in self.classes:
                continue
            out.append(cur)
            stack = list(self.classes[cur][0]) + stack
        return out

    def resolve(self, cls: str, method: str) -> Optional[Tuple[str, str]]:
        """(defining_class, kind) for the MRO-resolved method."""
        for c in self.mro(cls):
            methods = self.classes[c][1]
            if method in methods:
                return c, methods[method]
        return None

    def event_subclasses(self, base: str) -> List[Tuple[str, str, int]]:
        roots = {base}
        changed = True
        found: List[Tuple[str, str, int]] = []
        while changed:
            changed = False
            for name, (bases, _m, f, line) in self.classes.items():
                if name in roots:
                    continue
                if any(b in roots for b in bases):
                    roots.add(name)
                    found.append((name, f, line))
                    changed = True
        return found


def _dispatch_table(sf: SourceFile, cls_name: str,
                    attr: str) -> Tuple[Dict[str, str], int]:
    """{EventClassName: handler_name} from the class-level dict literal."""
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            for st in node.body:
                if isinstance(st, ast.Assign) and any(
                        isinstance(t, ast.Name) and t.id == attr
                        for t in st.targets) and \
                        isinstance(st.value, ast.Dict):
                    table = {}
                    for k, v in zip(st.value.keys, st.value.values,
                                    strict=True):
                        kn = _base_name(k) if k is not None else None
                        if kn and isinstance(v, ast.Constant):
                            table[kn] = v.value
                    return table, st.lineno
    return {}, 0


def check(files: List[SourceFile], config: dict) -> List[Finding]:
    cfg = config["r2"]
    findings: List[Finding] = []
    ev_file = next((sf for sf in files
                    if sf.relpath.endswith(cfg["events_file"])), None)
    if ev_file is None:
        return findings     # fixture trees without the events file
    index = _ClassIndex(files)
    table, table_line = _dispatch_table(ev_file, cfg["dispatch_class"],
                                        cfg["dispatch_table"])
    if not table:
        findings.append(Finding(
            ev_file.relpath, 1, RULE_ID,
            f"{cfg['dispatch_class']}.{cfg['dispatch_table']} dispatch "
            f"table not found or not a dict literal"))
        return findings

    # (1) every Event subclass has a dispatch entry (itself or a base)
    handled: Set[str] = set(table)
    for name, f, line in index.event_subclasses(cfg["event_base"]):
        if not f.endswith(cfg["events_file"]):
            continue
        if name not in handled and \
                not any(b in handled for b in index.mro(name)[1:]):
            findings.append(Finding(
                f, line, RULE_ID,
                f"event class {name} has no entry in "
                f"{cfg['dispatch_class']}.{cfg['dispatch_table']} — "
                f"handle() would raise TypeError on it"))

    # (2) every concrete runtime really handles every table entry
    for rt in cfg["runtimes"]:
        if rt not in index.classes:
            continue
        _bases, _methods, rt_file, rt_line = index.classes[rt]
        exempt = cfg["exemptions"].get(rt, {})
        for ev_name, handler in sorted(table.items()):
            resolved = index.resolve(rt, handler)
            if resolved is None:
                findings.append(Finding(
                    rt_file, rt_line, RULE_ID,
                    f"{rt}: no definition of {handler} anywhere in its "
                    f"MRO — {ev_name} events would crash"))
                continue
            _definer, kind = resolved
            if kind == "pass" and handler not in exempt:
                findings.append(Finding(
                    rt_file, rt_line, RULE_ID,
                    f"{rt}: {ev_name} events fall through to a silent "
                    f"`pass` stub for {handler}; implement it, raise, or "
                    f"add a config exemption with a reason"))

    # (3) dead on_* handlers nothing dispatches to
    routed = set(table.values())
    for rt in cfg["runtimes"]:
        if rt not in index.classes:
            continue
        _bases, methods, rt_file, _line = index.classes[rt]
        for m in sorted(methods):
            if m.startswith("on_") and m not in routed:
                findings.append(Finding(
                    rt_file, index.classes[rt][3], RULE_ID,
                    f"{rt}.{m} looks like an event handler but no "
                    f"{cfg['dispatch_table']} entry routes to it"))
    return findings
