"""R9 — Pallas kernel consistency.

A ``pl.pallas_call`` wires together five things that must agree but
that Pallas only validates at trace time (and, for some mismatches,
only on real TPU hardware — interpret mode happily runs index maps of
the wrong arity): the grid, the Block Specs, the kernel signature, the
out_shape, and the operand list. This rule statically cross-checks
every ``pl.pallas_call`` in scope:

- **index-map arity**: each BlockSpec index map must take exactly
  ``grid rank + num_scalar_prefetch`` arguments (scalar-prefetch refs
  arrive as trailing index-map args; loop-closure constants bound as
  trailing defaults, ``lambda ..., g=g:``, are excluded);
- **index-map result**: the returned tuple must have one coordinate
  per block-shape dimension;
- **out_specs vs out_shape**: one spec per ShapeDtypeStruct, with
  matching rank;
- **operand count**: outer-call operands must equal
  ``num_scalar_prefetch + len(in_specs)``;
- **kernel arity**: the kernel's positional parameters must equal
  prefetch refs + input refs + output refs + scratch refs
  (``functools.partial``-bound keywords are compile-time constants and
  don't count);
- **interpret guard**: every ``pallas_call`` must pass ``interpret=``
  explicitly (the repo routes it through ``ops._auto_interpret`` so
  kernels run everywhere; a call without it is TPU-only by accident).

``pl.BlockSpec(memory_space=...)`` (whole-operand SMEM/ANY blocks)
counts as an operand but has no block shape or index map to check.
Pieces the resolver cannot see through (computed spec lists, starred
operands) are skipped, not guessed at.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from ..core import Finding, SourceFile
from . import jitutil

RULE_ID = "R9"


def _sds_rank(expr: ast.AST) -> Optional[int]:
    """Rank of a jax.ShapeDtypeStruct((...), dtype) literal."""
    if not isinstance(expr, ast.Call):
        return None
    d = jitutil.dotted(expr.func)
    if d is None or d.split(".")[-1] != "ShapeDtypeStruct":
        return None
    shape = expr.args[0] if expr.args else None
    for kw in expr.keywords:
        if kw.arg == "shape":
            shape = kw.value
    if isinstance(shape, (ast.Tuple, ast.List)):
        return len(shape.elts)
    return None


def _map_result_len(imap: ast.Lambda) -> Optional[int]:
    if isinstance(imap.body, ast.Tuple):
        return len(imap.body.elts)
    return None


def check(files: List[SourceFile], config: dict) -> List[Finding]:
    cfg = config.get("r9", {})
    scope = cfg.get("scope", ["kernels/"])
    findings: List[Finding] = []
    for sf in files:
        if scope and not any(s in sf.relpath for s in scope):
            continue
        for pc in jitutil.iter_pallas_calls(sf.tree):
            line = pc.node.lineno

            def flag(ln: int, msg: str) -> None:
                findings.append(Finding(sf.relpath, ln, RULE_ID, msg))

            if not pc.has_interpret:
                flag(line,
                     "pallas_call without an explicit `interpret=` — "
                     "route it through ops._auto_interpret so the kernel "
                     "runs off-TPU (interpret mode) and fails loudly when "
                     "lowering is unavailable")

            expected_arity = None
            if pc.grid_rank is not None:
                expected_arity = pc.grid_rank + pc.num_prefetch

            labeled = []
            for i, spec in enumerate(pc.in_specs or []):
                labeled.append((f"in_specs[{i}]", spec, None))
            out_ranks = [(_sds_rank(s), s) for s in (pc.out_shapes or [])]
            for i, spec in enumerate(pc.out_specs or []):
                rank = out_ranks[i][0] if i < len(out_ranks) else None
                labeled.append((f"out_specs[{i}]", spec, rank))

            for label, spec, sds_rank in labeled:
                shape, imap, is_bs = jitutil.blockspec_parts(spec)
                if not is_bs:
                    continue
                if imap is not None and expected_arity is not None:
                    arity = jitutil.nondefault_lambda_arity(imap)
                    if arity != expected_arity:
                        flag(imap.lineno,
                             f"{label} index map takes {arity} args but "
                             f"the grid has rank {pc.grid_rank}"
                             + (f" + {pc.num_prefetch} scalar-prefetch "
                                f"refs" if pc.num_prefetch else "")
                             + f" — expected {expected_arity}")
                if imap is not None and shape is not None:
                    n = _map_result_len(imap)
                    if n is not None and n != len(shape.elts):
                        flag(imap.lineno,
                             f"{label} index map returns {n} coordinates "
                             f"for a rank-{len(shape.elts)} block shape")
                if shape is not None and sds_rank is not None \
                        and len(shape.elts) != sds_rank:
                    flag(spec.lineno,
                         f"{label} block shape is rank {len(shape.elts)} "
                         f"but the matching out_shape entry is rank "
                         f"{sds_rank}")

            if pc.out_specs is not None and pc.out_shapes is not None \
                    and len(pc.out_specs) != len(pc.out_shapes):
                flag(line,
                     f"{len(pc.out_specs)} out_specs for "
                     f"{len(pc.out_shapes)} out_shape entries")

            if pc.outer is not None and pc.in_specs is not None \
                    and not any(isinstance(a, ast.Starred)
                                for a in pc.outer.args) \
                    and not pc.outer.keywords:
                n_ops = len(pc.outer.args)
                want = pc.num_prefetch + len(pc.in_specs)
                if n_ops != want:
                    flag(pc.outer.lineno,
                         f"pallas_call receives {n_ops} operands but "
                         f"declares {len(pc.in_specs)} in_specs"
                         + (f" + {pc.num_prefetch} scalar-prefetch"
                            if pc.num_prefetch else "")
                         + f" — expected {want}")

            if pc.kernel is not None and pc.in_specs is not None \
                    and pc.out_specs is not None and pc.n_scratch is not None:
                n_kernel = len(jitutil.positional_params(pc.kernel)) \
                    - pc.kernel_bound_pos
                want = pc.num_prefetch + len(pc.in_specs) \
                    + len(pc.out_specs) + pc.n_scratch
                if n_kernel != want:
                    flag(pc.kernel.lineno,
                         f"kernel `{pc.kernel.name}` takes {n_kernel} "
                         f"positional refs but the call wires "
                         f"{pc.num_prefetch} prefetch + "
                         f"{len(pc.in_specs)} inputs + "
                         f"{len(pc.out_specs)} outputs + "
                         f"{pc.n_scratch} scratch = {want}")
    return findings
