"""Shared AST plumbing for the compute-layer rules (R7/R8/R9).

The compute layer spells jit three ways —

    @jax.jit / @functools.partial(jax.jit, static_argnames=(...)) def f(...)
    self._decode = jax.jit(lambda p, t, c, pos: ...)
    self._prefill = jax.jit(_local_def)

— and Pallas kernels one way: a function (possibly wrapped in a local
``functools.partial``) passed as the first operand of ``pl.pallas_call``.
This module finds all of them and resolves the local-name indirections
the kernels actually use (``kernel = functools.partial(_kernel, ...)``,
``grid = (b, h, n)``, ``grid_spec = pltpu.PrefetchScalarGridSpec(...)``).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Set, Union

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]


# ---------------------------------------------------------------------------
# name helpers
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """'jax.jit' for Attribute chains, 'jit' for Names, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def is_jax_jit(node: ast.AST) -> bool:
    d = dotted(node)
    return d in ("jit", "jax.jit")


def is_partial(node: ast.AST) -> bool:
    d = dotted(node)
    return d in ("partial", "functools.partial")


def is_pallas_call(node: ast.AST) -> bool:
    d = dotted(node)
    return d is not None and d.split(".")[-1] == "pallas_call"


def _static_from_kwargs(keywords: List[ast.keyword]) -> Set[str]:
    """Parse static_argnames=('a', 'b') / 'a' from a jit call/decorator."""
    out: Set[str] = set()
    for kw in keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            out.add(v.value)
        elif isinstance(v, (ast.Tuple, ast.List)):
            for el in v.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    out.add(el.value)
    return out


def positional_params(fn: FuncNode) -> List[str]:
    a = fn.args
    return [p.arg for p in list(a.posonlyargs) + list(a.args)]


def kwonly_params(fn: FuncNode) -> List[str]:
    return [p.arg for p in fn.args.kwonlyargs]


def param_defaults(fn: FuncNode) -> Dict[str, ast.AST]:
    """positional-param name -> default expression (only those that have one)."""
    a = fn.args
    pos = list(a.posonlyargs) + list(a.args)
    out: Dict[str, ast.AST] = {}
    for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults,
                    strict=True):
        out[p.arg] = d
    for p, d in zip(a.kwonlyargs, a.kw_defaults, strict=True):
        if d is not None:
            out[p.arg] = d
    return out


def nondefault_lambda_arity(fn: ast.Lambda) -> int:
    """Lambda params that are *not* defaulted — the repo binds loop-closure
    constants as trailing defaults (``lambda b_, h, ki, g=g: ...``)."""
    a = fn.args
    n_pos = len(a.posonlyargs) + len(a.args)
    return n_pos - len(a.defaults)


# ---------------------------------------------------------------------------
# local-assignment resolution
# ---------------------------------------------------------------------------

def local_assignments(scope: ast.AST) -> Dict[str, ast.AST]:
    """name -> last assigned expression, for simple ``name = expr``
    statements in the (non-nested) statement list of a function/module."""
    out: Dict[str, ast.AST] = {}
    body = getattr(scope, "body", [])
    stack = list(body)
    while stack:
        stmt = stack.pop(0)
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            out[stmt.targets[0].id] = stmt.value
        elif isinstance(stmt, (ast.If, ast.For, ast.While, ast.With)):
            stack.extend(stmt.body)
            stack.extend(getattr(stmt, "orelse", []))
    return out


def resolve(expr: ast.AST, *scopes: ast.AST) -> ast.AST:
    """Follow Name -> local assignment through the given scopes (innermost
    first), a bounded number of hops."""
    for _ in range(4):
        if not isinstance(expr, ast.Name):
            return expr
        for scope in scopes:
            assigns = local_assignments(scope)
            if expr.id in assigns:
                expr = assigns[expr.id]
                break
        else:
            return expr
    return expr


def find_def(name: str, *scopes: ast.AST) -> Optional[ast.FunctionDef]:
    """Find ``def name`` in the direct bodies of the given scopes."""
    for scope in scopes:
        for stmt in getattr(scope, "body", []):
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and stmt.name == name:
                return stmt
    return None


# ---------------------------------------------------------------------------
# jitted-function discovery
# ---------------------------------------------------------------------------

@dataclass
class JittedFn:
    fn: FuncNode                  # FunctionDef or Lambda
    statics: Set[str]             # static_argnames
    line: int
    via: str                      # 'decorator' | 'call'


def _jit_decorator_statics(dec: ast.AST) -> Optional[Set[str]]:
    """None if `dec` is not a jit decorator, else its static names."""
    if is_jax_jit(dec):
        return set()
    if isinstance(dec, ast.Call):
        if is_jax_jit(dec.func):
            return _static_from_kwargs(dec.keywords)
        if is_partial(dec.func) and dec.args and is_jax_jit(dec.args[0]):
            return _static_from_kwargs(dec.keywords)
    return None


def iter_jitted(tree: ast.Module) -> Iterator[JittedFn]:
    """Every function the file jits at its definition or wrap site."""
    seen: Set[int] = set()
    # decorated defs
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                statics = _jit_decorator_statics(dec)
                if statics is not None and id(node) not in seen:
                    seen.add(id(node))
                    yield JittedFn(node, statics, node.lineno, "decorator")
    # jax.jit(<lambda>) / jax.jit(<local name>) call sites; track the
    # enclosing function so local defs resolve
    parents: List[ast.AST] = [tree]

    def walk(node: ast.AST, scopes: List[ast.AST]):
        if isinstance(node, ast.Call) and is_jax_jit(node.func) and node.args:
            target = node.args[0]
            statics = _static_from_kwargs(node.keywords)
            if isinstance(target, ast.Lambda):
                yield JittedFn(target, statics, node.lineno, "call")
            elif isinstance(target, ast.Name):
                fd = find_def(target.id, *scopes)
                if fd is not None and id(fd) not in seen:
                    seen.add(id(fd))
                    yield JittedFn(fd, statics, node.lineno, "call")
        inner = scopes
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            inner = [node] + scopes
        for child in ast.iter_child_nodes(node):
            yield from walk(child, inner)

    yield from walk(tree, parents)


# ---------------------------------------------------------------------------
# pallas_call discovery
# ---------------------------------------------------------------------------

@dataclass
class PallasCall:
    node: ast.Call                        # the pl.pallas_call(...) call
    outer: Optional[ast.Call]             # pl.pallas_call(...)(operands...)
    kernel: Optional[ast.FunctionDef]     # resolved kernel def
    kernel_bound_pos: int                 # positional args pre-bound by partial
    grid_rank: Optional[int]
    num_prefetch: int
    in_specs: Optional[List[ast.AST]]     # BlockSpec exprs
    out_specs: Optional[List[ast.AST]]
    out_shapes: Optional[List[ast.AST]]   # ShapeDtypeStruct exprs
    n_scratch: Optional[int]
    has_interpret: bool = False
    kwargs: Dict[str, ast.AST] = field(default_factory=dict)


def _tuple_len(expr: ast.AST) -> Optional[int]:
    if isinstance(expr, (ast.Tuple, ast.List)):
        return len(expr.elts)
    return None


def _as_list(expr: Optional[ast.AST], *scopes) -> Optional[List[ast.AST]]:
    if expr is None:
        return None
    expr = resolve(expr, *scopes)
    if isinstance(expr, (ast.Tuple, ast.List)):
        return list(expr.elts)
    return [expr]


def _scratch_len(expr: Optional[ast.AST], *scopes) -> Optional[int]:
    if expr is None:
        return 0
    expr = resolve(expr, *scopes)
    n = _tuple_len(expr)
    if n is not None:
        return n
    # helper-call idiom: scratch_shapes=_scratch(...) returning a literal list
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name):
        fd = find_def(expr.func.id, *scopes)
        if fd is not None:
            for stmt in ast.walk(fd):
                if isinstance(stmt, ast.Return) and stmt.value is not None:
                    return _tuple_len(stmt.value)
    return None


def _resolve_kernel(expr: ast.AST, *scopes):
    """(FunctionDef | None, n positional args bound by functools.partial)."""
    expr = resolve(expr, *scopes)
    bound = 0
    if isinstance(expr, ast.Call) and is_partial(expr.func) and expr.args:
        bound = len(expr.args) - 1
        expr = resolve(expr.args[0], *scopes)
    if isinstance(expr, ast.Name):
        fd = find_def(expr.id, *scopes)
        return fd, bound
    if isinstance(expr, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return expr, bound
    return None, bound


def iter_pallas_calls(tree: ast.Module) -> Iterator[PallasCall]:
    # map each pallas_call node to its immediately-outer operand call
    outer_of: Dict[int, ast.Call] = {}
    enclosing: Dict[int, List[ast.AST]] = {}

    def walk(node: ast.AST, scopes: List[ast.AST]):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Call) and is_pallas_call(
                    node.func.func):
                outer_of[id(node.func)] = node
            if is_pallas_call(node.func):
                enclosing[id(node)] = list(scopes)
        inner = scopes
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            inner = [node] + scopes
        for child in ast.iter_child_nodes(node):
            walk(child, inner)

    walk(tree, [tree])

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and is_pallas_call(node.func)):
            continue
        scopes = enclosing.get(id(node), [tree])
        kwargs = {kw.arg: kw.value for kw in node.keywords
                  if kw.arg is not None}
        kernel, bound = (None, 0)
        if node.args:
            kernel, bound = _resolve_kernel(node.args[0], *scopes)
        elif "kernel" in kwargs:
            kernel, bound = _resolve_kernel(kwargs["kernel"], *scopes)

        grid_expr = kwargs.get("grid")
        in_specs_expr = kwargs.get("in_specs")
        out_specs_expr = kwargs.get("out_specs")
        scratch_expr = kwargs.get("scratch_shapes")
        num_prefetch = 0
        gs = kwargs.get("grid_spec")
        if gs is not None:
            gs = resolve(gs, *scopes)
            if isinstance(gs, ast.Call):
                gskw = {kw.arg: kw.value for kw in gs.keywords
                        if kw.arg is not None}
                grid_expr = gskw.get("grid", grid_expr)
                in_specs_expr = gskw.get("in_specs", in_specs_expr)
                out_specs_expr = gskw.get("out_specs", out_specs_expr)
                scratch_expr = gskw.get("scratch_shapes", scratch_expr)
                np_expr = gskw.get("num_scalar_prefetch")
                if isinstance(np_expr, ast.Constant) \
                        and isinstance(np_expr.value, int):
                    num_prefetch = np_expr.value

        grid_rank = None
        if grid_expr is not None:
            grid_rank = _tuple_len(resolve(grid_expr, *scopes))

        out_shape_expr = kwargs.get("out_shape")
        out_shapes = None
        if out_shape_expr is not None:
            resolved = resolve(out_shape_expr, *scopes)
            out_shapes = list(resolved.elts) \
                if isinstance(resolved, (ast.Tuple, ast.List)) else [resolved]

        yield PallasCall(
            node=node,
            outer=outer_of.get(id(node)),
            kernel=kernel,
            kernel_bound_pos=bound,
            grid_rank=grid_rank,
            num_prefetch=num_prefetch,
            in_specs=_as_list(in_specs_expr, *scopes),
            out_specs=_as_list(out_specs_expr, *scopes),
            out_shapes=out_shapes,
            n_scratch=_scratch_len(scratch_expr, *scopes),
            has_interpret="interpret" in kwargs,
            kwargs=kwargs,
        )


def blockspec_parts(spec: ast.AST):
    """(block_shape_tuple | None, index_map_lambda | None, is_blockspec).

    ``pl.BlockSpec(memory_space=...)`` yields (None, None, True) — a full
    operand in one (SMEM/ANY) block, nothing to check.
    """
    if not (isinstance(spec, ast.Call) and dotted(spec.func) is not None
            and dotted(spec.func).split(".")[-1] == "BlockSpec"):
        return None, None, False
    shape = spec.args[0] if spec.args else None
    imap = spec.args[1] if len(spec.args) > 1 else None
    for kw in spec.keywords:
        if kw.arg == "block_shape":
            shape = kw.value
        elif kw.arg == "index_map":
            imap = kw.value
    if not isinstance(shape, (ast.Tuple, ast.List)):
        shape = None
    if not isinstance(imap, ast.Lambda):
        imap = None
    return shape, imap, True
