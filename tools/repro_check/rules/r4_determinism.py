"""R4 — determinism discipline.

Simulation results must be a pure function of (scenario, seed). Inside
the configured scope, flag:

- wall-clock reads (``time.time()`` & friends),
- the process-global RNGs (``np.random.<legacy>``, stdlib
  ``random.*``) — per-stream seeded generators
  (``np.random.default_rng(seed)``) are fine,
- *unseeded* ``Generator`` construction — ``np.random.default_rng()``
  or a bit generator (``PCG64()``/``Philox()``/...) called with no
  arguments pulls OS entropy, so results stop being a function of
  the seed,
- iteration over unordered sets (literal ``{...}``, ``set(...)`` calls,
  set comprehensions) whose order would leak hash randomization into
  event order.

Live-serving wall-clock users (``serving/engine.py``) are exempt via
config.
"""
from __future__ import annotations

import ast
from typing import List

from ..core import Finding, SourceFile

RULE_ID = "R4"


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    return False


def check(files: List[SourceFile], config: dict) -> List[Finding]:
    cfg = config["r4"]
    findings: List[Finding] = []
    wallclock = set(cfg["wallclock"])
    np_ok = set(cfg["np_random_allowed"])
    seeded_ctors = set(cfg.get("seeded_ctors",
                               ["default_rng", "PCG64", "Philox"]))
    for sf in files:
        if not any(s in sf.relpath for s in cfg["scope"]):
            continue
        if sf.matches(cfg["exempt_files"]):
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                f = node.func
                # time.time() / time.monotonic() / ...
                if isinstance(f, ast.Attribute) and \
                        isinstance(f.value, ast.Name) and \
                        f.value.id == "time" and f.attr in wallclock:
                    findings.append(Finding(
                        sf.relpath, node.lineno, RULE_ID,
                        f"wall-clock read time.{f.attr}() in "
                        f"deterministic scope — derive times from the "
                        f"event clock"))
                # unseeded Generator construction: default_rng() or a
                # bit generator with no arguments draws OS entropy
                ctor = None
                if isinstance(f, ast.Attribute) and f.attr in seeded_ctors:
                    ctor = f.attr
                elif isinstance(f, ast.Name) and f.id in seeded_ctors:
                    ctor = f.id
                if ctor is not None and not node.args \
                        and not node.keywords:
                    findings.append(Finding(
                        sf.relpath, node.lineno, RULE_ID,
                        f"unseeded {ctor}() in deterministic scope — "
                        f"pass an explicit seed so the Generator stream "
                        f"is reproducible"))
                # stdlib random.X(...)
                if isinstance(f, ast.Attribute) and \
                        isinstance(f.value, ast.Name) and \
                        f.value.id == "random":
                    findings.append(Finding(
                        sf.relpath, node.lineno, RULE_ID,
                        f"process-global random.{f.attr}() in "
                        f"deterministic scope — use a seeded "
                        f"np.random.default_rng substream"))
            # np.random.X for X outside the seeded-constructor allowlist
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.value, ast.Attribute) and \
                    node.value.attr == "random" and \
                    isinstance(node.value.value, ast.Name) and \
                    node.value.value.id in ("np", "numpy") and \
                    node.attr not in np_ok:
                findings.append(Finding(
                    sf.relpath, node.lineno, RULE_ID,
                    f"global-state np.random.{node.attr} in "
                    f"deterministic scope — use np.random.default_rng "
                    f"with an explicit seed"))
            # iteration over unordered sets
            if isinstance(node, (ast.For, ast.AsyncFor)) and \
                    _is_set_expr(node.iter):
                findings.append(Finding(
                    sf.relpath, node.lineno, RULE_ID,
                    "iteration over an unordered set in deterministic "
                    "scope — sort it or use an ordered container"))
            if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                 ast.GeneratorExp)):
                for gen in node.generators:
                    if _is_set_expr(gen.iter):
                        findings.append(Finding(
                            sf.relpath, node.lineno, RULE_ID,
                            "comprehension over an unordered set in "
                            "deterministic scope — sort it or use an "
                            "ordered container"))
    return findings
