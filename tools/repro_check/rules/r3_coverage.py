"""R3 — decision / result / view field coverage.

Name-level whole-program checks:

1. Every ``Decision``/``Allocation`` field must be *read* (attribute
   access) in every configured reader group (event simulator and live
   server), or carry a config guard explaining why one side may ignore
   it. A field silently ignored by one runtime means the two physics
   implementations diverge on the scheduling contract.
2. Every ``SimResult`` counter must be written by at least one site
   (keyword in a SimResult(...) construction, or attribute store).
3. Every ``ClusterView`` field must be populated by both view builders
   (keyword in a ClusterView(...) call, or a key of the dict returned
   by a configured ``**kwargs`` helper like ``link_view_kwargs``).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from ..core import Finding, SourceFile

RULE_ID = "R3"


def _dataclass_fields(sf: SourceFile, cls_name: str):
    """[(field, line)] of annotated assignments in the class body."""
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            out = []
            for st in node.body:
                if isinstance(st, ast.AnnAssign) and \
                        isinstance(st.target, ast.Name):
                    out.append((st.target.id, st.lineno))
            return node.lineno, out
    return None, []


def _attr_reads(files: List[SourceFile], suffixes: List[str]) -> Set[str]:
    """All attribute names *loaded* anywhere in the given files."""
    out: Set[str] = set()
    for sf in files:
        if not sf.matches(suffixes):
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.ctx, ast.Load):
                out.add(node.attr)
    return out


def _find(files: List[SourceFile], suffix: str) -> Optional[SourceFile]:
    return next((sf for sf in files if sf.relpath.endswith(suffix)), None)


def _call_keywords(files: List[SourceFile], suffixes: List[str],
                   callee: str) -> Set[str]:
    out: Set[str] = set()
    for sf in files:
        if not sf.matches(suffixes):
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                f = node.func
                name = f.attr if isinstance(f, ast.Attribute) else \
                    (f.id if isinstance(f, ast.Name) else None)
                if name == callee:
                    out.update(kw.arg for kw in node.keywords if kw.arg)
    return out


def _builder_keywords(files: List[SourceFile], suffixes: List[str],
                      callee: str) -> Set[str]:
    """Fields populated by builder functions: direct keywords of the
    ``callee(...)`` call plus keys of any dict literal / ``dict(...)``
    inside the same function (the ``**local_kwargs`` splat idiom)."""
    out = _call_keywords(files, suffixes, callee)
    for sf in files:
        if not sf.matches(suffixes):
            continue
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            calls_builder = any(
                isinstance(n, ast.Call) and (
                    (isinstance(n.func, ast.Name) and n.func.id == callee)
                    or (isinstance(n.func, ast.Attribute)
                        and n.func.attr == callee))
                for n in ast.walk(fn))
            if not calls_builder:
                continue
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Dict):
                    out.update(k.value for k in sub.keys
                               if isinstance(k, ast.Constant)
                               and isinstance(k.value, str))
                if isinstance(sub, ast.Call) and \
                        isinstance(sub.func, ast.Name) and \
                        sub.func.id == "dict":
                    out.update(kw.arg for kw in sub.keywords if kw.arg)
    return out


def _helper_dict_keys(sf: SourceFile, func_name: str) -> Set[str]:
    """String keys of dict literals / dict(...) calls inside a helper
    whose return value is splatted into a view constructor."""
    out: Set[str] = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name == func_name:
            for sub in ast.walk(node):
                if isinstance(sub, ast.Dict):
                    for k in sub.keys:
                        if isinstance(k, ast.Constant) and \
                                isinstance(k.value, str):
                            out.add(k.value)
                if isinstance(sub, ast.Call):
                    f = sub.func
                    if isinstance(f, ast.Name) and f.id == "dict":
                        out.update(kw.arg for kw in sub.keywords if kw.arg)
    return out


def check(files: List[SourceFile], config: dict) -> List[Finding]:
    cfg = config["r3"]
    findings: List[Finding] = []
    api = _find(files, cfg["api_file"])

    # (1) Decision/Allocation fields read by every reader group
    guards = cfg["decision_guards"]
    group_reads: Dict[str, Set[str]] = {
        g: _attr_reads(files, suffixes)
        for g, suffixes in cfg["reader_groups"].items()}
    for cls in cfg["decision_classes"] if api is not None else []:
        _cline, fields = _dataclass_fields(api, cls)
        for fname, line in fields:
            if fname in guards:
                continue
            missing = [g for g, reads in group_reads.items()
                       if fname not in reads]
            if missing:
                findings.append(Finding(
                    api.relpath, line, RULE_ID,
                    f"{cls}.{fname} is never read by "
                    f"{'/'.join(sorted(missing))} — honor it there or add "
                    f"a decision_guards entry explaining the asymmetry"))

    # (2) SimResult counters all written somewhere
    res_file = _find(files, cfg["result_file"])
    if res_file is not None:
        _cline, fields = _dataclass_fields(res_file, cfg["result_class"])
        written = _call_keywords([res_file], [cfg["result_file"]],
                                 cfg["result_class"])
        stored = {node.attr for node in ast.walk(res_file.tree)
                  if isinstance(node, ast.Attribute)
                  and isinstance(node.ctx, ast.Store)}
        for fname, line in fields:
            if fname not in written and fname not in stored:
                findings.append(Finding(
                    res_file.relpath, line, RULE_ID,
                    f"{cfg['result_class']}.{fname} is declared but no "
                    f"site ever writes it — dead counter or missing "
                    f"bookkeeping"))

    # (3) ClusterView fields populated by both builders
    if api is None:
        return findings
    _cline, view_fields = _dataclass_fields(api, cfg["view_class"])
    helper_keys: Set[str] = set()
    for hfile, funcs in cfg["view_helpers"].items():
        sf = _find(files, hfile)
        if sf is not None:
            for fn in funcs:
                helper_keys |= _helper_dict_keys(sf, fn)
    vguards = cfg["view_guards"]
    for group, suffixes in cfg["view_builders"].items():
        populated = _builder_keywords(files, suffixes, cfg["view_class"]) \
            | helper_keys
        if not populated:
            continue        # group's builder file absent (fixture tree)
        for fname, line in view_fields:
            if fname in vguards or fname in populated:
                continue
            findings.append(Finding(
                api.relpath, line, RULE_ID,
                f"{cfg['view_class']}.{fname} is not populated by the "
                f"{group} view builder — pass it or add a view_guards "
                f"entry explaining the asymmetry"))
    return findings
