"""Shared infrastructure for repro-check: findings, source loading,
inline suppressions.

Suppression syntax (see docs/invariants.md):

``# repro-check: disable=R1,R5``
    On any line: suppress those rules' findings anchored to that line.
    ``disable=all`` suppresses every rule on the line.

``# repro-check: orphan(<counter>)``
    R1-specific: declares that the enclosing exit path intentionally
    leaves ``<counter>`` (``kv_used``, ``refcount``, ``prefix_pin``)
    claimed or dropped — e.g. an ownership handoff the analyzer cannot
    see. Applies to the function whose body span contains the comment.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Set

DISABLE_RE = re.compile(r"#\s*repro-check:\s*disable=([A-Za-z0-9_,\s]+)")
ORPHAN_RE = re.compile(r"#\s*repro-check:\s*orphan\(\s*([A-Za-z0-9_,\s]+?)\s*\)")


@dataclass(frozen=True)
class Finding:
    file: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.file}:{self.line} {self.rule} {self.message}"


@dataclass
class SourceFile:
    """A parsed source file plus its repro-check comment pragmas."""

    path: Path
    relpath: str            # normalized posix path used in findings/config
    text: str
    tree: ast.Module
    disables: Dict[int, Set[str]] = field(default_factory=dict)
    orphans: Dict[int, Set[str]] = field(default_factory=dict)

    @classmethod
    def load(cls, path: Path, root: Path) -> "SourceFile":
        text = path.read_text()
        tree = ast.parse(text, filename=str(path))
        try:
            relpath = path.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            relpath = path.as_posix()
        sf = cls(path=path, relpath=relpath, text=text, tree=tree)
        sf._scan_comments()
        return sf

    def _scan_comments(self) -> None:
        reader = io.StringIO(self.text).readline
        try:
            tokens = list(tokenize.generate_tokens(reader))
        except tokenize.TokenError:
            return
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            line = tok.start[0]
            m = DISABLE_RE.search(tok.string)
            if m:
                rules = {r.strip().upper() for r in m.group(1).split(",")
                         if r.strip()}
                self.disables.setdefault(line, set()).update(rules)
            m = ORPHAN_RE.search(tok.string)
            if m:
                counters = {c.strip() for c in m.group(1).split(",")
                            if c.strip()}
                self.orphans.setdefault(line, set()).update(counters)

    def suppressed(self, line: int, rule: str) -> bool:
        rules = self.disables.get(line, ())
        return rule.upper() in rules or "ALL" in rules

    def orphan_counters(self, lo: int, hi: int) -> Set[str]:
        """Union of orphan(...) annotations on lines lo..hi inclusive."""
        out: Set[str] = set()
        for line, counters in self.orphans.items():
            if lo <= line <= hi:
                out |= counters
        return out

    def matches(self, suffixes: Iterable[str]) -> bool:
        return any(self.relpath.endswith(s) for s in suffixes)


def collect_py_files(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    # dedupe, stable order
    seen: Set[Path] = set()
    uniq = []
    for f in out:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            uniq.append(f)
    return uniq


def load_sources(paths: Iterable[str], root: Path = None) -> List[SourceFile]:
    root = root or Path.cwd()
    return [SourceFile.load(f, root) for f in collect_py_files(paths)]


def end_line(node: ast.AST) -> int:
    return getattr(node, "end_lineno", getattr(node, "lineno", 0))
