"""CLI entry point: ``python -m tools.repro_check src/``."""
from __future__ import annotations

import argparse
import sys

from . import ALL_RULES, run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_check",
        description="AST-based invariant checker (ledgers, events, "
                    "field coverage, determinism, units).")
    ap.add_argument("paths", nargs="*", help="files or directories")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset, e.g. R1,R4 "
                         "(default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rid in sorted(ALL_RULES):
            doc = (ALL_RULES[rid].__doc__ or "").strip().splitlines()[0]
            print(f"{rid}  {doc}")
        return 0
    if not args.paths:
        ap.error("the following arguments are required: paths")
    ids = [r.strip() for r in args.rules.split(",")] if args.rules else None
    findings = run_paths(args.paths, rule_ids=ids)
    for f in findings:
        print(f.render())
    if findings:
        print(f"\n{len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("repro-check: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
