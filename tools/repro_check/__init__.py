"""repro-check: AST-based invariant checker for this repo.

Usage::

    python -m tools.repro_check src/

Rules (see docs/invariants.md):

  R1  ledger conservation (kv_used / refcounts / prefix pins / links)
  R2  event-handler exhaustiveness across concrete runtimes
  R3  Decision/SimResult/ClusterView field coverage
  R4  determinism discipline (no wall clock / global RNG / set order)
  R5  unit-suffix arithmetic (no seconds + tokens)
  R6  trace-emission coverage (every handled event leaves a trace row)
  R7  jit tracing-safety (no Python control flow / host sync on tracers)
  R8  recompilation hazards (per-request shapes reaching jitted callees)
  R9  Pallas kernel consistency (grid / BlockSpec / kernel-arity wiring)
"""
from __future__ import annotations

from pathlib import Path
from typing import Iterable, List, Optional

from .config import default_config
from .core import Finding, load_sources
from .rules import ALL_RULES

__all__ = ["run_paths", "Finding", "ALL_RULES", "default_config"]


def run_paths(paths: Iterable[str], rule_ids: Optional[Iterable[str]] = None,
              config: Optional[dict] = None,
              root: Optional[Path] = None) -> List[Finding]:
    """Run the selected rules over the given files/dirs; return findings
    that survive inline suppression, sorted by (file, line, rule)."""
    config = config or default_config()
    files = load_sources(paths, root=root)
    by_path = {sf.relpath: sf for sf in files}
    ids = [r.upper() for r in rule_ids] if rule_ids else sorted(ALL_RULES)
    findings: List[Finding] = []
    for rid in ids:
        rule = ALL_RULES.get(rid)
        if rule is None:
            raise SystemExit(f"unknown rule {rid!r} "
                             f"(known: {', '.join(sorted(ALL_RULES))})")
        findings.extend(rule.check(files, config))
    kept = [f for f in findings
            if not (f.file in by_path
                    and by_path[f.file].suppressed(f.line, f.rule))]
    return sorted(set(kept), key=lambda f: (f.file, f.line, f.rule,
                                            f.message))
