"""Tests for the explicit per-metric benchmark gate
(benchmarks/compare_baseline.py)."""
import json
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT))

from benchmarks.compare_baseline import (  # noqa: E402
    compare, emit_baseline, main)


def base(metrics):
    return {"exp": {"metrics": metrics, "us_per_call": 1.0}}


def cur(metrics):
    return {"exp": {"metrics": metrics, "us_per_call": 2.0}}


def test_gated_metric_regression_fails():
    b = base({"success": {"value": 90.0, "gate": True}})
    fails = compare(cur({"success": 80.0}), b, tolerance=0.05)
    assert len(fails) == 1 and "floor" in fails[0]


def test_gated_metric_within_tolerance_passes():
    b = base({"success": {"value": 90.0, "gate": True}})
    assert compare(cur({"success": 86.0}), b, tolerance=0.05) == []


def test_ungated_metric_is_ignored_regardless_of_name():
    # the old name-pattern heuristic would have gated this ("success");
    # the explicit gate: false wins now
    b = base({"success": {"value": 90.0, "gate": False},
              "kept": {"value": 1.0, "gate": True}})
    assert compare(cur({"success": 1.0, "kept": 1.0}), b,
                   tolerance=0.05) == []


def test_lower_is_better_direction():
    b = base({"energy_per_token": {"value": 0.3, "gate": True,
                                   "direction": "lower"}})
    assert compare(cur({"energy_per_token": 0.31}), b,
                   tolerance=0.05) == []
    fails = compare(cur({"energy_per_token": 0.4}), b, tolerance=0.05)
    assert len(fails) == 1 and "ceiling" in fails[0]


def test_legacy_bare_number_entry_is_rejected():
    b = base({"success": 90.0})
    with pytest.raises(SystemExit, match="explicit gate schema"):
        compare(cur({"success": 90.0}), b, tolerance=0.05)


def test_missing_gated_metric_fails():
    b = base({"success": {"value": 90.0, "gate": True}})
    fails = compare(cur({}), b, tolerance=0.05)
    assert any("metric missing" in f for f in fails)


def test_emit_baseline_preserves_gates_and_defaults_new_to_false(capsys):
    b = base({"success": {"value": 90.0, "gate": True},
              "energy": {"value": 0.3, "gate": True,
                         "direction": "lower"}})
    merged = emit_baseline(
        cur({"success": 95.0, "energy": 0.28, "brand_new": 7.0}), b)
    m = merged["exp"]["metrics"]
    assert m["success"] == {"value": 95.0, "gate": True}
    assert m["energy"] == {"value": 0.28, "gate": True,
                           "direction": "lower"}
    assert m["brand_new"] == {"value": 7.0, "gate": False}
    assert merged["exp"]["us_per_call"] == 2.0
    assert "brand_new is new" in capsys.readouterr().err


def test_main_end_to_end(tmp_path):
    b = base({"success": {"value": 90.0, "gate": True}})
    c = cur({"success": 91.0})
    (tmp_path / "baseline.json").write_text(json.dumps(b))
    (tmp_path / "run.json").write_text(json.dumps(c))
    assert main([str(tmp_path / "run.json"),
                 str(tmp_path / "baseline.json")]) == 0
    bad = cur({"success": 10.0})
    (tmp_path / "bad.json").write_text(json.dumps(bad))
    assert main([str(tmp_path / "bad.json"),
                 str(tmp_path / "baseline.json")]) == 1
    # regeneration writes the merged schema
    out = tmp_path / "new_baseline.json"
    assert main([str(tmp_path / "run.json"),
                 str(tmp_path / "baseline.json"),
                 "--emit-baseline", str(out)]) == 0
    merged = json.loads(out.read_text())
    assert merged["exp"]["metrics"]["success"] == {"value": 91.0,
                                                   "gate": True}


def test_committed_baseline_is_explicit_schema():
    """Every metric in benchmarks/baseline.json must carry an explicit
    gate flag (the schema the CI gate enforces)."""
    baseline = json.loads(
        (REPO_ROOT / "benchmarks" / "baseline.json").read_text())
    assert baseline, "baseline.json is empty"
    n_gated = 0
    for exp, info in baseline.items():
        for key, entry in info["metrics"].items():
            assert isinstance(entry, dict) and "value" in entry \
                and "gate" in entry, f"{exp}.{key} not explicit-gate"
            n_gated += bool(entry["gate"])
    assert n_gated >= 10     # the quality gates must not silently vanish
