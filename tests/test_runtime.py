"""Event-driven runtime: golden array-vs-reference equivalence, event
ordering, scenario hooks, and the live server's realized outcome
semantics.

The golden test runs the same seeded benchmark workload through the
array-backed fast core (the default) and the scalar reference core
(`core="reference"`, a verbatim copy of the pre-vectorization event
runtime) and checks that the `SimResult`s agree bit-for-bit.
"""
import copy
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (
    BandwidthModel, Simulator, generate_workload, paper_testbed,
)
from repro.cluster.workload import classify
from repro.core import (
    Arrival, BandwidthChange, Decision, Deferred, EventLoop, InferDone,
    SchedulingPolicy, TxDone, available_scenarios,
    make_policy, make_scenario,
)
from repro.core.runtime import TraceScenario


# Seeded benchmark workload parameters (benchmarks/common.py at smoke scale)
_BENCH = dict(edge="llama2-7b", n=400, wl_seed=0, bw_seed=1, sim_seed=42)


@pytest.mark.parametrize("policy_name,fluctuating", [
    ("perllm", True), ("perllm", False), ("fineinfer", True),
])
def test_golden_array_core_bit_exact(policy_name, fluctuating):
    """Array-backed fast core == scalar reference core, bit-for-bit, on
    the seeded benchmark workload."""
    specs = paper_testbed(_BENCH["edge"])
    services = generate_workload(_BENCH["n"], seed=_BENCH["wl_seed"])

    sim_ref = Simulator(specs, BandwidthModel(fluctuating=fluctuating,
                                              seed=_BENCH["bw_seed"]),
                        seed=_BENCH["sim_seed"], core="reference")
    ref_services = [copy.copy(s) for s in services]
    ref = sim_ref.run(ref_services, make_policy(policy_name, len(specs)))

    sim_new = Simulator(specs, BandwidthModel(fluctuating=fluctuating,
                                              seed=_BENCH["bw_seed"]),
                        seed=_BENCH["sim_seed"])
    new_services = [copy.copy(s) for s in services]
    res = sim_new.run(new_services, make_policy(policy_name, len(specs)))

    assert res.success_rate == ref.success_rate
    assert res.avg_processing_time == ref.avg_processing_time
    assert res.p95_processing_time == ref.p95_processing_time
    assert res.makespan == ref.makespan
    assert res.e_tx == ref.e_tx
    assert res.e_infer == ref.e_infer
    assert res.e_idle == ref.e_idle
    assert res.per_server_served == ref.per_server_served
    assert [r.server for r in sorted(new_services, key=lambda r: r.sid)] \
        == [r.server for r in sorted(ref_services, key=lambda r: r.sid)]


def test_numeric_slot_rejected_with_clear_error():
    """The quantized-slot compat mode is retired: pinning a numeric
    `slot=` must fail loudly, pointing at the migration."""
    specs = paper_testbed(n_edge=1)
    with pytest.raises(ValueError, match="slotted mode was removed"):
        Simulator(specs, slot=0.5)
    # slot=None (the old way to request event mode) stays accepted
    assert Simulator(specs, slot=None).slot is None


# ---------------------------------------------------------------------------
# EventLoop ordering
# ---------------------------------------------------------------------------


def test_event_loop_time_order_and_kind_priority():
    loop = EventLoop()
    loop.push(Arrival(2.0, requests=("late",)))
    loop.push(InferDone(1.0, request="done"))
    loop.push(Arrival(1.0, requests=("tie",)))
    loop.push(TxDone(1.0, request="tx"))
    loop.push(BandwidthChange(1.0))
    popped = [loop.pop() for _ in range(len(loop))]
    # time first; at t=1.0 kind priority: bandwidth < done < tx < arrival
    assert isinstance(popped[0], BandwidthChange)
    assert isinstance(popped[1], InferDone)
    assert isinstance(popped[2], TxDone)
    assert isinstance(popped[3], Arrival) and popped[3].requests == ("tie",)
    assert isinstance(popped[4], Arrival) and popped[4].requests == ("late",)


def test_event_loop_fifo_within_kind():
    loop = EventLoop()
    for tag in ("a", "b", "c"):
        loop.push(Deferred(3.0, request=tag))
    assert [loop.pop().request for _ in range(3)] == ["a", "b", "c"]


class _PinTo0(SchedulingPolicy):
    """Deterministic single-server policy that records what it saw."""

    name = "pin0"

    def __init__(self):
        self.assign_log = []          # (sid, view.t)
        self.feedback_log = []        # (sid, Outcome)

    def assign(self, req, view):
        self.assign_log.append((req.sid, view.t))
        return Decision(server=0)

    def feedback(self, req, out):
        self.feedback_log.append((req.sid, out))


def _two_requests(t_first, t_second):
    a, b = [copy.copy(s) for s in generate_workload(2, seed=0)]
    a.arrival, b.arrival = float(t_first), float(t_second)
    a.payload_bytes = b.payload_bytes = 2e6
    return a, b


@given(st.floats(0.0, 5.0), st.floats(0.0, 5.0))
@settings(max_examples=25, deadline=None)
def test_event_ordering_fifo_uplink(t_first, t_second):
    """Out-of-order insertion cannot reorder the shared uplink: the loop
    pops arrivals by timestamp, so the earlier request transmits first."""
    specs = paper_testbed(n_edge=1)
    a, b = _two_requests(t_first, t_second)
    policy = _PinTo0()
    sim = Simulator(specs, slot=None, seed=0)
    # push order is b-then-a inside run() only if sorted — bypass run's
    # sort by seeding the loop directly, mimicking live out-of-order pushes
    from repro.cluster.simulator import _EventSimRuntime
    for r in (a, b):
        r.class_id = classify(r)
    rt = _EventSimRuntime(sim, policy)
    rt.loop.push(Arrival(b.arrival, requests=(b,)))   # inserted first
    rt.loop.push(Arrival(a.arrival, requests=(a,)))   # but may arrive earlier
    rt.drain()

    order = [sid for sid, _t in policy.assign_log]
    # exact ties resolve FIFO by insertion, i.e. b first
    expected = ([a.sid, b.sid] if a.arrival < b.arrival
                else [b.sid, a.sid])
    assert order == expected
    # FIFO uplink: the shared link serves transfers in pop order without
    # overlap — the second transfer completes a full tx after the first
    by_sid = {a.sid: a, b.sid: b}
    ready = {sid: by_sid[sid].arrival + out.tx_time
             for sid, out in policy.feedback_log}
    tx_dur = 2e6 * 8.0 / specs[0].bandwidth     # stable bandwidth, factor 1
    first, second = expected
    assert ready[first] <= ready[second] + 1e-9
    assert ready[second] >= max(by_sid[second].arrival, ready[first]) \
        + tx_dur - 1e-9


def test_event_mode_views_are_fresh_per_arrival():
    """Each arrival is scheduled against a view at its true timestamp
    (nothing quantizes arrivals to a grid)."""
    specs = paper_testbed()
    services = [copy.copy(s) for s in generate_workload(40, seed=2)]
    pin = _PinTo0()
    Simulator(specs, seed=1).run(services, pin)
    arrivals = {r.sid: r.arrival for r in services}
    assert all(t == arrivals[sid] for sid, t in pin.assign_log)


def test_event_mode_feedback_at_true_completion():
    """The learner hears about a request only when it actually finishes —
    a later arrival can be assigned first."""
    specs = paper_testbed(n_edge=1)
    a, b = _two_requests(0.1, 0.9)    # a finishes > 0.9, after b arrives
    a.prompt_tokens, a.output_tokens = 2048, 96
    policy = _PinTo0()
    Simulator(specs, seed=0).run([a, b], policy)
    assert [sid for sid, _ in policy.assign_log] == [a.sid, b.sid]
    # a's feedback arrived after b was assigned (interleaved timeline)
    assert policy.feedback_log[0][1].finish > 0.9


def test_deferral_applied_by_event_runtime():
    """Decision.defer_until becomes a Deferred event; dispatch (and hence
    transmission) cannot start before the window."""
    specs = paper_testbed()
    services = [copy.copy(s) for s in generate_workload(50, seed=1)]
    sim = Simulator(specs, slot=None, seed=1)
    res = sim.run(services, make_policy("fineinfer", len(specs),
                                        batch_window=1.0))
    assert res.n_services == 50
    for r in sorted(services, key=lambda r: r.sid):
        assert r.finish >= math.ceil(r.arrival / 1.0) * 1.0


# ---------------------------------------------------------------------------
# Scenario hooks
# ---------------------------------------------------------------------------


def test_scenario_registry():
    assert {"burst", "bwdrop", "diurnal", "poisson", "trace"} \
        <= set(available_scenarios())
    with pytest.raises(KeyError, match="unknown scenario"):
        make_scenario("not-a-scenario")
    sc = make_scenario("burst", burst_factor=6.0)
    assert sc.burst_factor == 6.0


def _dispersion(workload, window=1.0):
    t = np.array([r.arrival for r in workload])
    counts = np.bincount((t // window).astype(int))
    return counts.var() / counts.mean()


def test_burst_and_diurnal_arrivals_are_overdispersed():
    poisson = generate_workload(2000, rate=10.0, seed=7)
    burst = generate_workload(2000, rate=10.0, seed=7, scenario="burst")
    diurnal = generate_workload(2000, rate=10.0, seed=7, scenario="diurnal")
    assert _dispersion(poisson) < 1.5            # ≈1 for Poisson
    assert _dispersion(burst) > 3.0
    assert 1.5 < _dispersion(diurnal)
    # requirements draw identically: only arrival times differ
    assert [r.prompt_tokens for r in poisson] \
        == [r.prompt_tokens for r in burst]
    # burst preserves the long-run average rate for any burst_factor
    for bf in (4.0, 8.0):
        sc = make_scenario("burst", burst_factor=bf)
        t = sc.arrival_times(20000, 10.0, np.random.default_rng(0))
        assert 20000 / t[-1] == pytest.approx(10.0, rel=0.1)


def test_bandwidth_only_scenarios_keep_baseline_arrivals():
    """`poisson` and `bwdrop` (no arrival shaping) replay the exact
    no-scenario arrival stream, so their effects isolate per arrival."""
    base = generate_workload(300, rate=10.0, seed=7)
    for name in ("poisson", "bwdrop"):
        wl = generate_workload(300, rate=10.0, seed=7, scenario=name)
        assert [r.arrival for r in wl] == [r.arrival for r in base]


def test_trace_scenario_replays_and_cycles():
    times = [0.5, 1.25, 3.0]
    wl = generate_workload(3, rate=10.0, seed=0,
                           scenario=TraceScenario(times))
    assert [r.arrival for r in wl] == times
    wl = generate_workload(7, rate=10.0, seed=0,
                           scenario=TraceScenario(times))
    assert len(wl) == 7
    assert all(wl[i].arrival < wl[i + 1].arrival for i in range(6))


def test_bwdrop_scenario_degrades_the_dropped_link():
    """A mid-run cloud bandwidth drop injected as BandwidthChange events
    slows cloud-bound transfers in both runtime modes."""
    specs = paper_testbed()
    cloud = len(specs) - 1

    class PinCloud(SchedulingPolicy):
        name = "pin-cloud"

        def assign(self, req, view):
            return Decision(server=cloud)

    sc = make_scenario("bwdrop", scale=0.25, start_frac=0.0, stop_frac=1.0)
    events = sc.bandwidth_events(10.0, len(specs))
    assert [ev.scale for ev in events] == [{cloud: 0.25}, {cloud: 1.0}]

    for core in ("array", "reference"):
        services = [copy.copy(s) for s in generate_workload(150, seed=4)]
        base = Simulator(specs, seed=3, core=core).run(services, PinCloud())
        services = [copy.copy(s) for s in generate_workload(150, seed=4)]
        dropped = Simulator(specs, seed=3, core=core).run(
            services, PinCloud(), scenario=sc)
        assert dropped.avg_processing_time > base.avg_processing_time
        assert dropped.e_tx > base.e_tx


# ---------------------------------------------------------------------------
# Live server: realized outcome semantics on the shared loop
# ---------------------------------------------------------------------------


def _tiny_fleet():
    pytest.importorskip("jax")
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingEngine

    cfg = get_config("gemma-2b").reduced(n_layers=1, d_model=32,
                                         vocab_size=128)
    key = jax.random.key(0)
    specs = paper_testbed(n_edge=1)[:1] + [paper_testbed()[-1]]
    engines = [ServingEngine(cfg, init_params(key, cfg), max_batch=2,
                             max_seq=32) for _ in range(2)]
    return specs, engines


def test_server_outcome_has_real_tx_queue_split_and_realized_energy():
    from repro.serving.perllm_server import PerLLMServer

    specs, engines = _tiny_fleet()
    policy = _PinTo0()
    srv = PerLLMServer(specs, engines, scheduler=policy)   # stable bw
    for _ in range(3):
        srv.submit([1, 2, 3], max_new_tokens=4, payload_bytes=4e6)
    srv.run_until_idle()
    assert len(policy.feedback_log) == 3
    spec = specs[0]
    tx_dur = 4e6 * 8.0 / spec.bandwidth
    by_sid = {sr.service.sid: sr for sr in srv.completed}
    for sid, out in policy.feedback_log:
        sr = by_sid[sid]
        # transmission includes the serialized uplink wait, not 0.0
        assert out.tx_time == pytest.approx(sr.tx_time)
        assert out.tx_time >= tx_dur - 1e-9
        # real queue split: engine wait between TxDone and lane admission
        assert sr.admit_clock >= sr.dispatch_clock >= 0
        assert out.queue_time == pytest.approx(
            sr.admit_clock - sr.dispatch_clock)
        # inference is the realized window, and the split sums to latency
        assert out.infer_time == pytest.approx(
            sr.done_clock - sr.admit_clock)
        assert out.processing_time == pytest.approx(
            out.tx_time + out.queue_time + out.infer_time)
        # energy charges the realized window (not nominal service_time)
        expected = ((spec.power_active - spec.power_idle)
                    / spec.max_concurrency) * out.infer_time \
            + spec.tx_power * tx_dur
        assert out.energy == pytest.approx(expected)
    # the 4e6 payloads serialize on one uplink: later requests queued
    tx_times = [out.tx_time for _sid, out in policy.feedback_log]
    assert max(tx_times) > tx_dur + 1e-6


def test_server_bandwidth_factor_stable_within_slot():
    """The factor the policy observed is the factor dispatch realizes:
    repeated view builds within a slot don't advance the fluctuating
    model's RNG."""
    from repro.serving.perllm_server import PerLLMServer

    specs, engines = _tiny_fleet()
    srv = PerLLMServer(specs, engines, scheduler=_PinTo0(),
                       bandwidth=BandwidthModel(fluctuating=True, seed=3))
    v1 = srv.build_view(srv.clock)
    v2 = srv.build_view(srv.clock)
    assert v1.bw_factor == v2.bw_factor
    assert any(f != 1.0 for f in v1.bw_factor)


def test_server_lane_occupancy_tracks_remaining_tokens():
    """The live view's lane occupancy comes from each active request's
    actual remaining decode tokens — no hardcoded occupancy constant."""
    from repro.serving.perllm_server import PerLLMServer

    specs, engines = _tiny_fleet()
    srv = PerLLMServer(specs, engines, scheduler=_PinTo0())
    srv.submit([1, 2, 3], max_new_tokens=8, payload_bytes=1e4)
    # route + transmit + first engine tick (admission)
    for _ in range(40):
        if srv.engines[0].active_slots:
            break
        srv.step()
    assert srv.engines[0].active_slots
    eng = srv.engines[0]
    spec = specs[0]
    r = eng.slot_req[eng.active_slots[0]]
    remaining = r.max_new_tokens - len(r.generated)
    assert 0 < remaining < 8
    view = srv.build_view(srv.clock)
    base = max(srv.engine_clock[0], srv.clock)
    expected = base + remaining * spec.decode_step_time()
    assert max(view.lane_free[0]) == pytest.approx(expected)
    # one more decode tick shrinks the booked occupancy by one step
    srv.step()
    r2 = eng.slot_req[eng.active_slots[0]] if eng.active_slots else None
    if r2 is not None:
        view2 = srv.build_view(srv.clock)
        remaining2 = r2.max_new_tokens - len(r2.generated)
        assert remaining2 == remaining - 1
        base2 = max(srv.engine_clock[0], srv.clock)
        assert max(view2.lane_free[0]) == pytest.approx(
            base2 + remaining2 * spec.decode_step_time())
