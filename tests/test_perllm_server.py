"""PerLLMServer: the scheduler + real-engine service loop."""
import jax

from repro.cluster import paper_testbed
from repro.configs import get_config
from repro.models import init_params
from repro.serving import ServingEngine
from repro.serving.perllm_server import PerLLMServer


def _server():
    key = jax.random.key(0)
    edge_cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=64,
                                              vocab_size=256)
    cloud_cfg = get_config("gemma3-12b").reduced(n_layers=2, d_model=128,
                                                 vocab_size=256)
    specs = paper_testbed(n_edge=2)[:2] + [paper_testbed()[-1]]
    engines = [
        ServingEngine(edge_cfg, init_params(key, edge_cfg), max_batch=2,
                      max_seq=64),
        ServingEngine(edge_cfg, init_params(key, edge_cfg), max_batch=2,
                      max_seq=64),
        ServingEngine(cloud_cfg, init_params(key, cloud_cfg), max_batch=4,
                      max_seq=64),
    ]
    return PerLLMServer(specs, engines)


def test_server_serves_all_requests():
    srv = _server()
    _reqs = [srv.submit(list(range(3, 9 + i % 4)), max_new_tokens=3,
                        deadline=4.0) for i in range(10)]
    done = srv.run_until_idle()
    assert len(done) == 10
    assert all(len(sr.engine_req.generated) == 3 for sr in done)
    stats = srv.stats
    assert stats["served"] == 10
    assert sum(stats["per_server"]) == 10
    assert 0.0 <= stats["deadline_met"] <= 1.0


def test_server_learner_receives_outcomes():
    srv = _server()
    for _ in range(8):
        srv.submit([1, 2, 3, 4], max_new_tokens=2, deadline=5.0)
    srv.run_until_idle()
    # the bandit saw one update per request
    assert int(srv.scheduler.bandit.count.sum()) == 8
