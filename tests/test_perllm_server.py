"""PerLLMServer: the scheduler + real-engine service loop."""
import jax
import pytest

from repro.cluster import paper_testbed
from repro.configs import get_config
from repro.models import init_params
from repro.serving import ServingEngine
from repro.serving.perllm_server import PerLLMServer


def _server(**kw):
    key = jax.random.key(0)
    edge_cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=64,
                                              vocab_size=256)
    cloud_cfg = get_config("gemma3-12b").reduced(n_layers=2, d_model=128,
                                                 vocab_size=256)
    specs = paper_testbed(n_edge=2)[:2] + [paper_testbed()[-1]]
    engines = [
        ServingEngine(edge_cfg, init_params(key, edge_cfg), max_batch=2,
                      max_seq=64),
        ServingEngine(edge_cfg, init_params(key, edge_cfg), max_batch=2,
                      max_seq=64),
        ServingEngine(cloud_cfg, init_params(key, cloud_cfg), max_batch=4,
                      max_seq=64),
    ]
    return PerLLMServer(specs, engines, **kw)


def test_server_serves_all_requests():
    srv = _server()
    _reqs = [srv.submit(list(range(3, 9 + i % 4)), max_new_tokens=3,
                        deadline=4.0) for i in range(10)]
    done = srv.run_until_idle()
    assert len(done) == 10
    assert all(len(sr.engine_req.generated) == 3 for sr in done)
    stats = srv.stats
    assert stats["served"] == 10
    assert sum(stats["per_server"]) == 10
    assert 0.0 <= stats["deadline_met"] <= 1.0


def test_server_learner_receives_outcomes():
    srv = _server()
    for _ in range(8):
        srv.submit([1, 2, 3, 4], max_new_tokens=2, deadline=5.0)
    srv.run_until_idle()
    # the bandit saw one update per request
    assert int(srv.scheduler.bandit.count.sum()) == 8


def test_server_trace_spans_conserve_latency():
    from repro.obs import (
        KIND_ARM, KIND_DONE, KIND_INFER, KIND_QUEUE, KIND_TX,
        TraceRecorder,
    )
    rec = TraceRecorder()
    srv = _server(trace=rec)
    for i in range(6):
        srv.submit(list(range(3, 8 + i % 3)), max_new_tokens=3,
                   deadline=4.0)
    done = srv.run_until_idle()
    assert len(done) == 6
    cols = rec.to_arrays()
    kind, sid = cols["kind"], cols["sid"]
    t0, t1 = cols["t0"], cols["t1"]
    by_sid = {sr.service.sid: sr for sr in done}
    for s, sr in by_sid.items():
        m = sid == s
        span = 0.0
        for k in (KIND_TX, KIND_QUEUE, KIND_INFER):
            i = (m & (kind == k)).nonzero()[0]
            assert i.size == 1, (s, k)
            span += float(t1[i[0]] - t0[i[0]])
        assert span == pytest.approx(sr.latency, abs=1e-9)
        d = (m & (kind == KIND_DONE)).nonzero()[0]
        assert bool(cols["value"][d[0]]) == sr.met_deadline
    # the bandit shares the recorder: one ARM row per completed request
    assert int((kind == KIND_ARM).sum()) == 6


def test_server_stats_canonical_keys_and_aliases():
    from repro.obs import DEPRECATED_ALIASES
    srv = _server()
    for _ in range(5):
        srv.submit([1, 2, 3, 4], max_new_tokens=2, deadline=5.0)
    srv.run_until_idle()
    stats = srv.stats
    assert stats["n_served"] == 5
    for old, new in DEPRECATED_ALIASES.items():
        if new in stats:
            assert stats[old] == stats[new], (old, new)
    # engine-level stats share the same canonical namespace
    est = srv.engines[0].stats()
    assert "n_prefills" in est and est["prefills"] == est["n_prefills"]
