"""Pallas kernel correctness: shape/dtype sweeps vs the pure-jnp oracles.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
the same pallas_call lowers to Mosaic on a real TPU backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import decode_attention_ref, flash_attention_ref
from repro.models.layers import flash_jnp_call, sdpa
from repro.models.parallel import cpu_context

KEY = jax.random.key(42)


def _qkv(b, sq, sk, hq, hkv, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("shape", [
    (1, 128, 128, 2, 2, 64),     # MHA
    (2, 256, 256, 4, 2, 64),     # GQA
    (1, 256, 256, 8, 1, 128),    # MQA, head_dim 128
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
def test_flash_attention_kernel(shape, dtype, tol, causal, window):
    b, sq, sk, hq, hkv, d = shape
    q, k, v = _qkv(b, sq, sk, hq, hkv, d, dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          scale=1.0 / np.sqrt(d), block_q=128, block_k=128,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              scale=1.0 / np.sqrt(d))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("hq,hkv,s", [(4, 4, 512), (8, 2, 1024), (8, 1, 512)])
@pytest.mark.parametrize("valid", [1, 7, 350, -1])
def test_decode_attention_kernel(dtype, tol, hq, hkv, s, valid):
    b, d = 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32).astype(dtype)
    vl = s if valid == -1 else valid
    out = decode_attention(q, k, v, vl, scale=0.125, block_k=256,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, vl, scale=0.125)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("hq,hkv,page", [(4, 4, 16), (8, 2, 32), (8, 1, 64)])
@pytest.mark.parametrize("valid", [1, 7, 50, -1])
def test_paged_attention_matches_contiguous_decode(dtype, tol, hq, hkv,
                                                   page, valid):
    """Scattering a contiguous cache into shuffled pool pages and reading
    it back through the page table must reproduce `decode_attention`
    exactly (the paged kernel is the same math behind an indirection)."""
    from repro.kernels.paged_attention import paged_attention

    b, d, n_pages = 2, 64, 128 // page
    s = n_pages * page
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32).astype(dtype)
    vl = s if valid == -1 else valid

    # scatter each request's logical pages to shuffled physical pool slots
    n_pool = b * n_pages + 3
    perm = jax.random.permutation(ks[3], n_pool)[: b * n_pages]
    tables = perm.reshape(b, n_pages).astype(jnp.int32)
    k_pages = jnp.zeros((n_pool, hkv, page, d), dtype)
    v_pages = jnp.zeros((n_pool, hkv, page, d), dtype)
    # (B, Hkv, n_pages, page, D) -> (B, n_pages, Hkv, page, D)
    k_split = jnp.swapaxes(k.reshape(b, hkv, n_pages, page, d), 1, 2)
    v_split = jnp.swapaxes(v.reshape(b, hkv, n_pages, page, d), 1, 2)
    k_pages = k_pages.at[tables.reshape(-1)].set(
        k_split.reshape(-1, hkv, page, d))
    v_pages = v_pages.at[tables.reshape(-1)].set(
        v_split.reshape(-1, hkv, page, d))

    out = paged_attention(q, k_pages, v_pages, tables, vl, scale=0.125,
                          interpret=True)
    contiguous = decode_attention(q, k, v, vl, scale=0.125,
                                  block_k=min(page, 256), interpret=True)
    ref = decode_attention_ref(q, k, v, vl, scale=0.125)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(contiguous, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_paged_attention_ragged_valid_lengths():
    """Per-request valid lengths (a real continuous batch is ragged) vs the
    page-gathering oracle; padded table entries may alias live pages of
    other requests and must stay masked."""
    from repro.kernels.paged_attention import paged_attention
    from repro.kernels.ref import paged_attention_ref

    b, hq, hkv, d, page, n_pages = 3, 8, 2, 64, 16, 8
    n_pool = b * n_pages
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, hq, d))
    k_pages = jax.random.normal(ks[1], (n_pool, hkv, page, d))
    v_pages = jax.random.normal(ks[2], (n_pool, hkv, page, d))
    tables = jax.random.permutation(
        ks[3], n_pool).reshape(b, n_pages).astype(jnp.int32)
    # valid_len 0 is the degenerate fully-masked row: both kernel and
    # oracle reduce to the uniform softmax over masked scores — pinned
    # here so the agreement (not the absolute value) is the contract
    vl = jnp.array([0, 57, page * n_pages], jnp.int32)
    out = paged_attention(q, k_pages, v_pages, tables, vl, scale=0.125,
                          interpret=True)
    ref = paged_attention_ref(q, k_pages, v_pages, tables, vl, scale=0.125)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # model-layout ops wrapper agrees (auto-interpret on CPU)
    from repro.kernels import ops
    out2 = ops.paged_attention(q[:, None], jnp.swapaxes(k_pages, 1, 2),
                               jnp.swapaxes(v_pages, 1, 2), tables, vl,
                               scale=0.125)
    np.testing.assert_allclose(np.asarray(out2[:, 0]), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_jnp_matches_sdpa():
    ctx = cpu_context()
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 1024, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 1024, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 1024, 2, 32), jnp.float32)
    qi = jnp.arange(1024)[:, None]
    kj = jnp.arange(1024)[None, :]
    for window in (0, 256):
        mask = (kj <= qi)
        if window:
            mask = mask & (kj > qi - window)
        o1 = flash_jnp_call(q, k, v, causal=True, window=window, scale=0.2,
                            block_q=256, block_k=256)
        o2 = sdpa(q, k, v, mask[None, None, None], 0.2, ctx)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-4, atol=2e-4)


def test_flash_jnp_custom_vjp_matches_autodiff():
    """FA2 manual backward == autodiff through the reference sdpa."""
    ctx = cpu_context()
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 512, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 512, 2, 32), jnp.float32)
    qi = jnp.arange(512)[:, None]
    kj = jnp.arange(512)[None, :]
    mask = (kj <= qi) & (kj > qi - 128)

    def f1(q, k, v):
        return jnp.sum(jnp.sin(flash_jnp_call(
            q, k, v, causal=True, window=128, scale=0.2,
            block_q=128, block_k=128)))

    def f2(q, k, v):
        return jnp.sum(jnp.sin(sdpa(q, k, v, mask[None, None, None],
                                    0.2, ctx)))

    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2, strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_ssd_chunked_matches_recurrence():
    """Chunk-parallel SSD == naive per-token recurrence."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 2, 64, 4, 8, 16
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(KEY, 9), (b, s, n)) * 0.3

    y, final = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)

    # naive recurrence oracle
    hstate = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    xn, dtn, Bn, Cn = map(np.asarray, (x, dt, Bm, Cm))
    An = np.asarray(A)
    for t in range(s):
        da = np.exp(dtn[:, t] * An)                       # (b, h)
        upd = np.einsum("bh,bhp,bn->bhpn", dtn[:, t], xn[:, t], Bn[:, t])
        hstate = hstate * da[:, :, None, None] + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cn[:, t], hstate)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), hstate, rtol=2e-4,
                               atol=2e-4)


def test_rglru_scan_matches_recurrence():
    from repro.models.rglru import _gates
    dr = 16
    p = {"w_a": jnp.zeros(dr), "b_a": jnp.zeros(dr),
         "w_x": jnp.zeros(dr), "b_x": jnp.zeros(dr),
         "lam": jnp.ones(dr) * 0.5}
    u = jax.random.normal(KEY, (2, 32, dr))
    a, gi = _gates(p, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, gi), axis=1)
    # sequential oracle
    hn = np.zeros((2, dr))
    an, gn = np.asarray(a), np.asarray(gi)
    for t in range(32):
        hn = an[:, t] * hn + gn[:, t]
        np.testing.assert_allclose(np.asarray(h[:, t]), hn, rtol=1e-5,
                                   atol=1e-5)


@pytest.mark.parametrize("shape", [
    (2, 64, 4, 8, 16, 16), (1, 256, 2, 64, 128, 128), (2, 128, 8, 32, 64, 64),
])
def test_ssd_diag_kernel(shape):
    from repro.kernels.ref import ssd_diag_ref
    from repro.kernels.ssd_diag import ssd_diag
    b, s, h, d, n, chunk = shape
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.3
    out = ssd_diag(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_diag_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_model_forward_with_pallas_path():
    """use_pallas=True (interpret on CPU) == pure jnp forward."""
    from repro.configs import get_config
    from repro.models import cpu_context, dummy_batch, forward, init_params
    for arch in ("mamba2-2.7b", "gemma-2b"):
        cfg = get_config(arch).reduced()
        params = init_params(jax.random.key(0), cfg)
        batch = dummy_batch(jax.random.key(1), cfg, 1, 32, "train")
        ctx0 = cpu_context(remat=False)
        ctx1 = cpu_context(remat=False, use_pallas=True)
        l0, _, _ = forward(params, batch, cfg=cfg, ctx=ctx0, mode="train")
        l1, _, _ = forward(params, batch, cfg=cfg, ctx=ctx1, mode="train")
        np.testing.assert_allclose(np.asarray(l0, np.float32),
                                   np.asarray(l1, np.float32),
                                   rtol=3e-2, atol=3e-2)


def test_decode_with_pallas_matches_jnp():
    """decode_step with ctx.use_pallas == plain jnp decode (gemma-2b MQA)."""
    from repro.configs import get_config
    from repro.models import (
        cpu_context, decode_step, init_cache, init_params, prefill,
    )
    cfg = get_config("gemma-2b").reduced()
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 20), 0, cfg.vocab_size)
    outs = []
    for use_pallas in (False, True):
        ctx = cpu_context(remat=False, use_pallas=use_pallas)
        cache = init_cache(cfg, 2, 64)
        _, cache = prefill(params, {"tokens": toks[:, :16]}, cache,
                           cfg=cfg, ctx=ctx)
        l, _ = decode_step(params, toks[:, 16:17], cache, jnp.int32(16),
                           cfg=cfg, ctx=ctx)
        outs.append(np.asarray(l, np.float32))
    np.testing.assert_allclose(outs[0], outs[1], rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# dtype/shape parity sweep: non-power-of-two head dims (Qwen-style d=80,
# narrow d=48) through decode + paged decode vs the jnp oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("d", [48, 80])
def test_decode_attention_parity_nonpow2_head_dim(dtype, tol, d):
    b, hq, hkv, s = 2, 8, 2, 256
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32).astype(dtype)
    for vl in (1, 100, s):
        out = decode_attention(q, k, v, vl, scale=d ** -0.5, block_k=128,
                               interpret=True)
        ref = decode_attention_ref(q, k, v, vl, scale=d ** -0.5)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("d", [48, 80])
def test_paged_attention_parity_nonpow2_head_dim(dtype, tol, d):
    from repro.kernels.paged_attention import paged_attention
    from repro.kernels.ref import paged_attention_ref

    b, hq, hkv, page, n_pages = 2, 8, 2, 16, 8
    n_pool = b * n_pages
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32).astype(dtype)
    k_pages = jax.random.normal(
        ks[1], (n_pool, hkv, page, d), jnp.float32).astype(dtype)
    v_pages = jax.random.normal(
        ks[2], (n_pool, hkv, page, d), jnp.float32).astype(dtype)
    tables = jax.random.permutation(
        ks[3], n_pool).reshape(b, n_pages).astype(jnp.int32)
    vl = jnp.array([37, page * n_pages], jnp.int32)
    out = paged_attention(q, k_pages, v_pages, tables, vl,
                          scale=d ** -0.5, interpret=True)
    ref = paged_attention_ref(q, k_pages, v_pages, tables, vl,
                              scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_auto_interpret_memoized_and_forced_lowering_error():
    """The backend probe resolves once per process (lru_cache), and
    forcing interpret=False where Pallas cannot lower fails loudly
    instead of dying inside Mosaic."""
    from repro.kernels import ops

    ops._backend_is_cpu.cache_clear()
    first = ops._auto_interpret(None)
    before = ops._backend_is_cpu.cache_info().misses
    assert ops._auto_interpret(None) is first
    info = ops._backend_is_cpu.cache_info()
    assert info.misses == before and info.hits >= 1
    assert ops._auto_interpret(True) is True
    if ops._backend_is_cpu():
        with pytest.raises(RuntimeError, match="interpret=False was forced"):
            ops._auto_interpret(False)
    else:
        assert ops._auto_interpret(False) is False
