"""Unified SchedulingPolicy API: golden equivalence, registry, regressions.

The golden test freezes the *seed scheduling protocol* — a verbatim copy of
the pre-redesign `PerLLMScheduler` that returns bare server indices and
calls `view.commit` itself — and checks that the migrated policy, driven
through the new Decision path by the runtime, reproduces its `SimResult`
bit-for-bit (success rate, energy components, per-request choices) on a
fixed-seed workload. The legacy copy runs through the `as_policy`
deprecation shim, so the test also proves out-of-tree `SchedulerBase`
subclasses still behave identically.

Scope note: both sides share today's `CSUCB`, whose time-advance semantics
this same PR intentionally changed (`t` now ticks in `update()`, not
`ucb()`). The equivalence therefore isolates the *API migration* — bare
indices + policy-side commit vs Decision + runtime commit — rather than
reproducing the pre-PR commit's absolute numbers, which differ by design.
"""
import copy
import math

import numpy as np
import pytest

from repro.cluster import (
    BandwidthModel, ClusterView, SchedulerBase, Simulator, SlotView,
    generate_workload, paper_testbed,
)
from repro.cluster.workload import N_CLASSES
from repro.core import (
    CSUCB, CSUCBParams, Decision, LegacyPolicyAdapter,
    SchedulingPolicy, as_policy, available_policies, drive_slot, make_policy,
)
from repro.core.bandit import CSUCB as _CSUCB
from repro.core.constraints import evaluate_constraints
from repro.core.scheduler import E_SCALE


# ---------------------------------------------------------------------------
# Frozen seed protocol: the pre-redesign PerLLM scheduler, verbatim
# ---------------------------------------------------------------------------


class SeedPerLLM(SchedulerBase):
    """The seed `PerLLMScheduler` under the old batch contract: bare index
    list, policy-side `view.commit`, `observe` feedback."""

    name = "PerLLM"
    SAFETY = 1.05

    def __init__(self, n_servers, params=None, seed=0):
        self.n_servers = n_servers
        self.bandit = _CSUCB(N_CLASSES, n_servers, params, seed=seed)
        self.time_ratio = np.ones((N_CLASSES, n_servers), np.float64)
        self.ratio_count = np.zeros((N_CLASSES, n_servers), np.int64)
        self.err_var = np.zeros((N_CLASSES, n_servers), np.float64)
        self.infer_ratio = np.ones((N_CLASSES, n_servers), np.float64)
        self._pending_slacks = {}
        self._nominal_pred = {}
        self._last_nominal_infer = {}

    def predicted_time(self, req, j, view):
        cls = req.class_id
        d_hat = (view.predict_tx(req, j) + view.predict_queue(req, j)
                 + view.predict_infer(req, j) * self.infer_ratio[cls, j])
        margin = math.sqrt(self.err_var[cls, j])
        return d_hat * self.time_ratio[cls, j] * self.SAFETY + margin

    def schedule(self, arrivals, view, t_slot):
        choices = []
        for req in arrivals:
            slacks = []
            feasible = np.zeros(self.n_servers, bool)
            for j in range(self.n_servers):
                d_hat = self.predicted_time(req, j, view)
                s = evaluate_constraints(req, j, view, predicted_time=d_hat)
                slacks.append(s)
                feasible[j] = s.satisfied
            if feasible.any():
                j = self.bandit.select(req.class_id, feasible)
            else:
                j = int(np.argmin([self.predicted_time(req, jj, view)
                                   for jj in range(self.n_servers)]))
            self._pending_slacks[req.sid] = slacks[j]
            self._nominal_pred[req.sid] = self.predicted_time(req, j, view) \
                / self.SAFETY
            self._last_nominal_infer[req.sid] = view.predict_infer(req, j)
            view.commit(req, j,
                        infer_scale=self.infer_ratio[req.class_id, j])
            choices.append(j)
        return choices

    def observe(self, req, out):
        slacks = self._pending_slacks.pop(req.sid, None)
        nominal = self._nominal_pred.pop(req.sid, None)
        cls, j = req.class_id, out.server
        time_slack = (req.deadline - out.processing_time) / req.deadline
        f_y = min(time_slack,
                  slacks.compute if slacks else 0.0,
                  slacks.bandwidth if slacks else 0.0)
        reward = self.bandit.shaped_reward(out.energy / E_SCALE, f_y)
        violation = max(-f_y, 0.0)
        self.bandit.update(cls, j, reward, violation)
        nom_inf = out.infer_time
        self.infer_ratio[cls, j] += 0.1 * (
            out.infer_time / max(self._last_nominal_infer.pop(req.sid,
                                                              nom_inf),
                                 1e-9) - self.infer_ratio[cls, j])
        if nominal and nominal > 0:
            ratio = out.processing_time / nominal
            self.ratio_count[cls, j] += 1
            n = self.ratio_count[cls, j]
            self.time_ratio[cls, j] += (ratio - self.time_ratio[cls, j]) / n
            err = out.processing_time - nominal * self.time_ratio[cls, j]
            self.err_var[cls, j] += (err * err - self.err_var[cls, j]) \
                / max(n, 1)


def _run(scheduler, n=600, wl_seed=3, sim_seed=5):
    specs = paper_testbed()
    services = [copy.copy(s) for s in generate_workload(n, seed=wl_seed)]
    sim = Simulator(specs, BandwidthModel(fluctuating=True, seed=2),
                    seed=sim_seed)
    res = sim.run(services, scheduler)
    return res, [r.server for r in sorted(services, key=lambda r: r.sid)]


def test_golden_equivalence_perllm():
    """make_policy("perllm") through the Decision path == seed protocol."""
    res_new, choices_new = _run(make_policy("perllm", 6))
    res_old, choices_old = _run(SeedPerLLM(6))
    assert choices_new == choices_old
    assert res_new.success_rate == res_old.success_rate
    assert res_new.per_server_served == res_old.per_server_served
    assert res_new.e_tx == pytest.approx(res_old.e_tx)
    assert res_new.e_infer == pytest.approx(res_old.e_infer)
    assert res_new.e_idle == pytest.approx(res_old.e_idle)
    assert res_new.avg_processing_time == pytest.approx(
        res_old.avg_processing_time)
    assert res_new.makespan == pytest.approx(res_old.makespan)


def test_golden_equivalence_native_vs_compat_schedule():
    """The deprecated batch `schedule()` wrapper is the same computation."""
    res_a, choices_a = _run(make_policy("perllm", 6), n=300)
    res_b, choices_b = _run(as_policy(make_policy("perllm", 6)), n=300)
    assert choices_a == choices_b
    assert res_a.success_rate == res_b.success_rate


# ---------------------------------------------------------------------------
# Decision semantics
# ---------------------------------------------------------------------------


def test_policies_do_not_mutate_requests():
    """Deferral is Decision data now — FineInfer no longer stamps
    `req.defer_until` onto requests."""
    specs = paper_testbed()
    services = [copy.copy(s) for s in generate_workload(150, seed=1)]
    sim = Simulator(specs, BandwidthModel(), seed=1)
    sim.run(services, make_policy("fineinfer", len(specs)))
    assert not any(hasattr(r, "defer_until") for r in services)


def test_fineinfer_defer_applied_by_runtime():
    """Deferred batching still delays dispatch (tx starts at the window)."""
    specs = paper_testbed()
    services = [copy.copy(s) for s in generate_workload(80, seed=1)]
    sim = Simulator(specs, BandwidthModel(), seed=1)
    sim.run(services, make_policy("fineinfer", len(specs),
                                  batch_window=1.0))
    # every request finishes after its batching-window boundary
    for r in sorted(services, key=lambda r: r.sid):
        assert r.finish >= math.ceil(r.arrival / 1.0) * 1.0


def test_legacy_scheduler_base_still_runs():
    class Old(SchedulerBase):
        name = "old"

        def schedule(self, arrivals, view, t_slot):
            out = []
            for r in arrivals:
                view.commit(r, 0)
                out.append(0)
            return out

    specs = paper_testbed()
    services = [copy.copy(s) for s in generate_workload(60, seed=0)]
    res = Simulator(specs, seed=1).run(services, Old())
    assert res.name == "old"
    assert res.per_server_served[0] == 60


def test_drive_slot_commits_residuals():
    """The runtime, not the policy, consumes capacity per Decision."""
    specs = paper_testbed()
    view = ClusterView(t=0.0, specs=specs, bw_factor=[1.0] * len(specs),
                       uplink_free_at=[0.0] * len(specs),
                       lane_free=[[0.0] * s.max_concurrency for s in specs])

    class Fixed(SchedulingPolicy):
        def assign(self, req, v):
            return Decision(server=0)

    services = generate_workload(5, seed=0)
    from repro.cluster.workload import classify
    for s in services:
        s.class_id = classify(s)
    before = view.uplink_free_at[0]
    decisions = drive_slot(Fixed(), services, view)
    assert [d.server for d in decisions] == [0] * 5
    assert view.uplink_free_at[0] > before
    assert sorted(view.lane_free[0]) != [0.0] * specs[0].max_concurrency


def test_slotview_is_clusterview_alias():
    assert SlotView is ClusterView


def test_legacy_adapter_assign_does_not_touch_callers_view():
    """Per the contract, `assign` is pure w.r.t. the view: the adapter runs
    the legacy scheduler on a shadow copy, so a runtime doing
    assign + view.apply commits exactly once (no double-commit)."""
    class Old(SchedulerBase):
        name = "old"

        def schedule(self, arrivals, view, t_slot):
            out = []
            for r in arrivals:
                view.commit(r, 0)
                out.append(0)
            return out

    specs = paper_testbed()
    view = ClusterView(t=0.0, specs=specs, bw_factor=[1.0] * len(specs),
                       uplink_free_at=[0.0] * len(specs),
                       lane_free=[[0.0] * s.max_concurrency for s in specs])
    req = copy.copy(generate_workload(1, seed=0)[0])
    from repro.cluster.workload import classify
    req.class_id = classify(req)
    adapter = as_policy(Old())
    assert isinstance(adapter, LegacyPolicyAdapter)
    d = adapter.assign(req, view)
    assert view.uplink_free_at[0] == 0.0        # caller's view untouched
    assert view.lane_free[0] == [0.0] * specs[0].max_concurrency
    view.apply(req, d)
    assert view.uplink_free_at[0] > 0.0         # committed exactly once


def test_legacy_adapter_assign_lifts_infer_scale():
    """A legacy scheduler's scaled lane booking survives the shim: the
    adapter derives infer_scale from the shadow commit so the runtime's
    single apply reproduces it."""
    class OldScaled(SchedulerBase):
        name = "old-scaled"

        def schedule(self, arrivals, view, t_slot):
            out = []
            for r in arrivals:
                view.commit(r, 1, infer_scale=2.0)
                out.append(1)
            return out

    specs = paper_testbed()
    view = ClusterView(t=0.0, specs=specs, bw_factor=[1.0] * len(specs),
                       uplink_free_at=[0.0] * len(specs),
                       lane_free=[[0.0] * s.max_concurrency for s in specs])
    req = copy.copy(generate_workload(1, seed=0)[0])
    from repro.cluster.workload import classify
    req.class_id = classify(req)
    d = as_policy(OldScaled()).assign(req, view)
    assert d.infer_scale == pytest.approx(2.0)
    # applying the Decision books the same lane time the legacy commit did
    nominal = view.predict_infer(req, 1)
    ready = view.predict_tx(req, 1)
    view.apply(req, d)
    assert max(view.lane_free[1]) == pytest.approx(ready + 2.0 * nominal)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_round_trip():
    for name in ("perllm", "PerLLM", "FineInfer", "agod",
                 "rewardless-guidance", "RewardlessGuidance"):
        p = make_policy(name, 6)
        assert isinstance(p, SchedulingPolicy)
    assert {"agod", "fineinfer", "perllm", "rewardless-guidance"} \
        <= set(available_policies())


def test_registry_kwargs_forwarded():
    p = make_policy("fineinfer", 6, batch_window=2.5)
    assert p.batch_window == 2.5
    p = make_policy("perllm", 4, params=CSUCBParams(delta=0.123))
    assert p.bandit.p.delta == 0.123
    assert p.n_servers == 4


def test_registry_unknown_name_raises():
    with pytest.raises(KeyError, match="unknown scheduling policy"):
        make_policy("nope-not-a-policy", 6)


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------


def test_csucb_ucb_is_side_effect_free():
    bandit = CSUCB(1, 3)
    mask = np.ones(3, bool)
    t0 = bandit.t
    s1 = bandit.ucb(0, mask)
    s2 = bandit.ucb(0, mask)
    assert bandit.t == t0            # scoring does not advance bandit time
    assert np.array_equal(s1, s2)    # double scoring is idempotent
    bandit.select(0, mask)
    assert bandit.t == t0
    bandit.update(0, 0, 0.5, 0.0)
    assert bandit.t == t0 + 1        # time advances only on feedback


def test_simulator_empty_services():
    specs = paper_testbed()
    res = Simulator(specs, seed=0).run([], make_policy("perllm", len(specs)))
    assert res.n_services == 0
    assert res.success_rate == 0.0
    assert res.total_energy == 0.0
    assert res.makespan == 0.0
    assert res.per_server_served == [0] * len(specs)


def test_perllm_server_view_not_degenerate():
    """The live server observes real bandwidth factors and persistent
    uplink state (previously hardcoded to 1.0 / clock)."""
    pytest.importorskip("jax")
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingEngine
    from repro.serving.perllm_server import PerLLMServer

    cfg = get_config("gemma-2b").reduced(n_layers=1, d_model=32,
                                         vocab_size=128)
    key = jax.random.key(0)
    specs = paper_testbed(n_edge=1)[:1] + [paper_testbed()[-1]]
    engines = [ServingEngine(cfg, init_params(key, cfg), max_batch=2,
                             max_seq=32) for _ in range(2)]
    srv = PerLLMServer(specs, engines,
                       bandwidth=BandwidthModel(fluctuating=True, seed=3))
    for _ in range(4):
        srv.submit([1, 2, 3], max_new_tokens=2, payload_bytes=4e6)
    srv.step()
    # routing committed real uplink occupancy that persists across steps
    assert max(srv.uplink_free_at) > 0.0
    view = srv._view()
    assert list(view.uplink_free_at) == list(srv.uplink_free_at)
    assert any(f != 1.0 for f in view.bw_factor)
    srv.run_until_idle()
    assert srv.stats["served"] == 4


def test_perllm_server_applies_defer_until():
    """The live runtime honors Decision.defer_until: deferred-batching
    requests are held out of the engines until their window boundary."""
    pytest.importorskip("jax")
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingEngine
    from repro.serving.perllm_server import PerLLMServer

    cfg = get_config("gemma-2b").reduced(n_layers=1, d_model=32,
                                         vocab_size=128)
    key = jax.random.key(0)
    specs = paper_testbed(n_edge=1)[:1] + [paper_testbed()[-1]]
    engines = [ServingEngine(cfg, init_params(key, cfg), max_batch=2,
                             max_seq=32) for _ in range(2)]
    srv = PerLLMServer(specs, engines,
                       scheduler=make_policy("fineinfer", 2,
                                             batch_window=1.0))
    srv.step()                       # advance the clock off zero
    assert 0.0 < srv.clock < 1.0
    sr = srv.submit([1, 2, 3], max_new_tokens=2)
    srv.step()                       # routed: window boundary is at t=1.0
    assert sr.decision.defer_until == 1.0
    assert sr.engine_req is None     # held — not yet in any engine
    assert sr in srv._deferred
    done = srv.run_until_idle()
    assert sr in done and sr.done
    assert sr.done_clock >= 1.0      # dispatched only after the window
