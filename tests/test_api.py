"""Unified SchedulingPolicy API: Decision/Allocation semantics, registry,
runtime-applied commits, and the retirement of the legacy batch protocol.

The pre-PR-1 `SchedulerBase`/`as_policy` deprecation shims are gone
(nothing in-tree subclassed them since PR 1); the golden coverage that the
Decision path reproduces the seed protocol lives on in
`tests/test_runtime.py` (frozen PR-1 slot loop) and
`tests/test_allocation.py` (nominal-tier bit-exactness).
"""
import copy
import math

import numpy as np
import pytest

from repro.cluster import (
    BandwidthModel, ClusterView, Simulator, generate_workload, paper_testbed,
)
from repro.core import (
    CSUCB, CSUCBParams, Decision, SchedulingPolicy, available_policies,
    drive_slot, ensure_policy, make_policy,
)


# ---------------------------------------------------------------------------
# Decision semantics
# ---------------------------------------------------------------------------


def test_policies_do_not_mutate_requests():
    """Deferral is Decision data — FineInfer never stamps `req.defer_until`
    onto requests."""
    specs = paper_testbed()
    services = [copy.copy(s) for s in generate_workload(150, seed=1)]
    sim = Simulator(specs, BandwidthModel(), seed=1)
    sim.run(services, make_policy("fineinfer", len(specs)))
    assert not any(hasattr(r, "defer_until") for r in services)


def test_fineinfer_defer_applied_by_runtime():
    """Deferred batching still delays dispatch (tx starts at the window)."""
    specs = paper_testbed()
    services = [copy.copy(s) for s in generate_workload(80, seed=1)]
    sim = Simulator(specs, BandwidthModel(), seed=1)
    sim.run(services, make_policy("fineinfer", len(specs),
                                  batch_window=1.0))
    # every request finishes after its batching-window boundary
    for r in sorted(services, key=lambda r: r.sid):
        assert r.finish >= math.ceil(r.arrival / 1.0) * 1.0


def test_drive_slot_commits_residuals():
    """The runtime, not the policy, consumes capacity per Decision."""
    specs = paper_testbed()
    view = ClusterView(t=0.0, specs=specs, bw_factor=[1.0] * len(specs),
                       uplink_free_at=[0.0] * len(specs),
                       lane_free=[[0.0] * s.max_concurrency for s in specs])

    class Fixed(SchedulingPolicy):
        def assign(self, req, v):
            return Decision(server=0)

    services = generate_workload(5, seed=0)
    from repro.cluster.workload import classify
    for s in services:
        s.class_id = classify(s)
    before = view.uplink_free_at[0]
    decisions = drive_slot(Fixed(), services, view)
    assert [d.server for d in decisions] == [0] * 5
    assert view.uplink_free_at[0] > before
    assert sorted(view.lane_free[0]) != [0.0] * specs[0].max_concurrency


def test_decision_defaults_are_nominal_allocation():
    """A bare Decision carries the nominal Allocation: nominal tier, full
    lane and uplink shares — the placement-only contract."""
    d = Decision(server=2)
    assert d.alloc.freq_tier == -1
    assert d.alloc.lane_share == 1.0
    assert d.alloc.bw_share == 1.0


# ---------------------------------------------------------------------------
# Legacy protocol retirement
# ---------------------------------------------------------------------------


def test_legacy_scheduler_base_protocol_removed():
    """The batch `SchedulerBase` shims are gone from both packages, and a
    batch-protocol object is rejected with a migration pointer rather
    than silently wrapped."""
    import repro.cluster as cluster
    import repro.core as core
    for name in ("SchedulerBase", "as_policy", "LegacyPolicyAdapter",
                 "SlotView"):
        assert not hasattr(core, name), name
        assert not hasattr(cluster, name), name

    class OldStyle:
        def schedule(self, arrivals, view, t_slot):
            return [0] * len(arrivals)

    with pytest.raises(TypeError, match="SchedulerBase batch protocol"):
        ensure_policy(OldStyle())
    with pytest.raises(TypeError, match="SchedulingPolicy"):
        Simulator(paper_testbed(), seed=0).run(
            [copy.copy(s) for s in generate_workload(3, seed=0)],
            OldStyle())


def test_scheduling_policy_has_no_batch_shim_methods():
    p = make_policy("perllm", 6)
    assert not hasattr(p, "observe")
    assert not callable(getattr(p, "schedule", None))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_round_trip():
    for name in ("perllm", "PerLLM", "FineInfer", "agod",
                 "rewardless-guidance", "RewardlessGuidance"):
        p = make_policy(name, 6)
        assert isinstance(p, SchedulingPolicy)
    assert {"agod", "fineinfer", "perllm", "rewardless-guidance"} \
        <= set(available_policies())


def test_registry_kwargs_forwarded():
    p = make_policy("fineinfer", 6, batch_window=2.5)
    assert p.batch_window == 2.5
    p = make_policy("perllm", 4, params=CSUCBParams(delta=0.123))
    assert p.bandit.p.delta == 0.123
    assert p.n_servers == 4


def test_registry_unknown_name_raises():
    with pytest.raises(KeyError, match="unknown scheduling policy"):
        make_policy("nope-not-a-policy", 6)


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------


def test_csucb_ucb_is_side_effect_free():
    bandit = CSUCB(1, 3)
    mask = np.ones(3, bool)
    t0 = bandit.t
    s1 = bandit.ucb(0, mask)
    s2 = bandit.ucb(0, mask)
    assert bandit.t == t0            # scoring does not advance bandit time
    assert np.array_equal(s1, s2)    # double scoring is idempotent
    bandit.select(0, mask)
    assert bandit.t == t0
    bandit.update(0, 0, 0.5, 0.0)
    assert bandit.t == t0 + 1        # time advances only on feedback


def test_csucb_regret_bound_tracks_arm_space():
    """Satellite bugfix: Eq. 7's arm count comes from the live arm-space
    shape, so a (class, server, tier) bandit reports a wider bound than
    its placement-only projection at the same pull counts."""
    flat = CSUCB(2, 3)
    tiered = CSUCB(2, 3, n_tiers=4)
    for b in (flat, tiered):
        b.update(0, 1, 0.1, 0.0)
        b.update(0, 1, 0.1, 0.0)
        b.update(0, 1, 0.1, 0.0)
    assert flat.regret_bound() == pytest.approx(
        math.sqrt(2.0 * 2 * 3 * math.log(3)))
    assert tiered.regret_bound() == pytest.approx(
        math.sqrt(2.0 * 2 * 3 * 4 * math.log(3)))
    assert tiered.regret_bound() > flat.regret_bound()


def test_simulator_empty_services():
    specs = paper_testbed()
    res = Simulator(specs, seed=0).run([], make_policy("perllm", len(specs)))
    assert res.n_services == 0
    assert res.success_rate == 0.0
    assert res.total_energy == 0.0
    assert res.makespan == 0.0
    assert res.per_server_served == [0] * len(specs)


def test_perllm_server_view_not_degenerate():
    """The live server observes real bandwidth factors and persistent
    uplink state (previously hardcoded to 1.0 / clock)."""
    pytest.importorskip("jax")
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingEngine
    from repro.serving.perllm_server import PerLLMServer

    cfg = get_config("gemma-2b").reduced(n_layers=1, d_model=32,
                                         vocab_size=128)
    key = jax.random.key(0)
    specs = paper_testbed(n_edge=1)[:1] + [paper_testbed()[-1]]
    engines = [ServingEngine(cfg, init_params(key, cfg), max_batch=2,
                             max_seq=32) for _ in range(2)]
    srv = PerLLMServer(specs, engines,
                       bandwidth=BandwidthModel(fluctuating=True, seed=3))
    for _ in range(4):
        srv.submit([1, 2, 3], max_new_tokens=2, payload_bytes=4e6)
    srv.step()
    # routing committed real uplink occupancy that persists across steps
    assert max(srv.uplink_free_at) > 0.0
    view = srv._view()
    assert list(view.uplink_free_at) == list(srv.uplink_free_at)
    assert any(f != 1.0 for f in view.bw_factor)
    srv.run_until_idle()
    assert srv.stats["served"] == 4


def test_perllm_server_applies_defer_until():
    """The live runtime honors Decision.defer_until: deferred-batching
    requests are held out of the engines until their window boundary."""
    pytest.importorskip("jax")
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingEngine
    from repro.serving.perllm_server import PerLLMServer

    cfg = get_config("gemma-2b").reduced(n_layers=1, d_model=32,
                                         vocab_size=128)
    key = jax.random.key(0)
    specs = paper_testbed(n_edge=1)[:1] + [paper_testbed()[-1]]
    engines = [ServingEngine(cfg, init_params(key, cfg), max_batch=2,
                             max_seq=32) for _ in range(2)]
    srv = PerLLMServer(specs, engines,
                       scheduler=make_policy("fineinfer", 2,
                                             batch_window=1.0))
    srv.step()                       # advance the clock off zero
    assert 0.0 < srv.clock < 1.0
    sr = srv.submit([1, 2, 3], max_new_tokens=2)
    srv.step()                       # routed: window boundary is at t=1.0
    assert sr.decision.defer_until == 1.0
    assert sr.engine_req is None     # held — not yet in any engine
    assert sr in srv._deferred
    done = srv.run_until_idle()
    assert sr in done and sr.done
    assert sr.done_clock >= 1.0      # dispatched only after the window