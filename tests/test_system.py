"""End-to-end system behaviour: the paper's pipeline wired together.

A miniature PerLLM deployment: real JAX serving engines as edge/cloud
servers driven by the CS-UCB scheduler over a simulated cluster, plus the
paper's headline claims at reduced scale.
"""
import copy

import jax
import numpy as np

from repro.cluster import (
    BandwidthModel, Simulator, generate_workload, paper_testbed,
)
from repro.configs import get_config
from repro.core import PerLLMScheduler, make_baselines
from repro.models import init_params
from repro.serving import ServingEngine


def test_paper_claims_reduced_scale():
    """Table-1-style run at 1/5 scale: success >= 93%, energy < FineInfer/2."""
    specs = paper_testbed("llama2-7b")
    services = generate_workload(2000, seed=0)
    results = {}
    for sched in [PerLLMScheduler(len(specs))] + make_baselines(len(specs)):
        sim = Simulator(specs, BandwidthModel(fluctuating=False, seed=1),
                        seed=42)
        results[sched.name] = sim.run([copy.copy(s) for s in services],
                                      sched)
    per = results["PerLLM"]
    fine = results["FineInfer"]
    assert per.success_rate >= 0.93
    assert per.total_energy < 0.5 * fine.total_energy
    assert per.avg_processing_time < fine.avg_processing_time


def test_scheduler_drives_real_engines():
    """PerLLM decisions dispatch to actual JAX serving engines."""
    edge_cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=64,
                                              vocab_size=256)
    cloud_cfg = get_config("gemma3-12b").reduced(n_layers=2, d_model=128,
                                                 vocab_size=256)
    key = jax.random.key(0)
    engines = [
        ServingEngine(edge_cfg, init_params(key, edge_cfg), max_batch=2,
                      max_seq=64),
        ServingEngine(cloud_cfg, init_params(key, cloud_cfg), max_batch=4,
                      max_seq=64),
    ]
    specs = paper_testbed(n_edge=1)  # 1 edge + cloud to mirror engines
    sched = PerLLMScheduler(2)
    services = generate_workload(30, rate=5.0, seed=1)

    from repro.cluster.workload import classify
    from repro.core import ClusterView, drive_slot
    view = ClusterView(t=0.0, specs=specs[:2], bw_factor=[1.0, 1.0],
                       uplink_free_at=[0.0, 0.0],
                       lane_free=[[0.0] * 2, [0.0] * 4])
    for svc in services:
        svc.class_id = classify(svc)
    decisions = drive_slot(sched, services, view, 0)
    assert len(decisions) == len(services)
    for svc, d in zip(services, decisions, strict=True):
        engines[d.server].submit(list(np.arange(4) + svc.sid % 32),
                                 max_new_tokens=2)
    done = [e.run_until_idle() for e in engines]
    assert sum(len(d) for d in done) == len(services)


def test_fluctuating_bandwidth_still_meets_claims():
    specs = paper_testbed("yi-6b")
    services = generate_workload(1500, seed=2)
    sim = Simulator(specs, BandwidthModel(fluctuating=True, seed=7), seed=9)
    res = sim.run([copy.copy(s) for s in services],
                  PerLLMScheduler(len(specs)))
    assert res.success_rate >= 0.9


def test_regret_trace_recorded():
    specs = paper_testbed()
    services = generate_workload(500, seed=4)
    sched = PerLLMScheduler(len(specs))
    Simulator(specs, seed=1).run([copy.copy(s) for s in services], sched)
    trace = sched.regret_trace
    assert len(trace) == 500
