"""Cluster model: cost monotonicity, bandwidth bounds, energy accounting."""
import copy

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.cluster import (
    BandwidthModel, Simulator, generate_workload, paper_testbed, tpu_testbed,
)
from repro.cluster.workload import classify
from repro.core import PerLLMScheduler


def test_service_time_monotone_in_tokens():
    spec = paper_testbed()[0]
    assert spec.service_time(100, 10) < spec.service_time(200, 10)
    assert spec.service_time(100, 10) < spec.service_time(100, 20)


def test_decode_memory_vs_compute_bound():
    spec = paper_testbed()[-1]      # cloud A100
    t1 = spec.decode_step_time(batch=1)
    t_big = spec.decode_step_time(batch=10_000)
    assert t_big > t1               # eventually compute-bound
    # batch=1 is memory-bound: equals weight-streaming time
    stream = spec.active_params() * spec.weight_bytes_per_param / spec.mem_bw
    assert abs(t1 - stream) < 1e-9


@given(st.integers(0, 1000), st.integers(0, 5))
@settings(max_examples=50, deadline=None)
def test_bandwidth_factor_bounds(t, j):
    bw = BandwidthModel(fluctuating=True, amplitude=0.2, seed=1)
    f = bw.factor(t, j)
    assert 0.8 - 1e-9 <= f <= 1.2 + 1e-9
    assert BandwidthModel(fluctuating=False).factor(t, j) == 1.0


def test_workload_deterministic_and_diverse():
    w1 = generate_workload(200, seed=9)
    w2 = generate_workload(200, seed=9)
    assert [r.payload_bytes for r in w1] == [r.payload_bytes for r in w2]
    assert all(2.0 <= r.deadline <= 6.0 for r in w1)
    classes = {classify(r) for r in w1}
    assert len(classes) >= 6        # diverse service classes

def test_energy_components_nonnegative_and_complete():
    specs = paper_testbed()
    services = generate_workload(300, seed=1)
    sim = Simulator(specs, BandwidthModel(), seed=2)
    res = sim.run([copy.copy(s) for s in services], PerLLMScheduler(len(specs)))
    assert res.e_tx >= 0 and res.e_infer > 0 and res.e_idle > 0
    assert abs(res.total_energy - (res.e_tx + res.e_infer + res.e_idle)) < 1e-6
    assert res.makespan > 0
    assert res.throughput_tokens_per_s > 0


def test_tpu_testbed_cloud_is_faster():
    paper_cloud = paper_testbed()[-1]
    tpu_cloud = tpu_testbed(cloud_chips=4)[-1]
    assert tpu_cloud.flops > paper_cloud.flops
    assert tpu_cloud.kind == "cloud"


def test_hidden_efficiency_per_class():
    specs = paper_testbed()
    sim = Simulator(specs, seed=3)
    assert sim.efficiency.shape[1] == len(specs)
    assert (sim.efficiency >= 0.7 - 1e-9).all()
    assert (sim.efficiency <= 1.0 + 1e-9).all()
    # diversity across classes (the personalization premise)
    assert np.std(sim.efficiency, axis=0).max() > 0.01
