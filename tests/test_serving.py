"""Serving engine: continuous batching, slot reuse, per-slot positions."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import cpu_context, decode_step, init_cache, init_params, prefill
from repro.serving import ServingEngine, sample_tokens

CFG = get_config("gemma-2b").reduced(n_layers=2, d_model=128, vocab_size=512)


def _params():
    return init_params(jax.random.key(0), CFG)


def test_engine_completes_all_requests():
    eng = ServingEngine(CFG, _params(), max_batch=3, max_seq=128)
    _reqs = [eng.submit(list(range(5, 12 + i)), max_new_tokens=6)
             for i in range(7)]
    done = eng.run_until_idle()
    assert len(done) == 7
    assert all(len(r.generated) == 6 for r in done)


def test_engine_greedy_matches_manual_decode():
    """One request through the engine == manual prefill+decode loop."""
    params = _params()
    prompt = [3, 5, 7, 9, 11]
    eng = ServingEngine(CFG, params, max_batch=2, max_seq=64)
    req = eng.submit(prompt, max_new_tokens=4)
    eng.run_until_idle()

    ctx = cpu_context()
    cache = init_cache(CFG, 1, 64)
    tok = jnp.asarray(prompt, jnp.int32)[None]
    last, cache = prefill(params, {"tokens": tok}, cache, cfg=CFG, ctx=ctx)
    manual = [int(jnp.argmax(last[0]))]
    pos = len(prompt)
    for _ in range(3):
        logits, cache = decode_step(
            params, jnp.asarray([[manual[-1]]], jnp.int32), cache,
            jnp.int32(pos), cfg=CFG, ctx=ctx)
        manual.append(int(jnp.argmax(logits[0])))
        pos += 1
    assert req.generated == manual


def test_engine_slot_reuse():
    eng = ServingEngine(CFG, _params(), max_batch=2, max_seq=64)
    for i in range(5):
        eng.submit([1, 2, 3, 4 + i], max_new_tokens=3)
    done = eng.run_until_idle()
    assert len(done) == 5
    slots = {r.slot for r in done}
    assert slots <= {0, 1}          # only 2 slots existed


def test_eos_stops_generation():
    params = _params()
    # find the greedy first token, then use it as "EOS"
    eng0 = ServingEngine(CFG, params, max_batch=1, max_seq=64)
    r0 = eng0.submit([5, 6, 7], max_new_tokens=4)
    eng0.run_until_idle()
    eos = r0.generated[0]
    eng = ServingEngine(CFG, params, max_batch=1, max_seq=64)
    r = eng.submit([5, 6, 7], max_new_tokens=10, eos_id=eos)
    eng.run_until_idle()
    assert r.generated == [eos]


def test_sampling_modes():
    key = jax.random.key(0)
    logits = jnp.array([[0.0, 5.0, 0.0, 0.0]])
    assert int(sample_tokens(key, logits, temperature=0.0)[0]) == 1
    # top-k=1 == greedy even with temperature
    assert int(sample_tokens(key, logits, temperature=1.0, top_k=1)[0]) == 1
    # high temperature explores
    draws = {int(sample_tokens(jax.random.key(i), logits,
                               temperature=50.0)[0]) for i in range(40)}
    assert len(draws) > 1


def test_engine_compile_counters():
    """decode compiles exactly once per (batch, 1) token shape and
    prefill once per pow-2 seq bucket — warm shapes never re-count."""
    eng = ServingEngine(CFG, _params(), max_batch=2, max_seq=64)
    assert eng.decode_compiles == 0 and eng.prefill_compiles == 0
    eng.submit([1, 2, 3, 4, 5], max_new_tokens=3)        # bucket 8
    eng.submit([9, 8, 7, 6, 5, 4, 3], max_new_tokens=3)  # bucket 8 too
    eng.run_until_idle()
    assert eng.prefill_compiles == 1
    assert eng.decode_compiles == 1
    eng.submit(list(range(1, 21)), max_new_tokens=3)     # bucket 32
    eng.run_until_idle()
    assert eng.prefill_compiles == 2
    assert eng.decode_compiles == 1
    eng.submit([2, 4, 6], max_new_tokens=3)              # bucket 4: new
    eng.submit([3, 5, 7], max_new_tokens=3)              # bucket 4: warm
    eng.run_until_idle()
    assert eng.prefill_compiles == 3
    assert eng.decode_compiles == 1
    # the counters mirror jit's own shape-keyed cache when it exposes one
    if hasattr(eng._decode, "_cache_size"):
        assert eng._decode._cache_size() == eng.decode_compiles
        assert eng._prefill._cache_size() == eng.prefill_compiles


def test_prefix_hit_decode_compile_counted_once():
    """The prefix-hit suffix prefill runs through the batch-1 decode jit:
    one extra decode shape the first time, none after."""
    eng = ServingEngine(CFG, _params(), max_batch=2, max_seq=128,
                        paged=True, kv_block_tokens=16)
    shared = list(range(100, 132))          # 32 tokens = 2 full blocks
    eng.submit(shared + [7, 8, 9], max_new_tokens=3)
    eng.run_until_idle()
    d0 = eng.decode_compiles
    eng.submit(shared + [10, 11, 12], max_new_tokens=3)
    eng.run_until_idle()
    assert eng.n_prefix_hits == 1
    assert eng.decode_compiles == d0 + 1
    eng.submit(shared + [13, 14], max_new_tokens=3)
    eng.run_until_idle()
    assert eng.n_prefix_hits == 2
    assert eng.decode_compiles == d0 + 1
