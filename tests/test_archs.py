"""Per-architecture smoke tests (reduced configs, CPU).

Every assigned architecture instantiates a same-family reduced variant
(≤ 2–6 layers, d_model ≤ 512, ≤ 4 experts), runs one forward/train step and
asserts output shapes + finiteness; decode is checked for *exact* agreement
with the full forward (prefill → decode == teacher-forced logits).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, INPUT_SHAPES, shape_applicable
from repro.models import (
    cpu_context, decode_step, dummy_batch, forward, init_cache, init_params,
    loss_fn, prefill,
)

CTX = cpu_context(remat=False)


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_loss(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    batch = dummy_batch(key, cfg, 2, 32, "train")
    logits, _, aux = forward(params, batch, cfg=cfg, ctx=CTX, mode="train")
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    total, metrics = loss_fn(params, batch, cfg=cfg, ctx=CTX)
    assert bool(jnp.isfinite(total))
    # random init ⇒ loss near ln(V)
    assert abs(float(metrics["loss"]) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_no_nan(arch, key):
    from repro.training import AdamWConfig, init_opt_state, make_train_step
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    opt = init_opt_state(params)
    step = make_train_step(cfg, CTX, AdamWConfig(lr=1e-3, warmup_steps=1))
    batch = dummy_batch(key, cfg, 2, 32, "train")
    params, opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_matches_forward(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    B, S = 2, 32
    batch = dummy_batch(key, cfg, B, S, "prefill")
    full, _, _ = forward(params, batch, cfg=cfg, ctx=CTX, mode="train")
    pre = {k: (v[:, :S - 1] if k == "tokens"
               else (v[:, :, :S - 1] if k == "positions" else v))
           for k, v in batch.items()}
    cache = init_cache(cfg, B, 64)
    last, cache = prefill(params, pre, cache, cfg=cfg, ctx=CTX)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, S - 2]),
                               rtol=2e-2, atol=2e-2)
    extras = {"audio_frames": batch["audio_frames"]} if cfg.enc_dec else None
    logits, cache = decode_step(params, batch["tokens"][:, S - 1:S], cache,
                                jnp.int32(S - 1), cfg=cfg, ctx=CTX,
                                batch_extras=extras)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, S - 1]),
                               rtol=2e-2, atol=2e-2)


def test_rolling_window_cache_matches_full(key):
    """SWA decode with a rolling cache == full-cache attention + window mask."""
    cfg = get_config("mixtral-8x7b").reduced()  # window=64 in reduced form
    assert cfg.sliding_window == 64
    params = init_params(key, cfg)
    B, S = 1, 96   # prompt shorter than window would not roll; 96 > 64 rolls
    batch = dummy_batch(key, cfg, B, S + 8, "prefill")
    full, _, _ = forward(params, batch, cfg=cfg, ctx=CTX, mode="train")
    pre = {"tokens": batch["tokens"][:, :S]}
    assert S % cfg.sliding_window != 0 or True
    cache = init_cache(cfg, B, 256)
    # prefill length must be a multiple of the window for slot alignment
    pre = {"tokens": batch["tokens"][:, :64]}
    last, cache = prefill(params, pre, cache, cfg=cfg, ctx=CTX)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, 63]),
                               rtol=2e-2, atol=2e-2)
    for t in range(64, 72):
        logits, cache = decode_step(params, batch["tokens"][:, t:t + 1],
                                    cache, jnp.int32(t), cfg=cfg, ctx=CTX)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=3e-2, atol=3e-2)


def test_shape_applicability_matrix():
    rows = 0
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            ok = shape_applicable(cfg, shape)
            if shape.name != "long_500k":
                assert ok, (arch, shape.name)
            rows += 1
    assert rows == 40
    # exactly the five sub-quadratic archs run long_500k
    longs = [a for a in ASSIGNED_ARCHS
             if shape_applicable(get_config(a), INPUT_SHAPES["long_500k"])]
    assert sorted(longs) == sorted([
        "mixtral-8x7b", "mamba2-2.7b", "gemma3-12b", "recurrentgemma-2b",
        "gemma3-27b"])


def test_param_counts_match_published():
    expected = {
        "mixtral-8x7b": 46.7e9, "minicpm3-4b": 4.07e9,
        "deepseek-moe-16b": 16.9e9, "mamba2-2.7b": 2.8e9,
        "qwen2-vl-2b": 1.5e9, "gemma3-12b": 11.8e9,
        "recurrentgemma-2b": 2.7e9, "gemma-2b": 2.5e9,
        "whisper-base": 0.08e9, "gemma3-27b": 27.0e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.12, (arch, got, want)


@pytest.mark.parametrize("arch", ["yi-6b", "llama2-7b", "llama3-8b",
                                  "yi-9b", "llama2-33b"])
def test_paper_deployment_models_forward(arch, key):
    """The paper's own edge/cloud models also instantiate and run."""
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    batch = dummy_batch(key, cfg, 1, 16, "train")
    logits, _, _ = forward(params, batch, cfg=cfg, ctx=CTX, mode="train")
    assert logits.shape == (1, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
