"""Admission control, preemption, and the per-link bandwidth topology.

Covers the PR's invariants: rejected requests consume no server energy and
surface as SLO misses; preemption never oversubscribes a lane (the victim's
lane is free before the preemptor's InferStart) and requeues the victim's
remaining decode tokens; a link's fluctuation trace is invariant to cluster
size (`LinkTopology` substreams — the `BandwidthModel` RNG-coupling fix);
and with everything disabled the degenerate topology reproduces the legacy
runtime bit-for-bit.
"""
import copy
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (
    BandwidthModel, Link, LinkTopology, Simulator, generate_workload,
    make_topology, paper_testbed,
)
from repro.cluster.simulator import _EventSimRuntime
from repro.cluster.workload import classify
from repro.core import (
    Arrival, Decision, SchedulingPolicy, make_policy, make_scenario,
)


# ---------------------------------------------------------------------------
# LinkTopology: structure + the per-link RNG substream fix
# ---------------------------------------------------------------------------


def _one_lane_spec(name="edge0", bandwidth=100e6):
    base = paper_testbed(n_edge=1)[0]
    return dataclasses.replace(base, name=name, bandwidth=bandwidth,
                               max_concurrency=1)


def test_link_trace_invariant_to_cluster_size():
    """The legacy model's shared RNG couples a link's noise to how many
    links exist; LinkTopology substreams do not."""
    def topo(n_links):
        links = [Link(f"l{i}", 1e8, fluctuating=True) for i in range(n_links)]
        return LinkTopology(links, [[lk.name] for lk in links], seed=7)

    small, big = topo(2), topo(6)
    trace_small = [small.factor("l1", k) for k in range(50)]
    trace_big = [big.factor("l1", k) for k in range(50)]
    assert trace_small == trace_big
    # sampling other links first must not perturb the trace either
    mixed = []
    for k in range(50):
        big.factor("l3", k)
        big.factor("l5", k)
        mixed.append(big.factor("l1", k))
    assert mixed == trace_big
    # the legacy model is order-coupled (documented defect, kept for the
    # golden shim): the same draw differs once another draw precedes it
    m1 = BandwidthModel(fluctuating=True, seed=7)
    m2 = BandwidthModel(fluctuating=True, seed=7)
    a = m1.factor(0, 1)
    m2.factor(0, 0)
    b = m2.factor(0, 1)
    assert a != b


def test_degenerate_topology_is_bit_exact_with_default():
    """Passing the explicit degenerate topology == passing none, in both
    event cores (the golden guarantee the rewrite rides on)."""
    specs = paper_testbed("llama2-7b")
    wl = generate_workload(300, seed=0)
    for core in ("array", "reference"):
        results = []
        for explicit in (False, True):
            bw = BandwidthModel(fluctuating=True, seed=1)
            sim = Simulator(
                specs, bw, seed=42, core=core,
                topology=LinkTopology.degenerate(specs, bw)
                if explicit else None)
            results.append(sim.run([copy.copy(s) for s in wl],
                                   make_policy("perllm", len(specs))))
        assert results[0] == results[1]


def test_shared_backhaul_serializes_cloud_transfers():
    """In the edge-cloud topology, cloud-bound transfers traverse
    user-cloud + the shared edge-cloud backhaul; scaling the backhaul to
    near-zero throttles the cloud even though its access link is healthy."""
    specs = paper_testbed()
    cloud = len(specs) - 1

    class PinCloud(SchedulingPolicy):
        name = "pin-cloud"

        def assign(self, req, view):
            return Decision(server=cloud)

    topo = LinkTopology.edge_cloud(specs)
    assert topo.paths[cloud] == ["user-cloud", "edge-cloud"]
    sc = make_scenario("cloud-outage", scale=0.02, start_frac=0.0,
                       stop_frac=1.0)
    for core in ("array", "reference"):
        wl = generate_workload(80, seed=4)
        base = Simulator(specs, seed=3, core=core,
                         topology=LinkTopology.edge_cloud(specs)).run(
            [copy.copy(s) for s in wl], PinCloud())
        degraded = Simulator(specs, seed=3, core=core,
                             topology=LinkTopology.edge_cloud(specs)).run(
            [copy.copy(s) for s in wl], PinCloud(), scenario=sc)
        assert degraded.avg_processing_time > 2 * base.avg_processing_time
    with pytest.raises(KeyError, match="unknown topology"):
        make_topology("mesh", specs)


def test_view_exposes_link_state():
    specs = paper_testbed()
    sim = Simulator(specs, slot=None, seed=0,
                    topology=LinkTopology.edge_cloud(specs))
    seen = {}

    class Peek(SchedulingPolicy):
        name = "peek"

        def assign(self, req, view):
            seen.update(bw=view.link_bw, q=view.link_queue,
                        paths=view.paths, running=view.running)
            return Decision(server=0)

    sim.run([copy.copy(s) for s in generate_workload(5, seed=0)], Peek())
    assert set(seen["bw"]) == {"user-edge0", "user-edge1", "user-edge2",
                               "user-edge3", "user-edge4", "user-cloud",
                               "edge-cloud"}
    assert all(v >= 0 for v in seen["q"].values())
    assert seen["paths"][-1] == ["user-cloud", "edge-cloud"]
    assert isinstance(seen["running"], list)


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------


class RejectAll(SchedulingPolicy):
    name = "reject-all"

    def __init__(self):
        self.feedback_log = []

    def assign(self, req, view):
        return Decision(server=0, admit=False)

    def feedback(self, req, out):
        self.feedback_log.append(out)


def test_rejected_requests_consume_no_server_energy():
    """A shed request never touches a server: zero tx/infer energy, no
    served count, success False, and the rejected Outcome still reaches
    the policy's feedback with the SLO-violation cost."""
    specs = paper_testbed()
    for core in ("array", "reference"):
        policy = RejectAll()
        wl = [copy.copy(s) for s in generate_workload(40, seed=2)]
        res = Simulator(specs, seed=0, core=core).run(wl, policy)
        assert res.n_rejected == 40
        assert res.success_rate == 0.0
        assert res.e_tx == 0.0 and res.e_infer == 0.0
        assert res.per_server_served == [0] * len(specs)
        assert len(policy.feedback_log) == 40
        for req, out in zip(sorted(wl, key=lambda r: r.arrival),
                            policy.feedback_log, strict=True):
            assert out.rejected and not out.success
            assert out.energy == 0.0
            assert out.processing_time == pytest.approx(2.0 * req.deadline)


def test_admission_improves_admitted_slo_under_overload():
    """The acceptance bar: under sustained overload, PerLLM+admission has
    strictly higher admitted-request SLO satisfaction than always-admit
    PerLLM (which degrades everyone uniformly)."""
    specs = paper_testbed("llama2-7b")
    wl = generate_workload(1200, rate=10.0, seed=0, scenario="overload")
    runs = {}
    for admission in (False, True):
        sim = Simulator(specs, BandwidthModel(seed=1), seed=42)
        runs[admission] = sim.run(
            [copy.copy(s) for s in wl],
            make_policy("perllm", len(specs), admission=admission))
    always = runs[False]
    gated = runs[True]
    assert always.n_rejected == 0
    assert gated.n_rejected > 0
    # admitted-SLO strictly better, and better than always-admit's overall
    assert gated.admitted_success_rate > always.admitted_success_rate
    assert gated.success_rate > always.success_rate


def test_rejection_does_not_poison_perllm_estimators():
    policy = make_policy("perllm", 2, admission=True)
    ratio_before = policy.infer_ratio.copy()
    req = copy.copy(generate_workload(1, seed=0)[0])
    req.class_id = classify(req)
    from repro.cluster.simulator import Outcome
    out = Outcome(server=1, tx_time=0.0, queue_time=0.0, infer_time=0.0,
                  finish=0.0, processing_time=2 * req.deadline,
                  success=False, energy=0.0, rejected=True)
    policy.feedback(req, out)
    assert np.array_equal(policy.infer_ratio, ratio_before)
    assert policy.ratio_count.sum() == 0


# ---------------------------------------------------------------------------
# Preemption
# ---------------------------------------------------------------------------


class PreemptFor(SchedulingPolicy):
    """Pins everything to server 0; the request with sid == `preemptor`
    preempts whatever is running there (the runtime decides legality)."""

    name = "preempt-for"

    def __init__(self, preemptor_sid):
        self.preemptor_sid = preemptor_sid

    def assign(self, req, view):
        victim = None
        if req.sid == self.preemptor_sid and view.running:
            tasks = view.running[0]
            if tasks:
                victim = tasks[0].sid
        return Decision(server=0, preempt_victim=victim)


class _RecordingRuntime(_EventSimRuntime):
    """Captures every booking and preemption for invariant checks."""

    def __init__(self, sim, policy):
        super().__init__(sim, policy)
        self.bookings = []
        self.preempts = []        # (time, victim booking)

    def dispatch(self, t, req, decision):
        super().dispatch(t, req, decision)
        self.bookings.append(self._inflight[req.sid])

    def on_preempt(self, ev):
        victim = self._inflight.get(ev.victim)
        super().on_preempt(ev)
        if victim is not None and victim.cancelled:
            self.preempts.append((ev.time, victim))


def _run_preemption(t_victim, t_preemptor):
    """One-lane server; a long-decode victim and a later preemptor."""
    spec = _one_lane_spec()
    sim = Simulator([spec], slot=None, seed=0)
    a, b = [copy.copy(s) for s in generate_workload(2, seed=0)]
    a.arrival, b.arrival = float(t_victim), float(t_victim + t_preemptor)
    a.prompt_tokens, a.output_tokens = 1024, 96     # long-running victim
    b.prompt_tokens, b.output_tokens = 64, 8
    a.payload_bytes = b.payload_bytes = 1e6
    for r in (a, b):
        r.class_id = classify(r)
        r.preemptions = 0
    rt = _RecordingRuntime(sim, PreemptFor(b.sid))
    rt.loop.push(Arrival(a.arrival, requests=(a,)))
    rt.loop.push(Arrival(b.arrival, requests=(b,)))
    rt.drain()
    return rt, a, b


@given(st.floats(0.0, 2.0), st.floats(0.05, 10.0))
@settings(max_examples=25, deadline=None)
def test_preempted_lane_free_before_preemptors_infer_start(t_victim,
                                                           t_preemptor):
    """Lanes are never oversubscribed under preemption: on a one-lane
    server, the effective busy intervals of all bookings are disjoint, and
    the victim's lane is returned no later than the preemptor's
    InferStart."""
    rt, a, b = _run_preemption(t_victim, t_preemptor)
    assert rt.n_preempted == len(rt.preempts)
    # every booking's effective interval: truncated at preemption time
    intervals = []
    preempt_at = {id(v): t for t, v in rt.preempts}
    for bk in rt.bookings:
        end = preempt_at.get(id(bk), bk.finish) if bk.cancelled else bk.finish
        start = bk.begin
        if end > start:
            intervals.append((start, end))
    intervals.sort()
    for (_s1, e1), (s2, _e2) in zip(intervals, intervals[1:], strict=False):
        assert e1 <= s2 + 1e-9, f"lane oversubscribed: {intervals}"
    # the preemptor's own booking starts at/after the preemption instant
    for t, _victim in rt.preempts:
        preemptor_bookings = [bk for bk in rt.bookings
                              if bk.request.sid == b.sid]
        assert preemptor_bookings
        assert all(bk.begin >= t - 1e-9 for bk in preemptor_bookings)
    # both requests eventually complete exactly once each
    assert len(rt.outcomes) == 2
    assert {o.server for o in rt.outcomes} == {0}


def test_preemption_requeues_remaining_tokens():
    rt, a, b = _run_preemption(0.0, 1.0)
    assert rt.n_preempted == 1
    assert a.preemptions == 1
    assert 0 < a.output_tokens <= 96      # remaining decode tokens only
    assert a.finish > 0 and b.finish > 0
    # the victim's final outcome spans its whole life (SLO unchanged)
    victim_out = [o for o in rt.outcomes if o.finish == a.finish][0]
    assert victim_out.processing_time == pytest.approx(a.finish - a.arrival)


def test_slotted_construction_rejected():
    """Slotted mode is retired: a numeric `slot=` fails at construction
    with a migration-pointing error, so a policy that relies on event
    semantics (e.g. preemption) can never land in a quantized runtime."""
    spec = _one_lane_spec()
    with pytest.raises(ValueError, match="slotted mode was removed"):
        Simulator([spec], slot=0.5, seed=0)


def test_live_server_preempts_engine_slot():
    """PerLLMServer preemption: the victim is evicted from its engine slot
    (ServingEngine.evict) and requeued with its remaining tokens; both
    requests still complete."""
    pytest.importorskip("jax")
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingEngine
    from repro.serving.perllm_server import PerLLMServer

    class PreemptLatest(SchedulingPolicy):
        name = "preempt-latest"

        def __init__(self):
            self.armed = False

        def assign(self, req, view):
            victim = None
            if self.armed and view.running and view.running[0]:
                victim = view.running[0][0].sid
            return Decision(server=0, preempt_victim=victim)

    cfg = get_config("gemma-2b").reduced(n_layers=1, d_model=32,
                                         vocab_size=128)
    specs = [_one_lane_spec()]
    engines = [ServingEngine(cfg, init_params(jax.random.key(0), cfg),
                             max_batch=1, max_seq=64)]
    policy = PreemptLatest()
    srv = PerLLMServer(specs, engines, scheduler=policy)
    first = srv.submit([1, 2, 3], max_new_tokens=12, payload_bytes=1e4)
    for _ in range(60):
        if srv.engines[0].active_slots:
            break
        srv.step()
    assert srv.engines[0].active_slots
    policy.armed = True
    second = srv.submit([4, 5], max_new_tokens=2, payload_bytes=1e4)
    done = srv.run_until_idle()
    assert srv.n_preempted == 1
    assert first.service.preemptions == 1
    assert first.service.output_tokens < 12        # only the remainder
    assert {sr.service.sid for sr in done} \
        == {first.service.sid, second.service.sid}
    assert not srv.rejected


def test_live_server_rejects_cleanly():
    pytest.importorskip("jax")
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingEngine
    from repro.serving.perllm_server import PerLLMServer

    cfg = get_config("gemma-2b").reduced(n_layers=1, d_model=32,
                                         vocab_size=128)
    specs = [_one_lane_spec()]
    engines = [ServingEngine(cfg, init_params(jax.random.key(0), cfg),
                             max_batch=1, max_seq=64)]
    policy = RejectAll()
    srv = PerLLMServer(specs, engines, scheduler=policy)
    srv.submit([1, 2, 3], max_new_tokens=4)
    done = srv.run_until_idle()
    assert done == []
    assert len(srv.rejected) == 1
    assert srv.stats["rejected"] == 1
    (out,) = policy.feedback_log
    assert out.rejected and out.energy == 0.0


def test_perllm_preempt_only_targets_doomed_tasks():
    """PerLLM's victim search only fires when the candidate is already
    missing its own deadline; a healthy cluster never preempts."""
    specs = paper_testbed("llama2-7b")
    wl = generate_workload(400, rate=8.0, seed=0)
    sim = Simulator(specs, slot=None, seed=42)
    res = sim.run([copy.copy(s) for s in wl],
                  make_policy("perllm", len(specs), admission=True,
                              preempt=True))
    assert res.n_preempted == 0      # nothing doomed at this load
    assert res.success_rate > 0.9
