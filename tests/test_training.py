"""Training substrate: optimizer math, loop convergence, checkpoints, data."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.training import (
    AdamWConfig, DataConfig, SyntheticLM, adamw_update, init_opt_state,
    load_checkpoint, save_checkpoint, train,
)


def test_adamw_matches_reference_step():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=1,
                      min_lr_ratio=1.0)
    params = {"w": jnp.array([[1.0, 2.0]], jnp.float32)}
    grads = {"w": jnp.array([[0.1, -0.2]], jnp.float32)}
    state = init_opt_state(params)
    new, state, metrics = adamw_update(cfg, params, grads, state)
    # manual adam step 1: m=0.1g_hat... mhat=g, vhat=g², delta=g/|g| = sign
    expect = np.array([[1.0, 2.0]]) - 1e-2 * np.sign([[0.1, -0.2]])
    np.testing.assert_allclose(np.asarray(new["w"]), expect, rtol=1e-4)


def test_grad_clip_applies():
    cfg = AdamWConfig(lr=1e-2, grad_clip=0.5, warmup_steps=0)
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0)}
    state = init_opt_state(params)
    _, _, metrics = adamw_update(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) == 200.0  # reported pre-clip


def test_training_loss_decreases():
    cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=128,
                                         vocab_size=512)
    _, _, hist = train(cfg, steps=40, batch_size=4, seq_len=64,
                       log_every=10,
                       opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5,
                                           total_steps=40))
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=64,
                                         vocab_size=128)
    params = init_params(jax.random.key(0), cfg)
    save_checkpoint(str(tmp_path / "ck"), params, extra={"step": 7})
    like = jax.tree.map(jnp.zeros_like, params)
    restored, extra = load_checkpoint(str(tmp_path / "ck"), like)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_synthetic_data_deterministic_and_learnable():
    c = DataConfig(vocab_size=128, seq_len=32, batch_size=2, seed=11)
    b1 = next(SyntheticLM(c).batches())
    b2 = next(SyntheticLM(c).batches())
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 32)
    # bigram structure: successor followed ~half the time
    data = SyntheticLM(c)
    toks = np.concatenate([next(data.batches())["tokens"].ravel()
                           for _ in range(20)])
    succ = data.successor[toks[:-1]]
    frac = np.mean(succ == toks[1:])
    assert 0.3 < frac < 0.7


def test_microbatch_accumulation_matches_full_batch():
    """mb=2 gradient accumulation == single full-batch step (same math)."""
    import jax
    import jax.numpy as jnp
    from repro.models import cpu_context, dummy_batch
    from repro.training import make_train_step

    cfg = get_config("gemma-2b").reduced(n_layers=2, d_model=64,
                                         vocab_size=128)
    ctx = cpu_context(remat=False)
    params = init_params(jax.random.key(0), cfg)
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, grad_clip=1e9)
    batch = dummy_batch(jax.random.key(1), cfg, 4, 16, "train")

    p1, _, m1 = make_train_step(cfg, ctx, ocfg)(params, opt, batch)
    p2, _, m2 = make_train_step(cfg, ctx, ocfg, microbatches=2)(
        params, init_opt_state(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2), strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3)
