"""Tests for tools/repro_check: per-rule fixtures (flagging / clean /
suppressed), the PR 6 regression fixture, and the repo self-check."""
import subprocess
import sys
import textwrap
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT))

from tools.repro_check import default_config, run_paths  # noqa: E402


def run_on(tmp_path, files, rules=None, config=None):
    """Write {relpath: code} under tmp_path and run the checker on it."""
    for rel, code in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(code))
    return run_paths([str(tmp_path)], rule_ids=rules, config=config,
                     root=tmp_path)


# ---------------------------------------------------------------------------
# R1 — ledger conservation
# ---------------------------------------------------------------------------

R1_FLAGGING = """
    class RT:
        def requeue(self, req, t):
            self.n_kv_orphaned += 1
            req.kv_server, req.kv_blocks = -1, 0
"""

R1_CLEAN = """
    class RT:
        def requeue(self, req, t):
            self.n_kv_orphaned += 1
            self._prefix_unpin(req, t)
            self._kv_free(req.kv_server, req.kv_blocks, t)
            req.kv_server, req.kv_blocks = -1, 0
"""

R1_SUPPRESSED = """
    class RT:
        def requeue(self, req, t):
            self.n_kv_orphaned += 1
            # repro-check: orphan(kv_used)
            req.kv_server, req.kv_blocks = -1, 0
"""


def test_r1_flags_reset_without_release(tmp_path):
    fs = run_on(tmp_path, {"cluster/simulator.py": R1_FLAGGING}, ["R1"])
    assert len(fs) == 1 and fs[0].rule == "R1"
    assert "kv_used" in fs[0].message


def test_r1_clean_on_release_before_reset(tmp_path):
    assert run_on(tmp_path, {"cluster/simulator.py": R1_CLEAN},
                  ["R1"]) == []


def test_r1_orphan_annotation_suppresses(tmp_path):
    assert run_on(tmp_path, {"cluster/simulator.py": R1_SUPPRESSED},
                  ["R1"]) == []


def test_r1b_flags_missing_prefix_unpin(tmp_path):
    code = """
        class RT:
            def drop(self, req, b, t):
                self._kv_free(b.j, req.kv_blocks, t)
                req.kv_server, req.kv_blocks = -1, 0
    """
    fs = run_on(tmp_path, {"cluster/simulator.py": code}, ["R1"])
    assert len(fs) == 1 and "prefix_pin" in fs[0].message


def test_r1_handoff_return_is_not_a_leak(tmp_path):
    # _resolve_eviction shape: reset then hand the claimed object off
    code = """
        class RT:
            def resolve(self, sr, j):
                old_j, old_req = sr.evicted
                sr.service.kv_server = -1
                sr.service.kv_blocks = 0
                if old_j == j:
                    return old_req
                self.engines[old_j].release(old_req)
                return None
    """
    assert run_on(tmp_path, {"serving/perllm_server.py": code},
                  ["R1"]) == []


def test_r1c_flags_leaked_refcount_charge(tmp_path):
    code = """
        class Cache:
            def grab(self, shared):
                self.allocator.ref(shared)
                return None
    """
    fs = run_on(tmp_path, {"serving/kvcache.py": code}, ["R1"])
    assert len(fs) == 1 and "refcount" in fs[0].message


def test_r1c_none_guard_idiom_is_clean(tmp_path):
    # PagedKVCache.allocate shape: correlated `if shared:` branches and
    # an `ids is None` failure guard that releases the pinned share
    code = """
        class Cache:
            def allocate(self, n, prompt=None):
                shared = self.match_prefix(prompt)
                if shared:
                    self.allocator.ref(shared)
                ids = self._allocate_fresh(n - len(shared))
                if ids is None:
                    if shared:
                        self.allocator.free(shared)
                    return None
                return self.table(shared + ids)
    """
    assert run_on(tmp_path, {"serving/kvcache.py": code}, ["R1"]) == []


def test_r1d_link_booking_outside_path_loop(tmp_path):
    code = """
        class RT:
            def book_one(self, lk, end):
                self.link_free[lk] = end

            def book_path(self, path, end):
                for name in path:
                    self.link_free[name] = end
    """
    fs = run_on(tmp_path, {"cluster/network.py": code}, ["R1"])
    assert len(fs) == 1 and "link_free" in fs[0].message
    assert fs[0].line == 4


def test_r1d_vectorized_and_single_link_bookings_are_clean(tmp_path):
    """The array-backed fast path's booking forms: a vectorized
    whole-path index, `np.add.at` over path indices, and the guarded
    single-link shortcut are all complete-path bookings."""
    code = """
        import numpy as np

        class RT:
            def book_vectorized(self, path_idx, end):
                self.link_free[path_idx] = end

            def book_add_at(self, path_idx, dur):
                np.add.at(self.link_free, path_idx, dur)

            def book_fast(self, j, end):
                name = self._single_link[j]
                if name is not None:
                    self.link_free[name] = end
                else:
                    for lk in self.topo.paths[j]:
                        self.link_free[lk] = end
    """
    assert run_on(tmp_path, {"cluster/network.py": code}, ["R1"]) == []


def test_r1d_unguarded_single_link_and_scalar_add_at_flagged(tmp_path):
    """The shortcut without the `is not None` guard (the name may not be
    a whole path) and an `np.add.at` over a scalar link index are still
    partial bookings."""
    code = """
        import numpy as np

        class RT:
            def book_unguarded(self, j, end):
                name = self._single_link[j]
                self.link_free[name] = end

            def book_one_link(self, lk, dur):
                np.add.at(self.link_free, lk, dur)
    """
    fs = run_on(tmp_path, {"cluster/network.py": code}, ["R1"])
    assert len(fs) == 2
    assert any("np.add.at" in f.message for f in fs)


def test_r1_disable_comment_suppresses(tmp_path):
    code = """
        class RT:
            def book_one(self, lk, end):
                self.link_free[lk] = end  # repro-check: disable=R1
    """
    assert run_on(tmp_path, {"cluster/network.py": code}, ["R1"]) == []


# ---------------------------------------------------------------------------
# R2 — event-handler exhaustiveness
# ---------------------------------------------------------------------------

def r2_config(exemptions=None):
    cfg = default_config()
    cfg["r2"].update({
        "events_file": "core/runtime.py",
        "runtimes": ["MyRT"],
        "exemptions": exemptions or {},
    })
    return cfg


R2_EVENTS_FLAGGING = """
    class Event:
        pass

    class Ping(Event):
        pass

    class Pong(Event):
        pass

    class Runtime:
        def on_ping(self, ev):
            pass

        _HANDLERS = {Ping: "on_ping"}
"""


def test_r2_flags_unrouted_event_and_pass_stub(tmp_path):
    files = {
        "core/runtime.py": R2_EVENTS_FLAGGING,
        "cluster/simulator.py": """
            from core.runtime import Runtime

            class MyRT(Runtime):
                pass
        """,
    }
    fs = run_on(tmp_path, files, ["R2"], config=r2_config())
    msgs = [f.message for f in fs]
    assert any("Pong" in m and "no entry" in m for m in msgs)
    assert any("silent `pass` stub" in m for m in msgs)


def test_r2_clean_with_real_handler(tmp_path):
    files = {
        "core/runtime.py": """
            class Event:
                pass

            class Ping(Event):
                pass

            class Runtime:
                def on_ping(self, ev):
                    pass

                _HANDLERS = {Ping: "on_ping"}
        """,
        "cluster/simulator.py": """
            class MyRT(Runtime):
                def on_ping(self, ev):
                    self.count += 1
        """,
    }
    assert run_on(tmp_path, files, ["R2"], config=r2_config()) == []


def test_r2_exemption_and_suppression(tmp_path):
    files = {
        "core/runtime.py": """
            class Event:
                pass

            class Ping(Event):
                pass

            class Pong(Event):  # repro-check: disable=R2
                pass

            class Runtime:
                def on_ping(self, ev):
                    pass

                _HANDLERS = {Ping: "on_ping"}
        """,
        "cluster/simulator.py": """
            class MyRT(Runtime):
                pass
        """,
    }
    cfg = r2_config(exemptions={"MyRT": {"on_ping": "never pushed"}})
    assert run_on(tmp_path, files, ["R2"], config=cfg) == []


# ---------------------------------------------------------------------------
# R3 — field coverage
# ---------------------------------------------------------------------------

def r3_config(guards=None):
    cfg = default_config()
    cfg["r3"].update({
        "decision_classes": ["Decision"],
        "decision_guards": guards or {},
        "reader_groups": {
            "sim": ["core/api.py", "cluster/simulator.py"],
            "server": ["core/api.py", "serving/perllm_server.py"],
        },
    })
    return cfg


R3_API = """
    import dataclasses

    @dataclasses.dataclass
    class Decision:
        server: int = -1
        infer_scale: float = 1.0
"""


def test_r3_flags_field_unread_by_one_runtime(tmp_path):
    files = {
        "core/api.py": R3_API,
        "cluster/simulator.py": "def f(d):\n    return d.server, d.infer_scale\n",
        "serving/perllm_server.py": "def g(d):\n    return d.server\n",
    }
    fs = run_on(tmp_path, files, ["R3"], config=r3_config())
    assert len(fs) == 1
    assert "infer_scale" in fs[0].message and "server" in fs[0].message


def test_r3_clean_when_both_read_or_guarded(tmp_path):
    files = {
        "core/api.py": R3_API,
        "cluster/simulator.py": "def f(d):\n    return d.server, d.infer_scale\n",
        "serving/perllm_server.py": "def g(d):\n    return d.server\n",
    }
    cfg = r3_config(guards={"infer_scale": "sim-only physics knob"})
    assert run_on(tmp_path, files, ["R3"], config=cfg) == []


def test_r3_disable_comment_suppresses(tmp_path):
    api = """
        import dataclasses

        @dataclasses.dataclass
        class Decision:
            server: int = -1
            infer_scale: float = 1.0  # repro-check: disable=R3
    """
    files = {
        "core/api.py": api,
        "cluster/simulator.py": "def f(d):\n    return d.server\n",
        "serving/perllm_server.py": "def g(d):\n    return d.server\n",
    }
    fs = run_on(tmp_path, files, ["R3"], config=r3_config())
    assert fs == []


def test_r3_flags_dead_simresult_counter(tmp_path):
    cfg = default_config()
    cfg["r3"]["result_file"] = "cluster/simulator.py"
    files = {
        "cluster/simulator.py": """
            import dataclasses

            @dataclasses.dataclass
            class SimResult:
                n_done: int = 0
                n_ghost: int = 0

            def finish():
                return SimResult(n_done=3)
        """,
    }
    fs = run_on(tmp_path, files, ["R3"], config=cfg)
    assert len(fs) == 1 and "n_ghost" in fs[0].message


# ---------------------------------------------------------------------------
# R4 — determinism discipline
# ---------------------------------------------------------------------------

R4_FLAGGING = """
    import time
    import numpy as np

    def jitter():
        t0 = time.time()
        noise = np.random.rand()
        for v in {1, 2, 3}:
            t0 += v
        return t0 + noise
"""


def test_r4_flags_wallclock_global_rng_set_iteration(tmp_path):
    fs = run_on(tmp_path, {"repro/cluster/jitter.py": R4_FLAGGING}, ["R4"])
    kinds = " ".join(f.message for f in fs)
    assert len(fs) == 3
    assert "time.time" in kinds and "np.random.rand" in kinds \
        and "unordered set" in kinds


def test_r4_clean_with_seeded_rng(tmp_path):
    code = """
        import numpy as np

        def jitter(seed):
            rng = np.random.default_rng(seed)
            return sum(sorted({1, 2, 3})) + rng.uniform()
    """
    assert run_on(tmp_path, {"repro/cluster/jitter.py": code}, ["R4"]) == []


def test_r4_unseeded_generator_flagged_seeded_clean(tmp_path):
    """`default_rng()` / `PCG64()` with no seed pull OS entropy; with an
    explicit seed (or spawned substreams) the Generator idiom is fine."""
    bad = """
        import numpy as np

        def jitter():
            rng = np.random.default_rng()
            gen = np.random.Generator(np.random.PCG64())
            return rng.uniform() + gen.uniform()
    """
    fs = run_on(tmp_path, {"repro/cluster/jitter.py": bad}, ["R4"])
    assert len(fs) == 2
    assert all("unseeded" in f.message for f in fs)

    good = """
        import numpy as np

        def jitter(seed):
            rng = np.random.default_rng(seed)
            gen = np.random.Generator(np.random.PCG64(seed + 1))
            sub = rng.spawn(1)[0]
            return rng.uniform() + gen.uniform() + sub.uniform()
    """
    assert run_on(tmp_path, {"repro/cluster/jitter.py": good}, ["R4"]) == []


def test_r4_engine_exempt_and_suppression(tmp_path):
    files = {
        # engine is exempt by config: live serving may read the clock
        "repro/serving/engine.py": "import time\nt = time.time()\n",
        "repro/core/x.py":
            "import time\nt = time.time()  # repro-check: disable=R4\n",
    }
    assert run_on(tmp_path, files, ["R4"]) == []


# ---------------------------------------------------------------------------
# R5 — unit-suffix arithmetic
# ---------------------------------------------------------------------------

def test_r5_flags_conflicting_suffixes(tmp_path):
    code = "def f(wait_s, prompt_tokens):\n    return wait_s + prompt_tokens\n"
    fs = run_on(tmp_path, {"a.py": code}, ["R5"])
    assert len(fs) == 1 and "_s" in fs[0].message \
        and "_tokens" in fs[0].message


def test_r5_clean_on_matching_units(tmp_path):
    code = ("def f(end_s, start_s, n_blocks, k_blocks):\n"
            "    return (end_s - start_s) + (n_blocks - k_blocks)\n")
    assert run_on(tmp_path, {"a.py": code}, ["R5"]) == []


def test_r5_disable_comment_suppresses(tmp_path):
    code = ("def f(wait_s, prompt_tokens):\n"
            "    return wait_s + prompt_tokens  # repro-check: disable=R5\n")
    assert run_on(tmp_path, {"a.py": code}, ["R5"]) == []


# ---------------------------------------------------------------------------
# R6 — trace-emission coverage
# ---------------------------------------------------------------------------

def r6_config(exemptions=None):
    cfg = default_config()
    cfg["r2"].update({"runtimes": ["MyRT"], "exemptions": {}})
    cfg["r6"].update({"runtimes": ["MyRT"],
                      "exemptions": exemptions or {}})
    return cfg


R6_EVENTS = """
    class Event:
        pass

    class Ping(Event):
        pass

    class Runtime:
        def on_ping(self, ev):
            pass

        _HANDLERS = {Ping: "on_ping"}
"""


def test_r6_flags_handler_without_emission(tmp_path):
    files = {
        "core/runtime.py": R6_EVENTS,
        "cluster/simulator.py": """
            class MyRT(Runtime):
                def on_ping(self, ev):
                    self.count += 1
        """,
    }
    fs = run_on(tmp_path, files, ["R6"], config=r6_config())
    assert len(fs) == 1 and fs[0].rule == "R6"
    assert "on_ping" in fs[0].message and "trace" in fs[0].message


def test_r6_direct_and_helper_emissions_are_clean(tmp_path):
    files = {
        "core/runtime.py": R6_EVENTS,
        "cluster/simulator.py": """
            class MyRT(Runtime):
                def on_ping(self, ev):
                    if self.trace is not None:
                        self.trace.append(0, ev.sid, ev.time, ev.time)

            class HelperRT(Runtime):
                def on_ping(self, ev):
                    self._handle(ev)

                def _handle(self, ev):
                    self._trace_mark(ev)
        """,
    }
    cfg = r6_config()
    assert run_on(tmp_path, files, ["R6"], config=cfg) == []
    cfg["r6"]["runtimes"] = ["HelperRT"]
    assert run_on(tmp_path, files, ["R6"], config=cfg) == []


def test_r6_super_call_reaches_base_emission(tmp_path):
    files = {
        "core/runtime.py": R6_EVENTS,
        "cluster/simulator.py": """
            class Base(Runtime):
                def on_ping(self, ev):
                    self.trace.append(0, ev.sid, ev.time, ev.time)

            class MyRT(Base):
                def on_ping(self, ev):
                    self.cleanup(ev)
                    super().on_ping(ev)
        """,
    }
    assert run_on(tmp_path, files, ["R6"], config=r6_config()) == []


def test_r6_exemption_and_pass_stub_skipped(tmp_path):
    files = {
        "core/runtime.py": R6_EVENTS,
        "cluster/simulator.py": """
            class MyRT(Runtime):
                def on_ping(self, ev):
                    self.count += 1
        """,
    }
    cfg = r6_config(exemptions={"MyRT": {"on_ping": "not a lifecycle "
                                                    "event"}})
    assert run_on(tmp_path, files, ["R6"], config=cfg) == []
    # a pass-stub handler (R2's domain) is not an R6 finding
    stub = {
        "core/runtime.py": R6_EVENTS,
        "cluster/simulator.py": """
            class MyRT(Runtime):
                pass
        """,
    }
    assert run_on(tmp_path, stub, ["R6"], config=r6_config()) == []


# ---------------------------------------------------------------------------
# regression fixture (PR 6 bug shape) + repo self-check
# ---------------------------------------------------------------------------

def test_pr6_regression_fixture_is_caught():
    """The committed pre-fix shape of the PR 6 orphaned-pages bug must
    keep tripping R1 — both the silent-reset and the missing-unpin
    halves — plus the first-hop-only link booking (the shape the
    vectorized fast path must never regress into), and the CLI must
    exit non-zero on it."""
    fixture = REPO_ROOT / "tests" / "fixtures" / "repro_check"
    fs = run_paths([str(fixture)], rule_ids=["R1"], root=REPO_ROOT)
    assert len(fs) == 3
    assert any("kv_used" in f.message and "dispatch" in f.message
               for f in fs)
    assert any("prefix_pin" in f.message for f in fs)
    assert any("link_free" in f.message for f in fs)
    proc = subprocess.run(
        [sys.executable, "-m", "tools.repro_check",
         "tests/fixtures/repro_check"],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert proc.returncode == 1, proc.stdout + proc.stderr


def test_repo_tree_is_clean():
    """`python -m tools.repro_check src/` exits 0 on the repo (the CI
    contract: every invariant holds or is explicitly annotated)."""
    proc = subprocess.run(
        [sys.executable, "-m", "tools.repro_check", "src"],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ---------------------------------------------------------------------------
# R7 — jit tracing-safety
# ---------------------------------------------------------------------------

R7_FLAGGING = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x, lim):
        if x > lim:
            return x
        while x.sum() > 0:
            x = x - 1
        n = int(jnp.sum(x))
        return x.item() + n
"""

R7_CLEAN = """
    import functools
    import jax
    import jax.numpy as jnp

    @functools.partial(jax.jit, static_argnames=("causal",))
    def f(x, causal):
        if causal:                       # static arg: legal Python branch
            x = x * 2
        if x.shape[0] > 4:               # shapes are static at trace time
            x = x[:4]
        for _ in range(x.ndim):          # static iteration count
            x = x + 1
        return jnp.where(x > 0, x, 0.0)
"""

R7_SUPPRESSED = """
    import jax

    @jax.jit
    def f(x, lim):
        if x > lim:                      # repro-check: disable=R7
            return x
        return x * 2
"""


def test_r7_flags_traced_control_flow_and_host_sync(tmp_path):
    fs = run_on(tmp_path, {"kernels/hot.py": R7_FLAGGING}, ["R7"])
    msgs = [f.message for f in fs]
    assert len(fs) == 4
    assert any("`if`" in m for m in msgs)
    assert any("`while`" in m for m in msgs)
    assert any("`int()`" in m for m in msgs)
    assert any(".item()" in m for m in msgs)


def test_r7_static_args_and_shape_reads_are_clean(tmp_path):
    assert run_on(tmp_path, {"kernels/hot.py": R7_CLEAN}, ["R7"]) == []


def test_r7_kernel_refs_are_traced_but_partial_kwargs_static(tmp_path):
    code = """
        import functools
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _k(x_ref, o_ref, *, flip):
            if flip:                          # static: bound via partial
                o_ref[...] = -x_ref[...]
            if x_ref[0] > 0:                  # traced ref read: flagged
                o_ref[...] = x_ref[...]

        def run(x, flip):
            return pl.pallas_call(
                functools.partial(_k, flip=flip),
                grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
                out_shape=None,
                interpret=True,
            )(x)
    """
    fs = run_on(tmp_path, {"kernels/k.py": code}, ["R7"])
    assert len(fs) == 1
    assert "Pallas kernel" in fs[0].message and "pl.when" in fs[0].message


def test_r7_nonhashable_static_default_flagged(tmp_path):
    code = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("opts",))
        def f(x, opts=[1, 2]):
            return x
    """
    fs = run_on(tmp_path, {"kernels/cfg.py": code}, ["R7"])
    assert len(fs) == 1 and "non-hashable" in fs[0].message


def test_r7_disable_comment_suppresses(tmp_path):
    assert run_on(tmp_path, {"kernels/hot.py": R7_SUPPRESSED},
                  ["R7"]) == []


def test_r7_out_of_scope_file_ignored(tmp_path):
    assert run_on(tmp_path, {"training/loop.py": R7_FLAGGING},
                  ["R7"]) == []


# ---------------------------------------------------------------------------
# R8 — recompilation hazards
# ---------------------------------------------------------------------------

R8_FLAGGING = """
    import jax
    import jax.numpy as jnp

    class Engine:
        def __init__(self):
            self._fwd = jax.jit(lambda p, t: t)
            self.queue = []

        def step(self):
            req = self.queue.pop(0)
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            return self._fwd(self.params, toks)
"""

R8_CLEAN = """
    import jax
    import jax.numpy as jnp

    class Engine:
        def __init__(self):
            self._fwd = jax.jit(lambda p, t: t)
            self.queue = []
            self.cur_tokens = [0] * 8

        def step(self):
            req = self.queue.pop(0)
            tok = jnp.asarray([[req.prompt[0]]], jnp.int32)  # literal shape
            fixed = jnp.asarray(self.cur_tokens, jnp.int32)[:, None]
            self._fwd(self.params, tok)
            return self._fwd(self.params, fixed)
"""

R8_SUPPRESSED = """
    import jax
    import jax.numpy as jnp

    class Engine:
        def __init__(self):
            self._fwd = jax.jit(lambda p, t: t)
            self.queue = []

        def step(self):
            req = self.queue.pop(0)
            toks = jnp.asarray(req.prompt, jnp.int32)
            return self._fwd(self.params, toks)  # repro-check: disable=R8
"""


def test_r8_flags_per_request_shape_into_jit(tmp_path):
    fs = run_on(tmp_path, {"serving/eng.py": R8_FLAGGING}, ["R8"])
    assert len(fs) == 1
    assert "self._fwd" in fs[0].message
    assert "recompile" in fs[0].message


def test_r8_literal_and_fixed_shapes_are_clean(tmp_path):
    assert run_on(tmp_path, {"serving/eng.py": R8_CLEAN}, ["R8"]) == []


def test_r8_bucketing_through_padding_still_flagged_then_suppressed(
        tmp_path):
    assert run_on(tmp_path, {"serving/eng.py": R8_SUPPRESSED},
                  ["R8"]) == []


def test_r8_kwargs_splat_into_jit_flagged(tmp_path):
    code = """
        import jax

        class Engine:
            def __init__(self):
                self._fwd = jax.jit(lambda **kw: kw)

            def step(self, batch):
                return self._fwd(**batch)
    """
    fs = run_on(tmp_path, {"serving/eng.py": code}, ["R8"])
    assert len(fs) == 1 and "splat" in fs[0].message


def test_r8_jitted_lambda_closure_capture_flagged(tmp_path):
    code = """
        import jax
        import jax.numpy as jnp

        class Engine:
            def __init__(self, n):
                table = jnp.arange(n)
                self._fwd = jax.jit(lambda t: t + table)

            def step(self, t):
                return self._fwd(t)
    """
    fs = run_on(tmp_path, {"serving/eng.py": code}, ["R8"])
    assert len(fs) == 1 and "closes over array `table`" in fs[0].message


def test_r8_unreached_private_method_not_walked(tmp_path):
    code = """
        import jax
        import jax.numpy as jnp

        class Engine:
            def __init__(self):
                self._fwd = jax.jit(lambda p, t: t)

            def _debug_only(self, req):
                return self._fwd(None, jnp.asarray(req.prompt))

            def step(self):
                return 0
    """
    assert run_on(tmp_path, {"serving/eng.py": code}, ["R8"]) == []


# ---------------------------------------------------------------------------
# R9 — Pallas kernel consistency
# ---------------------------------------------------------------------------

R9_CLEAN = """
    import functools
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def _k(s_ref, x_ref, o_ref, acc, *, blk):
        o_ref[...] = x_ref[...] * 2.0

    def run(x, interpret):
        m, n = x.shape
        grid = (m // 8, n // 128)
        kernel = functools.partial(_k, blk=8)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec((8, 128), lambda i, j: (i, j)),
            ],
            out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
            scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32)],
            interpret=interpret,
        )(s, x)
"""


def test_r9_consistent_call_is_clean(tmp_path):
    assert run_on(tmp_path, {"kernels/good.py": R9_CLEAN}, ["R9"]) == []


def test_r9_flags_arity_rank_operand_and_interpret(tmp_path):
    code = """
        import jax
        from jax.experimental import pallas as pl

        def _k(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x):
            m, n = x.shape
            return pl.pallas_call(
                _k,
                grid=(m // 8,),
                in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0, 0)),
                out_shape=jax.ShapeDtypeStruct((m, n, 1), x.dtype),
            )(x, x)
    """
    fs = run_on(tmp_path, {"kernels/bad.py": code}, ["R9"])
    msgs = [f.message for f in fs]
    assert len(fs) == 5
    assert any("interpret" in m for m in msgs)
    assert any("takes 2 args" in m for m in msgs)
    assert any("returns 3 coordinates" in m for m in msgs)
    assert any("rank 2" in m and "rank 3" in m for m in msgs)
    assert any("2 operands" in m for m in msgs)


def test_r9_kernel_arity_vs_wired_refs(tmp_path):
    code = """
        import jax
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        def _k(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x, interpret):
            m, n = x.shape
            return pl.pallas_call(
                _k,
                grid=(m // 8,),
                in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
                scratch_shapes=[pltpu.VMEM((8, 1), jnp.float32)],
                interpret=interpret,
            )(x)
    """
    fs = run_on(tmp_path, {"kernels/bad.py": code}, ["R9"])
    assert len(fs) == 1
    assert "takes 2 positional refs" in fs[0].message
    assert "= 3" in fs[0].message


def test_r9_prefetch_grid_spec_counts(tmp_path):
    code = """
        import jax
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        def _k(tbl_ref, x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(tables, x, interpret):
            m, n = x.shape
            grid_spec = pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(m // 8,),
                in_specs=[pl.BlockSpec((8, 128), lambda i, tbl: (tbl[i], 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            )
            return pl.pallas_call(
                _k,
                grid_spec=grid_spec,
                out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
                interpret=interpret,
            )(tables, x)
    """
    fs = run_on(tmp_path, {"kernels/pf.py": code}, ["R9"])
    # out map takes 1 arg but grid rank 1 + 1 prefetch = 2; the in map
    # is correct — prefetch refs arrive as trailing index-map args
    assert len(fs) == 1
    assert "out_specs[0]" in fs[0].message and "expected 2" in fs[0].message


def test_r9_disable_comment_suppresses(tmp_path):
    code = """
        import jax
        from jax.experimental import pallas as pl

        def _k(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x):
            m, _ = x.shape
            return pl.pallas_call(   # repro-check: disable=R9
                _k,
                grid=(m // 8,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
                out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
            )(x)
    """
    assert run_on(tmp_path, {"kernels/bad.py": code}, ["R9"]) == []


# ---------------------------------------------------------------------------
# committed compute-layer fixtures: pinned findings + CLI rendering
# ---------------------------------------------------------------------------


def test_compute_layer_fixtures_are_caught():
    """Each committed R7/R8/R9 fixture keeps producing its findings with
    correct `file:line RULE-ID` rendering, and the CLI exits non-zero
    per rule (the must-fail direction CI enforces)."""
    fixture = REPO_ROOT / "tests" / "fixtures" / "repro_check"

    r7 = run_paths([str(fixture)], rule_ids=["R7"], root=REPO_ROOT)
    assert [f.line for f in r7] == [16, 24, 25, 26]
    assert all(f.file == "tests/fixtures/repro_check/kernels/jit_tracing.py"
               for f in r7)
    assert r7[0].render().startswith(
        "tests/fixtures/repro_check/kernels/jit_tracing.py:16 R7 ")

    r8 = run_paths([str(fixture)], rule_ids=["R8"], root=REPO_ROOT)
    assert len(r8) == 1 and r8[0].line == 20
    assert r8[0].render().startswith(
        "tests/fixtures/repro_check/serving/engine_shapes.py:20 R8 ")

    r9 = run_paths([str(fixture)], rule_ids=["R9"], root=REPO_ROOT)
    assert len(r9) == 5
    assert all(f.file == "tests/fixtures/repro_check/kernels/bad_pallas.py"
               for f in r9)

    for rule in ("R7", "R8", "R9"):
        proc = subprocess.run(
            [sys.executable, "-m", "tools.repro_check", "--rules", rule,
             "tests/fixtures/repro_check"],
            cwd=REPO_ROOT, capture_output=True, text=True)
        assert proc.returncode == 1, (rule, proc.stdout, proc.stderr)
        assert rule in proc.stdout
