"""Allocation-aware scheduling contract: DVFS tiers + lane/uplink shares.

Covers the PR's invariants:

* monotone physics — a lower frequency tier is never faster and never
  spends more dynamic energy per token (time ∝ 1/f, power ∝ f³ ⇒ energy
  per token ∝ f²); sub-unit shares stretch time without changing
  per-request energy;
* no oversubscription — allocations book exclusive stretched windows, so
  per-lane busy intervals stay disjoint and share bounds are validated;
* nominal-tier golden — on a testbed whose specs carry a multi-tier DVFS
  table, pinning every decision to the nominal tier reproduces the
  single-tier (PR-3 admission/preemption and PR-4 paged-KV) simulator
  output bit-for-bit;
* the energy claim — PerLLM's learned (class, server, tier) policy cuts
  total energy ≥ 20% vs the fixed-nominal-tier PerLLM at equal-or-better
  admitted SLO attainment on the `diurnal` and `overload` scenarios.
"""
import copy

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (
    BandwidthModel, DVFS_TIERS, Simulator, generate_workload, paper_testbed,
)
from repro.cluster.simulator import _EventSimRuntime
from repro.cluster.workload import classify
from repro.core import (
    Allocation, Arrival, CSUCB, ClusterView, Decision, SchedulingPolicy,
    make_policy,
)


def _req(sid=0, arrival=0.0, prompt=256, out=16, deadline=4.0, payload=2e6):
    from repro.cluster.workload import ServiceRequest
    r = ServiceRequest(sid=sid, arrival=arrival, prompt_tokens=prompt,
                       output_tokens=out, deadline=deadline,
                       payload_bytes=payload)
    r.class_id = classify(r)
    return r


def _view(specs, t=0.0):
    return ClusterView(t=t, specs=specs, bw_factor=[1.0] * len(specs),
                       uplink_free_at=[0.0] * len(specs),
                       lane_free=[[0.0] * s.max_concurrency for s in specs])


# ---------------------------------------------------------------------------
# Monotone tier physics
# ---------------------------------------------------------------------------


@given(prompt=st.integers(32, 2048), out=st.integers(4, 96),
       k1=st.integers(0, len(DVFS_TIERS) - 1),
       k2=st.integers(0, len(DVFS_TIERS) - 1))
@settings(max_examples=40, deadline=None)
def test_lower_tier_never_faster_never_costlier_per_token(prompt, out,
                                                          k1, k2):
    """time ∝ 1/f and energy/token ∝ f²: the slower of two tiers is never
    faster and never spends more dynamic energy per token, on any spec."""
    if DVFS_TIERS[k1] > DVFS_TIERS[k2]:
        k1, k2 = k2, k1                       # k1 = slower (lower f)
    for spec in paper_testbed(freq_tiers=DVFS_TIERS)[:1] + \
            [paper_testbed(freq_tiers=DVFS_TIERS)[-1]]:
        t_slow = spec.service_time(prompt, out, tier=k1)
        t_fast = spec.service_time(prompt, out, tier=k2)
        assert t_slow >= t_fast
        tokens = prompt + out
        e_slow = spec.infer_energy(t_slow, tier=k1) / tokens
        e_fast = spec.infer_energy(t_fast, tier=k2) / tokens
        assert e_slow <= e_fast + 1e-12
        # the nominal tier reproduces the untier'd formulas bit-exactly
        assert spec.service_time(prompt, out, tier=spec.nominal_tier) \
            == spec.service_time(prompt, out)
        assert spec.infer_energy(t_fast, tier=-1) == spec.infer_energy(t_fast)


@given(share=st.floats(0.05, 1.0), prompt=st.integers(32, 512),
       out=st.integers(4, 64))
@settings(max_examples=25, deadline=None)
def test_shares_stretch_time_not_per_request_energy(share, prompt, out):
    """A sub-unit lane/bandwidth share stretches the window by 1/share
    while drawing share × power — per-request energy is share-invariant."""
    spec = paper_testbed(freq_tiers=DVFS_TIERS)[0]
    view = _view([spec])
    req = _req(prompt=prompt, out=out)
    full = Allocation()
    sliced = Allocation(lane_share=share, bw_share=share)
    t_full = view.predict_infer(req, 0, full)
    t_sliced = view.predict_infer(req, 0, sliced)
    assert t_sliced == pytest.approx(t_full / share)
    assert view.predict_tx(req, 0, sliced) \
        == pytest.approx(view.predict_tx(req, 0, full) / share)
    e_full = spec.infer_energy(t_full, lane_share=1.0)
    e_sliced = spec.infer_energy(t_sliced, lane_share=share)
    assert e_sliced == pytest.approx(e_full)


def test_allocation_validates_share_bounds():
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError):
            Allocation(lane_share=bad)
        with pytest.raises(ValueError):
            Allocation(bw_share=bad)


# ---------------------------------------------------------------------------
# Committed shares never oversubscribe
# ---------------------------------------------------------------------------


class _RandomAlloc(SchedulingPolicy):
    """Pins everything to server 0 with a randomized allocation."""

    name = "random-alloc"

    def __init__(self, n_tiers, seed=0):
        self.rng = np.random.default_rng(seed)
        self.n_tiers = n_tiers

    def assign(self, req, view):
        alloc = Allocation(
            freq_tier=int(self.rng.integers(self.n_tiers)),
            lane_share=float(self.rng.uniform(0.3, 1.0)),
            bw_share=float(self.rng.uniform(0.3, 1.0)))
        return Decision(server=0, alloc=alloc)


@given(seed=st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_committed_shares_never_oversubscribe(seed):
    """Random allocations on a one-lane, one-link server: every booking's
    busy window is exclusive — stretched sub-share bookings can never
    stack into >100% committed lane or uplink."""
    import dataclasses
    spec = dataclasses.replace(paper_testbed(n_edge=1,
                                             freq_tiers=DVFS_TIERS)[0],
                               max_concurrency=1)
    sim = Simulator([spec], slot=None, seed=0)
    rt = _EventSimRuntime(sim, _RandomAlloc(len(DVFS_TIERS), seed))
    wl = [copy.copy(s) for s in generate_workload(25, rate=20.0, seed=seed)]
    for r in wl:
        r.class_id = classify(r)
        r.preemptions = 0
        r.kv_server, r.kv_blocks = -1, 0
    bookings = []
    orig = rt.dispatch

    def record(t, req, decision, **kw):
        orig(t, req, decision, **kw)
        bookings.append(rt._inflight[req.sid])

    rt.dispatch = record
    for r in wl:
        rt.loop.push(Arrival(r.arrival, requests=(r,)))
    rt.drain()
    assert len(rt.outcomes) == len(wl)
    # lane windows disjoint
    lanes = sorted((b.begin, b.finish) for b in bookings)
    for (_s1, e1), (s2, _e2) in zip(lanes, lanes[1:], strict=False):
        assert e1 <= s2 + 1e-9, "lane oversubscribed"
    # uplink transfer windows disjoint (each holds its stretched duration)
    links = sorted((b.ready - b.tx_dur, b.ready) for b in bookings)
    for (_s1, e1), (s2, _e2) in zip(links, links[1:], strict=False):
        assert e1 <= s2 + 1e-9, "uplink oversubscribed"


def test_commit_tracks_tier_load():
    """`ClusterView.commit` splits committed lane-seconds by tier when the
    view carries a tier ledger."""
    specs = paper_testbed(freq_tiers=DVFS_TIERS)
    view = _view(specs)
    view.tier_load = [[0.0] * s.n_tiers for s in specs]
    req = _req()
    view.commit(req, 0, alloc=Allocation(freq_tier=0))
    view.commit(req, 0, alloc=Allocation(freq_tier=0))
    view.commit(req, 0)                       # nominal (tier -1 resolves)
    nominal = specs[0].nominal_tier
    assert view.tier_load[0][0] > 0.0
    assert view.tier_load[0][nominal] > 0.0
    assert view.tier_load[0][0] == pytest.approx(
        2.0 * view.tier_load[0][nominal] / DVFS_TIERS[0])


# ---------------------------------------------------------------------------
# CSUCB over (class, server, tier)
# ---------------------------------------------------------------------------


def test_csucb_grid_select_respects_mask_and_returns_pair():
    bandit = CSUCB(1, 3, n_tiers=4)
    mask = np.zeros((3, 4), bool)
    mask[1, 2] = mask[2, 0] = True
    for _ in range(10):
        j, k = bandit.select(0, mask)
        assert mask[j, k]
        bandit.update(0, j, -0.1, 0.0, tier=k)
    with pytest.raises(ValueError, match="tiers"):
        bandit.select(0, np.ones(3, bool))


# ---------------------------------------------------------------------------
# Nominal-tier golden: bit-exact against the single-tier runtime
# ---------------------------------------------------------------------------


def _golden_pair(scenario=None, n=400, kv_blocks=0,
                 admission=False, preempt=False):
    """(single-tier reference, multi-tier-specs-pinned-nominal) SimResults
    plus per-request server choices, on identical seeds."""
    results = []
    for tiered_specs in (False, True):
        specs = paper_testbed(
            "llama2-7b", kv_blocks=kv_blocks,
            freq_tiers=DVFS_TIERS if tiered_specs else (1.0,))
        wl = [copy.copy(s) for s in generate_workload(
            n, seed=0, scenario=scenario)]
        sim = Simulator(specs, BandwidthModel(fluctuating=True, seed=1),
                        seed=42)
        # reference: single-tier specs (default policy); candidate:
        # multi-tier specs with every decision pinned to the nominal tier
        pol = make_policy("perllm", len(specs), admission=admission,
                          preempt=preempt, tiers=not tiered_specs)
        res = sim.run(wl, pol, scenario=scenario)
        servers = [r.server for r in sorted(wl, key=lambda r: r.sid)]
        results.append((res, servers))
    return results


@pytest.mark.parametrize("kw", [
    dict(),                                             # plain event mode
    dict(scenario="overload", admission=True,
         preempt=True),                                 # PR-3 semantics
    dict(scenario="kv-pressure", kv_blocks=48,
         admission=True, preempt=True),                 # PR-4 semantics
])
def test_nominal_tier_bit_exact_golden(kw):
    """Multi-tier specs + every decision pinned to the nominal tier ==
    single-tier specs, bit-for-bit: the allocation machinery at f = 1.0
    is exactly the placement-only runtime (PR-3 admission/preemption and
    PR-4 paged-KV results reproduce unchanged)."""
    (ref, ref_servers), (pinned, pinned_servers) = _golden_pair(**kw)
    assert pinned == ref                    # SimResult dataclass equality
    assert pinned_servers == ref_servers


# ---------------------------------------------------------------------------
# The energy claim (ISSUE 5 acceptance bar)
# ---------------------------------------------------------------------------


def _energy_pair(scenario, n=2000):
    out = {}
    for tiers in (False, True):
        specs = paper_testbed("llama2-7b", freq_tiers=DVFS_TIERS)
        wl = generate_workload(n, seed=0, scenario=scenario)
        sim = Simulator(specs, BandwidthModel(seed=1), slot=None, seed=42)
        pol = make_policy("perllm", len(specs), admission=True, tiers=tiers)
        out[tiers] = sim.run([copy.copy(s) for s in wl], pol,
                             scenario=scenario)
    return out[False], out[True]


@pytest.mark.parametrize("scenario", ["diurnal", "overload"])
def test_learned_tiers_cut_energy_at_equal_or_better_admitted_slo(scenario):
    """PerLLM's learned (class, server, tier) policy cuts total energy by
    ≥ 20% vs the fixed-nominal-tier PerLLM, at equal-or-better admitted
    SLO attainment."""
    nominal, tiered = _energy_pair(scenario)
    cut = 1.0 - tiered.total_energy / nominal.total_energy
    assert cut >= 0.20, (
        f"{scenario}: tiered policy cut total energy only {cut*100:.1f}% "
        f"({tiered.total_energy/1e3:.1f} vs "
        f"{nominal.total_energy/1e3:.1f} kJ)")
    assert tiered.admitted_success_rate >= nominal.admitted_success_rate, (
        f"{scenario}: admitted SLO regressed "
        f"({tiered.admitted_success_rate:.4f} < "
        f"{nominal.admitted_success_rate:.4f})")
    # the win is allocation efficiency, not an artifact of serving less:
    # energy normalized per *served token* must also drop materially
    # (shedding alone cannot move this metric), and dynamic inference
    # energy — the lever DVFS actually pulls — must fall
    assert tiered.energy_per_token <= 0.90 * nominal.energy_per_token, (
        f"{scenario}: energy/token cut too thin "
        f"({tiered.energy_per_token:.3f} vs "
        f"{nominal.energy_per_token:.3f} J/tok)")
    assert tiered.e_infer < nominal.e_infer


# ---------------------------------------------------------------------------
# Live server: tiers map onto real decode-step pacing
# ---------------------------------------------------------------------------


def test_live_server_tier_paces_engine_ticks():
    """A dispatched Decision's DVFS tier retunes the host: engine ticks
    cost decode_step_time/f, the engine's freq_scale reflects it, and the
    realized energy charges f³ power over the stretched window."""
    pytest.importorskip("jax")
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingEngine
    from repro.serving.perllm_server import PerLLMServer

    class PinSlow(SchedulingPolicy):
        name = "pin-slow"

        def assign(self, req, view):
            return Decision(server=0, alloc=Allocation(freq_tier=0))

    cfg = get_config("gemma-2b").reduced(n_layers=1, d_model=32,
                                         vocab_size=128)
    spec = dataclasses.replace(paper_testbed(n_edge=1)[0],
                               freq_tiers=(0.5, 1.0))
    engines = [ServingEngine(cfg, init_params(jax.random.key(0), cfg),
                             max_batch=2, max_seq=32)]
    srv = PerLLMServer([spec], engines, scheduler=PinSlow())
    sr = srv.submit([1, 2, 3], max_new_tokens=4, payload_bytes=1e4)
    done = srv.run_until_idle()
    assert sr in done
    assert engines[0].freq_scale == 0.5
    assert srv.engine_tier[0] == 0
    # each decode tick costs the tier-stretched analytic step time
    assert spec.decode_step_time(tier=0) \
        == pytest.approx(2.0 * spec.decode_step_time())
    # realized energy: f³ power over the (stretched) realized window
    out_energy = spec.infer_energy(sr.done_clock - sr.admit_clock, tier=0) \
        + spec.tx_power * sr.tx_dur
    srv_energy = spec.infer_energy(sr.done_clock - sr.admit_clock) * 0.125 \
        + spec.tx_power * sr.tx_dur
    assert out_energy == pytest.approx(srv_energy)